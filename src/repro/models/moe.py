"""Mixture-of-Experts layer with capacity-layout aggregated expert compute.

The MoE layer is the LM-side embodiment of the paper's problem: top-k routing
fragments the token batch into E small per-expert GEMMs (fine-grained tasks).
Launching them separately starves the MXU; this module aggregates them into
one grouped launch over a static ``(E, C, d)`` capacity layout — the bucketed
static-shape analogue of the paper's on-the-fly aggregation (DESIGN.md §2).

Dispatch is the standard cumsum-position scheme: each token's position within
its expert's capacity buffer is its running count; tokens beyond capacity are
dropped (classic Switch behavior, capacity_factor as the S1 "sub-grid size"
knob).  Expert compute runs either as one batched XLA einsum or through the
``grouped_gemm`` Pallas kernel that additionally skips dead capacity tiles.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.models.common import Params, dense_init, split_keys, stacked_init


def moe_init(key, cfg, dtype) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, dtype=jnp.float32),
        "w_gate": stacked_init(ks[1], e, d, ff, dtype),
        "w_up": stacked_init(ks[2], e, d, ff, dtype),
        "w_down": stacked_init(ks[3], e, ff, d, dtype),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * (cfg.shared_expert_d_ff or cfg.d_ff)
        ks2 = split_keys(ks[4], 4)
        # the n_shared always-on experts are *fused* into one wide SwiGLU
        p["shared"] = {
            "w_gate": dense_init(ks2[0], d, sff, dtype),
            "w_up": dense_init(ks2[1], d, sff, dtype),
            "w_down": dense_init(ks2[2], sff, d, dtype),
        }
        p["shared_gate"] = dense_init(ks2[3], d, 1, dtype=jnp.float32)
    return p


CAPACITY_CHUNK = 16_384   # S1 knob: rows per aggregated expert-GEMM launch


def capacity_chunks(capacity: int, chunk: int = CAPACITY_CHUNK) -> int:
    """Number of (power-of-two) capacity chunks for the scanned expert FFN."""
    n = 1
    while capacity / n > chunk:
        n *= 2
    return n


def expert_capacity(n_tokens: int, cfg, capacity_factor: float = 1.25,
                    align: int = 128) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * capacity_factor))
    c = max(align, (c + align - 1) // align * align)
    # align up so the capacity-chunked scan divides evenly
    n = capacity_chunks(c)
    step = align * n
    return (c + step - 1) // step * step


def _dispatch_indices(top_idx: jax.Array, e: int, capacity: int):
    """Positions of each (token, k) pair inside its expert's capacity buffer.

    top_idx: (T, k) int32 expert ids.  Returns (pos (T, k), keep (T, k)).
    Sequential priority over the k slots (slot 0 routed first), cumulative
    counts across slots — the standard Switch/GShard dispatch order.
    """
    t, k = top_idx.shape
    pos = jnp.zeros((t, k), jnp.int32)
    counts = jnp.zeros((e,), jnp.int32)
    for j in range(k):
        onehot = jax.nn.one_hot(top_idx[:, j], e, dtype=jnp.int32)   # (T, E)
        within = jnp.cumsum(onehot, axis=0) - onehot                  # before t
        pos = pos.at[:, j].set(jnp.sum(within * onehot, axis=1)
                               + counts[top_idx[:, j]])
        counts = counts + jnp.sum(onehot, axis=0)
    keep = pos < capacity
    return pos, keep


def moe_ffn(p: Params, x: jax.Array, cfg, *, capacity_factor: float = 1.25,
            use_pallas: bool = False) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    xt = constrain(xt, "tokens", "embed")

    # --- routing ---
    # matmul in the activation dtype, fp32 only from the (T, E) logits on:
    # an fp32 router input would give the backward an fp32 cotangent copy
    # of the entire token stream (measured 6.4 GB x dozens for dbrx train).
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    logits = constrain(logits, "tokens", None)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, k)                      # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    capacity = expert_capacity(t, cfg, capacity_factor)
    pos, keep = _dispatch_indices(top_idx, e, capacity)

    # --- scatter tokens into the aggregation slab (E, C, d) ---
    flat_ti = jnp.repeat(jnp.arange(t), k)                        # (T*k,)
    flat_e = top_idx.reshape(-1)
    # dropped tokens point one past the buffer: scatter drops OOB updates
    flat_pos = jnp.where(keep.reshape(-1), pos.reshape(-1), capacity)
    x_cap = jnp.zeros((e, capacity, d), x.dtype)
    x_cap = x_cap.at[flat_e, flat_pos].add(xt[flat_ti])           # unique slots
    x_cap = constrain(x_cap, "expert", "capacity", "embed")

    group_len = jnp.minimum(
        jnp.sum(jax.nn.one_hot(top_idx.reshape(-1), e, dtype=jnp.int32), axis=0),
        capacity)

    # --- aggregated expert compute ---
    if use_pallas:
        from repro.kernels.ops import grouped_gemm
        g = grouped_gemm(x_cap, p["w_gate"], group_len)
        u = grouped_gemm(x_cap, p["w_up"], group_len)
        h = jax.nn.silu(g) * u
        y_cap = grouped_gemm(h, p["w_down"], group_len)
    else:
        # scan over capacity chunks: the (E, C, ff) hidden never exists at
        # once — one chunk's worth of MXU work per launch, rematted (the
        # hydro sub-grid-size knob applied to the aggregated expert GEMM;
        # dbrx train: 14 GB fp32 hidden transients -> ~0.9 GB per chunk)
        n_chunks = capacity_chunks(capacity)

        def chunk_body(xc):
            g = jnp.einsum("ecd,edf->ecf", xc, p["w_gate"])
            u = jnp.einsum("ecd,edf->ecf", xc, p["w_up"])
            h = jax.nn.silu(g) * u
            h = constrain(h, "expert", "capacity", "ff")
            return jnp.einsum("ecf,efd->ecd", h, p["w_down"])

        if n_chunks == 1:
            y_cap = chunk_body(x_cap)
        else:
            cc = capacity // n_chunks
            xch = x_cap.reshape(e, n_chunks, cc, d).transpose(1, 0, 2, 3)
            body = jax.checkpoint(
                lambda _, xc: (None, chunk_body(xc)),
                policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False)
            _, ych = jax.lax.scan(body, None, xch)
            y_cap = ych.transpose(1, 0, 2, 3).reshape(e, capacity, d)
    y_cap = constrain(y_cap, "expert", "capacity", "embed")

    # --- combine: gather each (token, k) result, weight, sum ---
    # OOB gather indices clip to the last row; those lanes carry weight 0
    gathered = constrain(y_cap[flat_e, flat_pos], "tokens", "embed")
    w = (top_p * keep).reshape(-1, 1).astype(gathered.dtype)
    y = jnp.zeros((t, d), x.dtype).at[flat_ti].add(gathered * w)
    y = constrain(y, "tokens", "embed")

    # --- fused shared (always-on) experts ---
    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        ys = hs @ sp["w_down"]
        gate = jax.nn.sigmoid(
            (xt @ p["shared_gate"].astype(xt.dtype)).astype(jnp.float32))
        y = y + (ys * gate.astype(ys.dtype))
    return y.reshape(b, s, d)


def aux_load_balance_loss(logits: jax.Array, top_idx: jax.Array, e: int):
    """Switch-style auxiliary loss (exported for the training loop)."""
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_idx[:, 0], e), axis=0)
    return e * jnp.sum(me * ce)
