"""Model assembly: init / forward / decode for all 10 assigned families.

Layer stacks are ``lax.scan``-ed over stacked parameters (small HLO, fast
compile, remat-friendly).  Heterogeneous stacks scan over *groups* whose body
is the repeating pattern:

  dense/moe      : [block] x L
  vlm            : [self x (every-1), cross] x G        (llama-3.2-vision)
  ssm  (xlstm)   : [mLSTM x (every-1), sLSTM] x G
  hybrid (zamba2): [mamba2 x every] x G, one SHARED attn+MLP block applied
                   between groups (one set of weights, G invocations — the
                   paper's "same code region, different data" taken to the
                   extreme: the aggregated kernel IS the shared block)
  audio (encdec) : encoder [block] x Le, decoder [self+cross] x Ld

The language-model loss is computed in sequence chunks so the fp32
``(B, S, V)`` logits tensor never materializes (vocab 152k at 1M tokens
would be ~600 GB).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.common import (
    Params, dense_init, dtype_of, rmsnorm, softmax_xent, split_keys,
)

Batch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stacked_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jnp.stack(split_keys(key, n)))


def _maybe_remat(fn, cfg):
    """Full remat of each layer body: recompute everything in backward.
    Measured against dots_with_no_batch_dims_saveable this halves the
    per-layer saved-activation slope (2.7 -> 1.1 GB/layer/device for
    granite-8b train_4k pre-SP) for ~33% more flops — the right trade for
    memory-bound large cells (EXPERIMENTS.md §Perf)."""
    if not cfg.remat:
        return fn
    policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def _embed_init(key, cfg, dtype) -> Params:
    ks = split_keys(key, 2)
    p = {"emb": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02).astype(dtype),
         "ln_f": jnp.ones((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dtype)
    return p


def _logits_head(p, h, cfg):
    w = p["emb"].T if cfg.tie_embeddings else p["head"]
    return h @ w


def chunked_xent(p, hidden, labels, cfg, chunk: int = 512):
    """Mean cross-entropy without materializing (B, S, V) logits."""
    b, s, d = hidden.shape
    hidden = rmsnorm(hidden, p["ln_f"], cfg.norm_eps)
    if s <= chunk or s % chunk != 0:
        return softmax_xent(_logits_head(p, hidden, cfg), labels)
    n = s // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        hh, ll = xs
        logits = _logits_head(p, hh, cfg)
        return carry + softmax_xent(logits, ll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / n


# ---------------------------------------------------------------------------
# per-family stacks
# ---------------------------------------------------------------------------

def _family(cfg) -> str:
    return cfg.family


def init_params(cfg, key) -> Params:
    dtype = dtype_of(cfg)
    ks = split_keys(key, 4)
    p: Params = {"embed": _embed_init(ks[0], cfg, dtype)}
    fam = _family(cfg)

    if fam in ("dense", "moe"):
        kind = "moe" if cfg.n_experts else "self"
        p["layers"] = _stacked_init(
            lambda k: tfm.block_init(k, cfg, dtype, kind), ks[1], cfg.n_layers)

    elif fam == "vlm":
        every = cfg.cross_attn_every
        groups = cfg.n_layers // every
        p["selfs"] = _stacked_init(
            lambda k: _stacked_init(
                lambda k2: tfm.block_init(k2, cfg, dtype, "self"),
                k, every - 1),
            ks[1], groups)
        p["crosses"] = _stacked_init(
            lambda k: tfm.block_init(k, cfg, dtype, "cross"), ks[2], groups)

    elif fam == "ssm":       # xlstm
        every = cfg.slstm_every
        groups = cfg.n_layers // every
        p["mlstm"] = _stacked_init(
            lambda k: _stacked_init(
                lambda k2: ssm_mod.mlstm_init(k2, cfg, dtype), k, every - 1),
            ks[1], groups)
        p["slstm"] = _stacked_init(
            lambda k: ssm_mod.slstm_init(k, cfg, dtype), ks[2], groups)
        p["norms"] = jnp.ones((groups, every, cfg.d_model), dtype)

    elif fam == "hybrid":    # zamba2
        every = cfg.shared_attn_every
        groups = cfg.n_layers // every
        p["mamba"] = _stacked_init(
            lambda k: _stacked_init(
                lambda k2: ssm_mod.mamba2_init(k2, cfg, dtype), k, every),
            ks[1], groups)
        p["norms"] = jnp.ones((groups, every, cfg.d_model), dtype)
        p["shared"] = tfm.block_init(ks[2], cfg, dtype, "self")

    elif fam == "audio":     # enc-dec
        p["encoder"] = _stacked_init(
            lambda k: tfm.block_init(k, cfg, dtype, "self"),
            ks[1], cfg.n_encoder_layers)
        p["decoder"] = _stacked_init(
            lambda k: tfm.decoder_layer_init(k, cfg, dtype),
            ks[2], cfg.n_layers)
        p["enc_ln"] = jnp.ones((cfg.d_model,), dtype)

    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def forward_hidden(cfg, params, batch: Batch) -> jax.Array:
    """Returns final hidden states (B, S, d) before the LM head."""
    fam = _family(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"]["emb"][tokens].astype(dtype_of(cfg))
    x = constrain(x, "batch", "seq_sp", "embed")
    positions = jnp.arange(s)

    if fam in ("dense", "moe"):
        def body(h, lp):
            return tfm.self_block_apply(lp, h, cfg, positions), None
        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])

    elif fam == "vlm":
        memory = batch["vision"].astype(x.dtype)

        def group(h, gp):
            sp, cp = gp

            def inner(hh, lp):
                return tfm.self_block_apply(lp, hh, cfg, positions), None
            h, _ = jax.lax.scan(inner, h, sp)
            h = tfm.cross_block_apply(cp, h, memory, cfg)
            return h, None
        group = _maybe_remat(group, cfg)
        x, _ = jax.lax.scan(group, x, (params["selfs"], params["crosses"]))

    elif fam == "ssm":
        def group(h, gp):
            mp, sp, norms = gp

            def inner(hh, inps):
                lp, nw = inps
                y, _ = ssm_mod.mlstm_apply(lp, rmsnorm(hh, nw, cfg.norm_eps),
                                           cfg)
                return constrain(hh + y, "batch", "seq_sp", "embed"), None
            h, _ = jax.lax.scan(inner, h, (mp, norms[:-1]))
            y, _ = ssm_mod.slstm_apply(sp, rmsnorm(h, norms[-1], cfg.norm_eps),
                                       cfg)
            return constrain(h + y, "batch", "seq_sp", "embed"), None
        group = _maybe_remat(group, cfg)
        x, _ = jax.lax.scan(group, x,
                            (params["mlstm"], params["slstm"], params["norms"]))

    elif fam == "hybrid":
        shared = params["shared"]

        def group(h, gp):
            mp, norms = gp
            h = tfm.self_block_apply(shared, h, cfg, positions)

            def inner(hh, inps):
                lp, nw = inps
                y, _ = ssm_mod.mamba2_apply(lp, rmsnorm(hh, nw, cfg.norm_eps),
                                            cfg)
                return constrain(hh + y, "batch", "seq_sp", "embed"), None
            h, _ = jax.lax.scan(inner, h, (mp, norms))
            return h, None
        group = _maybe_remat(group, cfg)
        x, _ = jax.lax.scan(group, x, (params["mamba"], params["norms"]))

    elif fam == "audio":
        frames = batch["frames"].astype(x.dtype)
        enc_pos = jnp.arange(frames.shape[1])

        def enc_body(h, lp):
            return tfm.self_block_apply(lp, h, cfg, enc_pos,
                                        causal=False), None
        enc_body = _maybe_remat(enc_body, cfg)
        memory, _ = jax.lax.scan(enc_body, frames, params["encoder"])
        memory = rmsnorm(memory, params["enc_ln"], cfg.norm_eps)

        def dec_body(h, lp):
            return tfm.encdec_decoder_apply(lp, h, memory, cfg,
                                            positions), None
        dec_body = _maybe_remat(dec_body, cfg)
        x, _ = jax.lax.scan(dec_body, x, params["decoder"])

    else:
        raise ValueError(fam)
    return x


def forward(cfg, params, batch: Batch) -> jax.Array:
    """Full logits (small models / smoke tests only)."""
    h = forward_hidden(cfg, params, batch)
    h = rmsnorm(h, params["embed"]["ln_f"], cfg.norm_eps)
    return _logits_head(params["embed"], h, cfg)


def loss_fn(cfg, params, batch: Batch) -> jax.Array:
    h = forward_hidden(cfg, params, batch)
    return chunked_xent(params["embed"], h, batch["labels"], cfg)


# ---------------------------------------------------------------------------
# decode (single token)
# ---------------------------------------------------------------------------

def init_cache(cfg, params, batch: Batch, batch_size: int, max_len: int):
    """Build the decode cache (KV / SSM states / cross-KV) for a family."""
    fam = _family(cfg)
    dtype = dtype_of(cfg)
    cache: Dict[str, Any] = {"len": jnp.zeros((batch_size,), jnp.int32)}

    def kv(n):
        return jax.vmap(lambda _: tfm.kv_cache_init(cfg, batch_size, max_len,
                                                    dtype))(jnp.arange(n))

    if fam in ("dense", "moe"):
        cache["kv"] = kv(cfg.n_layers)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        groups = cfg.n_layers // every
        cache["kv"] = kv(groups * (every - 1)).copy()
        # reshape to (G, every-1, ...)
        cache["kv"] = jax.tree_util.tree_map(
            lambda x: x.reshape((groups, every - 1) + x.shape[1:]), cache["kv"])
        memory = batch["vision"].astype(dtype)
        cache["cross_kv"] = jax.vmap(
            lambda cp: tfm.cross_kv_precompute(cp, memory, cfg)
        )(params["crosses"])
    elif fam == "ssm":
        every = cfg.slstm_every
        groups = cfg.n_layers // every
        cache["mlstm"] = jax.vmap(lambda _: jax.vmap(
            lambda __: ssm_mod.mlstm_state_init(cfg, batch_size))(
                jnp.arange(every - 1)))(jnp.arange(groups))
        cache["slstm"] = jax.vmap(
            lambda _: ssm_mod.slstm_state_init(cfg, batch_size))(
                jnp.arange(groups))
    elif fam == "hybrid":
        every = cfg.shared_attn_every
        groups = cfg.n_layers // every
        cache["mamba"] = jax.vmap(lambda _: jax.vmap(
            lambda __: ssm_mod.mamba2_state_init(cfg, batch_size, dtype))(
                jnp.arange(every)))(jnp.arange(groups))
        cache["shared_kv"] = kv(groups)
    elif fam == "audio":
        cache["kv"] = kv(cfg.n_layers)
        memory = forward_encoder(cfg, params, batch["frames"].astype(dtype))
        cache["cross_kv"] = jax.vmap(
            lambda dp: tfm.xattn_kv_precompute(dp, memory, cfg)
        )(params["decoder"])
    return cache


def forward_encoder(cfg, params, frames):
    enc_pos = jnp.arange(frames.shape[1])

    def enc_body(h, lp):
        return tfm.self_block_apply(lp, h, cfg, enc_pos, causal=False), None
    memory, _ = jax.lax.scan(enc_body, frames, params["encoder"])
    return rmsnorm(memory, params["enc_ln"], cfg.norm_eps)


def _scan_decode(body, x, params_stacked, cache_stacked, extra_stacked=None):
    """Scan over layers with the cache as part of the CARRY.

    Passing the cache as scan xs and re-emitting it as ys keeps TWO
    full-size cache buffers live across the loop (the stacked ys output
    cannot alias the xs input); for a 32k-decode cell that is 2x the KV
    cache in HBM (measured: 55 GB temp for qwen1.5-32b decode_32k).  With
    the cache in the carry, the per-layer ``dynamic_update_index_in_dim``
    is performed in place on the single carry buffer (EXPERIMENTS.md §Perf
    hillclimb C).

    ``body(x, layer_params, cache_layer[, extra_layer]) -> (x, new_cache)``.
    """
    n = jax.tree_util.tree_leaves(params_stacked)[0].shape[0]

    def idx(tree, i):
        return jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            tree)

    def f(carry, inp):
        h, cache = carry
        lp, i = inp
        c_i = idx(cache, i)
        if extra_stacked is not None:
            h, c_new = body(h, lp, c_i, idx(extra_stacked, i))
        else:
            h, c_new = body(h, lp, c_i)
        cache = jax.tree_util.tree_map(
            lambda c, nw: jax.lax.dynamic_update_index_in_dim(
                c, nw.astype(c.dtype), i, 0),
            cache, c_new)
        return (h, cache), None

    (x, cache), _ = jax.lax.scan(
        f, (x, cache_stacked), (params_stacked, jnp.arange(n)))
    return x, cache


def decode_step(cfg, params, cache, tokens) -> Tuple[jax.Array, Any]:
    """tokens: (B, 1) -> (logits (B, V), new cache).  cache["len"] holds each
    request's current length (ragged aggregated batches)."""
    fam = _family(cfg)
    clen = cache["len"]
    x = params["embed"]["emb"][tokens].astype(dtype_of(cfg))
    cache = dict(cache)

    if fam in ("dense", "moe"):
        def body(h, lp, c):
            return tfm.self_block_decode(lp, h, cfg, c, clen)
        x, cache["kv"] = _scan_decode(body, x, params["layers"], cache["kv"])

    elif fam == "vlm":
        def group(h, gp, c, xkv):
            sp, cp = gp

            def inner(hh, lp, cc):
                return tfm.self_block_decode(lp, hh, cfg, cc, clen)
            h, c = _scan_decode(inner, h, sp, c)
            h = tfm.cross_block_decode(cp, h, cfg, xkv)
            return h, c
        x, cache["kv"] = _scan_decode(
            group, x, (params["selfs"], params["crosses"]), cache["kv"],
            extra_stacked=cache["cross_kv"])

    elif fam == "ssm":
        def group(h, gp, st):
            mp, sp, norms = gp
            mst, sst = st

            def inner(hh, inps, s):
                lp, nw = inps
                y, s = ssm_mod.mlstm_apply(lp, rmsnorm(hh, nw, cfg.norm_eps),
                                           cfg, state=s)
                return hh + y, s
            h, mst = _scan_decode(inner, h, (mp, norms[:-1]), mst)
            y, sst = ssm_mod.slstm_apply(sp, rmsnorm(h, norms[-1],
                                                     cfg.norm_eps),
                                         cfg, state=sst)
            return h + y, (mst, sst)
        x, (cache["mlstm"], cache["slstm"]) = _scan_decode(
            group, x, (params["mlstm"], params["slstm"], params["norms"]),
            (cache["mlstm"], cache["slstm"]))

    elif fam == "hybrid":
        shared = params["shared"]

        def group(h, gp, st):
            mp, norms = gp
            mst, skv = st
            h, skv = tfm.self_block_decode(shared, h, cfg, skv, clen)

            def inner(hh, inps, s):
                lp, nw = inps
                y, s = ssm_mod.mamba2_apply(lp, rmsnorm(hh, nw, cfg.norm_eps),
                                            cfg, state=s)
                return hh + y, s
            h, mst = _scan_decode(inner, h, (mp, norms), mst)
            return h, (mst, skv)
        x, (cache["mamba"], cache["shared_kv"]) = _scan_decode(
            group, x, (params["mamba"], params["norms"]),
            (cache["mamba"], cache["shared_kv"]))

    elif fam == "audio":
        def body(h, lp, c, xkv):
            return tfm.encdec_decoder_decode(lp, h, cfg, c, clen, xkv)
        x, cache["kv"] = _scan_decode(body, x, params["decoder"],
                                      cache["kv"],
                                      extra_stacked=cache["cross_kv"])

    else:
        raise ValueError(fam)

    h = rmsnorm(x[:, 0], params["embed"]["ln_f"], cfg.norm_eps)
    logits = _logits_head(params["embed"], h, cfg)
    cache["len"] = clen + 1
    return logits, cache
