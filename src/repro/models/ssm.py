"""State-space / recurrent mixers: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

All three are *chunked*: the sequence is processed in ``cfg.ssm_chunk``-sized
blocks with dense intra-chunk compute (MXU-friendly matmuls) and a scan over
inter-chunk states.  The chunk size is the strategy-1 knob of these layers —
bigger chunks mean bigger aggregated matmuls per launch, fewer scan steps,
more VMEM per block; the same trade the paper's sub-grid size controls.

Decode state is O(1) in sequence length (conv tail + SSM / matrix-memory
state), which is what qualifies the ssm/hybrid archs for the ``long_500k``
cell.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, split_keys

MAMBA_HEAD_DIM = 64
CONV_WIDTH = 4


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def mamba2_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    n_heads = inner // MAMBA_HEAD_DIM
    n = cfg.ssm_state
    ks = split_keys(key, 4)
    # in_proj emits z (gate), x, B, C, dt
    d_in_proj = 2 * inner + 2 * n + n_heads
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_WIDTH, inner + 2 * n),
                                     dtype=jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((inner + 2 * n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm_w": jnp.ones((inner,), dtype),
        "out_proj": dense_init(ks[2], inner, d, dtype),
    }


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan (Mamba2 algorithm 1, state-passing form).

    x: (b, T, H, P); dt: (b, T, H); A: (H,); B, C: (b, T, N).
    Returns y: (b, T, H, P).
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    nc = t // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * (-jnp.exp(A))[None, None, None, :]          # (b,nc,L,H) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum

    # intra-chunk (causal masked attention-like term)
    # decay(i, j) = exp(dA_cs[i] - dA_cs[j]) for i >= j.  Mask BEFORE the
    # exp: exp(+big) for the i<j entries is inf, and inf*0 poisons the
    # backward pass with NaNs even though the forward value is masked out.
    diff = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)             # (b,nc,L,L)
    m = cb[..., None] * decay * dtc[:, :, None, :, :]      # (b,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)

    # chunk-final states: S_c = sum_j exp(dA_cs[L-1]-dA_cs[j]) dt_j B_j x_j^T
    last = dA_cs[:, :, -1:, :]                             # (b,nc,1,H)
    w = jnp.exp(last - dA_cs) * dtc                        # (b,nc,L,H)
    S_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w, Bc, xc)

    # inter-chunk recurrence: S_{c} (state BEFORE chunk c)
    chunk_decay = jnp.exp(last[:, :, 0, :])                # (b,nc,H)

    def scan_fn(s_prev, inp):
        dec, s_new = inp                                   # (b,H), (b,H,N,P)
        s_next = s_prev * dec[..., None, None] + s_new
        return s_next, s_prev

    s0 = jnp.zeros((b, h, n, p), x.dtype)
    _, S_before = jax.lax.scan(
        scan_fn, s0,
        (chunk_decay.transpose(1, 0, 2), S_chunk.transpose(1, 0, 2, 3, 4)))
    S_before = S_before.transpose(1, 0, 2, 3, 4)           # (b,nc,H,N,P)

    # inter-chunk contribution: y_i += exp(dA_cs[i]) C_i . S_before
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         Cc, S_before, jnp.exp(dA_cs))
    y = (y_intra + y_inter).reshape(b, t, h, p)
    return y


def _causal_conv(x, w, b, tail: Optional[jax.Array] = None):
    """Depthwise causal conv, width CONV_WIDTH.  x: (B, T, C); w: (W, C)."""
    width = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + b[None, None, :], xp[:, -(width - 1):, :]


def mamba2_apply(p: Params, x: jax.Array, cfg,
                 state: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, T, d).  state=None -> training/prefill (chunked scan);
    state given -> single-token decode (T==1), returns updated state."""
    b, t, d = x.shape
    inner = cfg.ssm_expand * d
    h = inner // MAMBA_HEAD_DIM
    n = cfg.ssm_state
    proj = x @ p["in_proj"]
    # split: z (inner), xBC (inner + 2n), dt (h)
    z = proj[..., :inner]
    xbc = proj[..., inner:2 * inner + 2 * n]
    dt_raw = proj[..., 2 * inner + 2 * n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)
    A = p["A_log"]

    if state is None:
        xbc_c, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xbc_c = jax.nn.silu(xbc_c)
        xs = xbc_c[..., :inner].reshape(b, t, h, MAMBA_HEAD_DIM)
        Bm = xbc_c[..., inner:inner + n]
        Cm = xbc_c[..., inner + n:]
        chunk = min(cfg.ssm_chunk, t)
        assert t % chunk == 0, (t, chunk)
        y = _ssd_chunked(xs.astype(jnp.float32), dt, A,
                         Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk)
        y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
        new_state = None
    else:
        xbc_c, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                        tail=state["conv"])
        xbc_c = jax.nn.silu(xbc_c)
        xs = xbc_c[..., :inner].reshape(b, t, h, MAMBA_HEAD_DIM)
        Bm = xbc_c[..., inner:inner + n]
        Cm = xbc_c[..., inner + n:]
        # single-step SSM update: S' = exp(dt A) S + dt B x^T ; y = C . S'
        dA = jnp.exp(dt[:, 0] * (-jnp.exp(A))[None, :])               # (B,H)
        s = state["ssm"]                                              # (B,H,N,P)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0],
                         Bm[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        s = s * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), s)
        y = y[:, None] + xs.astype(jnp.float32) * p["D"][None, None, :, None]
        new_state = {"conv": conv_tail, "ssm": s}

    y = y.reshape(b, t, inner)
    # gated RMSNorm (Mamba2 norm-before-out-proj)
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-5)
    yn = yn * p["norm_w"].astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    out = yn.astype(x.dtype) @ p["out_proj"]
    return out, new_state


def mamba2_state_init(cfg, batch: int, dtype=jnp.float32) -> Dict:
    inner = cfg.ssm_expand * cfg.d_model
    h = inner // MAMBA_HEAD_DIM
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, inner + 2 * cfg.ssm_state),
                          dtype),
        "ssm": jnp.zeros((batch, h, cfg.ssm_state, MAMBA_HEAD_DIM),
                         jnp.float32),
    }


# ===========================================================================
# xLSTM: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar memory)
# ===========================================================================

def mlstm_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    hd = inner // cfg.n_heads
    ks = split_keys(key, 7)
    return {
        "up_l": dense_init(ks[0], d, inner, dtype),      # main branch
        "up_r": dense_init(ks[1], d, inner, dtype),      # gate branch
        "wq": dense_init(ks[2], inner, inner, dtype),
        "wk": dense_init(ks[3], inner, inner, dtype),
        "wv": dense_init(ks[4], inner, inner, dtype),
        "w_if": dense_init(ks[5], inner, 2 * cfg.n_heads, dtype=jnp.float32),
        "b_if": jnp.zeros((2 * cfg.n_heads,), jnp.float32),
        "norm_w": jnp.ones((inner,), dtype),
        "down": dense_init(ks[6], inner, d, dtype),
    }


def _mlstm_parallel(q, k, v, i_gate, f_gate):
    """Stabilized parallel mLSTM over one chunk.

    q/k/v: (B, H, L, hd); i_gate/f_gate: (B, H, L) log-space gates.
    Returns y (B, H, L, hd), plus chunk-final (C, n_vec, m) carries.
    """
    bsz, h, l, hd = q.shape
    logf = jax.nn.log_sigmoid(f_gate)                       # (B,H,L)
    F = jnp.cumsum(logf, axis=-1)                           # prefix sums
    # D[i,j] = F_i - F_j + i_j  for i >= j
    D = F[..., :, None] - F[..., None, :] + i_gate[..., None, :]
    causal = jnp.tril(jnp.ones((l, l), bool))
    D = jnp.where(causal, D, -jnp.inf)
    m = jnp.maximum(jnp.max(D, axis=-1), 0.0)               # stabilizer (B,H,L)
    S = jnp.einsum("bhid,bhjd->bhij", q, k) / math.sqrt(hd)
    W = S * jnp.exp(D - m[..., None])
    n_vec = jnp.maximum(jnp.abs(jnp.sum(W, axis=-1)), jnp.exp(-m))
    y = jnp.einsum("bhij,bhjd->bhid", W, v) / n_vec[..., None]
    return y


def mlstm_apply(p: Params, x: jax.Array, cfg,
                state: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """Pre-up-projected mLSTM block: x (B, T, d)."""
    b, t, d = x.shape
    inner = cfg.ssm_expand * d
    nh = cfg.n_heads
    hd = inner // nh
    xl = x @ p["up_l"]
    xr = jax.nn.silu(x @ p["up_r"])
    q = (xl @ p["wq"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    k = (xl @ p["wk"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    v = (xl @ p["wv"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    gates = xl.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_gate = gates[..., :nh].transpose(0, 2, 1)             # (B,H,T)
    f_gate = gates[..., nh:].transpose(0, 2, 1)

    if state is None:
        # chunkwise: full parallel inside chunks of ssm_chunk
        chunk = min(cfg.ssm_chunk, t)
        assert t % chunk == 0
        nc = t // chunk
        if nc == 1:
            y = _mlstm_parallel(q.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), i_gate, f_gate)
        else:
            # sequential over chunks with recurrent (C, n, m) carry
            qc = q.reshape(b, nh, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
            kc = k.reshape(b, nh, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
            vc = v.reshape(b, nh, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
            ic = i_gate.reshape(b, nh, nc, chunk).transpose(2, 0, 1, 3)
            fc = f_gate.reshape(b, nh, nc, chunk).transpose(2, 0, 1, 3)

            def chunk_step(carry, inp):
                C, nv, mm = carry
                qi, ki, vi, ii, fi = inp
                qi = qi.astype(jnp.float32)
                ki = ki.astype(jnp.float32)
                vi = vi.astype(jnp.float32)
                logf = jax.nn.log_sigmoid(fi)
                F = jnp.cumsum(logf, axis=-1)
                # intra-chunk
                D = F[..., :, None] - F[..., None, :] + ii[..., None, :]
                causal = jnp.tril(jnp.ones((chunk, chunk), bool))
                D = jnp.where(causal, D, -jnp.inf)
                # inter-chunk decay for position i: F_i (+ carry m)
                d_in = F + mm[..., None]
                m_new = jnp.maximum(jnp.max(D, -1), d_in)
                m_new = jnp.maximum(m_new, 0.0)
                qs = qi / math.sqrt(hd)
                S = jnp.einsum("bhid,bhjd->bhij", qs, ki)
                W = S * jnp.exp(D - m_new[..., None])
                h_intra = jnp.einsum("bhij,bhjd->bhid", W, vi)
                l_intra = jnp.sum(W, axis=-1)
                dec = jnp.exp(d_in - m_new)                 # (B,H,L)
                h_inter = jnp.einsum("bhid,bhde,bhi->bhie", qs, C, dec)
                l_inter = jnp.einsum("bhid,bhd,bhi->bhi", qs, nv, dec)
                l_tot = jnp.maximum(jnp.abs(l_intra + l_inter),
                                    jnp.exp(-m_new))
                y = (h_intra + h_inter) / l_tot[..., None]
                # update carry to end of chunk (C is stored exp(-m)-scaled)
                F_last = F[..., -1:]
                m_carry = jnp.maximum(mm + F_last[..., 0],
                                      jnp.max(ii + F_last - F, -1))
                scale_old = jnp.exp(mm + F_last[..., 0] - m_carry)
                add_w = jnp.exp(ii + F_last - F - m_carry[..., None])
                C_new = C * scale_old[..., None, None] + jnp.einsum(
                    "bhj,bhjd,bhje->bhde", add_w, ki, vi)
                nv_new = nv * scale_old[..., None] + jnp.einsum(
                    "bhj,bhjd->bhd", add_w, ki)
                return (C_new, nv_new, m_carry), y

            c0 = (jnp.zeros((b, nh, hd, hd), jnp.float32),
                  jnp.zeros((b, nh, hd), jnp.float32),
                  jnp.full((b, nh), -1e30, jnp.float32))
            _, ys = jax.lax.scan(chunk_step, c0, (qc, kc, vc, ic, fc))
            y = ys.transpose(1, 2, 0, 3, 4).reshape(b, nh, t, hd)
        new_state = None
    else:
        # O(1) decode: C' = f C + i k v^T ; y = q.C / max(|q.n|, e^-m)
        C, nv, mm = state["C"], state["n"], state["m"]
        logf = jax.nn.log_sigmoid(f_gate[..., 0])           # (B,H)
        ii = i_gate[..., 0]
        m_new = jnp.maximum(logf + mm, ii)
        fs = jnp.exp(logf + mm - m_new)
        is_ = jnp.exp(ii - m_new)
        k0 = k[:, :, 0].astype(jnp.float32)
        v0 = v[:, :, 0].astype(jnp.float32)
        q0 = q[:, :, 0].astype(jnp.float32)
        C = C * fs[..., None, None] + is_[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", k0, v0)
        nv = nv * fs[..., None] + is_[..., None] * k0
        num = jnp.einsum("bhd,bhde->bhe", q0 / math.sqrt(hd), C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                             q0 / math.sqrt(hd), nv)),
                          jnp.exp(-m_new))
        y = (num / den[..., None])[:, :, None]              # (B,H,1,hd)
        new_state = {"C": C, "n": nv, "m": m_new}

    y = y.transpose(0, 2, 1, 3).reshape(b, t, inner)
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-5)
    yn = yn.astype(x.dtype) * p["norm_w"]
    return (yn * xr) @ p["down"], new_state


def mlstm_state_init(cfg, batch: int) -> Dict:
    inner = cfg.ssm_expand * cfg.d_model
    hd = inner // cfg.n_heads
    return {
        "C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
    }


def slstm_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    ks = split_keys(key, 3)
    # 4 gates (i, f, z, o), input + recurrent weights
    return {
        "w_x": dense_init(ks[0], d, 4 * d, dtype),
        "w_h": dense_init(ks[1], d, 4 * d, dtype, scale=1.0 / math.sqrt(d)),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm_w": jnp.ones((d,), dtype),
        "down": dense_init(ks[2], d, d, dtype),
    }


def slstm_apply(p: Params, x: jax.Array, cfg,
                state: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """Scalar-memory sLSTM with exponential gating; sequential scan over T
    (the recurrent h-feedback makes it non-parallelizable — by design)."""
    b, t, d = x.shape
    gx = (x @ p["w_x"]).astype(jnp.float32)                 # (B,T,4d)

    if state is None:
        h0 = jnp.zeros((b, d), jnp.float32)
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.ones((b, d), jnp.float32)
        m0 = jnp.zeros((b, d), jnp.float32)
    else:
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]

    w_h = p["w_h"].astype(jnp.float32)
    bias = p["b"]

    def step(carry, gxt):
        h, c, n, m = carry
        g = gxt + h @ w_h + bias
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(gf + m, gi)                     # exp-gate stabilizer
        i = jnp.exp(gi - m_new)
        f = jnp.exp(gf + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(
        step, (h0, c0, n0, m0), gx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2)                               # (B,T,d)
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-5)
    out = (yn.astype(x.dtype) * p["norm_w"]) @ p["down"]
    new_state = None if state is None else {
        "h": h_f, "c": c_f, "n": n_f, "m": m_f}
    return out, new_state


def slstm_state_init(cfg, batch: int) -> Dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones((batch, d), jnp.float32), "m": z}
