from repro.models import common, model, moe, ssm, transformer
from repro.models.model import (
    decode_step, forward, forward_hidden, init_cache, init_params, loss_fn,
)

__all__ = [
    "common", "model", "moe", "ssm", "transformer",
    "decode_step", "forward", "forward_hidden", "init_cache", "init_params",
    "loss_fn",
]
