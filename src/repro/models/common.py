"""Shared building blocks: init helpers, norms, RoPE, attention, MLPs.

Pure-functional (pytree params), scan-friendly, memory-aware:
* attention is computed by scanning over query chunks so that the score
  buffer never exceeds (B, H, q_chunk, S) — the XLA-path analogue of a
  flash kernel, required for the 32k prefill cells to fit HBM.
* every helper takes explicit dtypes so smoke tests run fp32 on CPU while
  production configs run bf16.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# Default query-chunk length for the scanned attention path.  Tuned so the
# per-chunk score buffer stays ~100MB/device at the assigned shape cells.
DEFAULT_Q_CHUNK = 512


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def stacked_init(key, n: int, d_in: int, d_out: int, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (n, d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, head_dim); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (chunked-query XLA path; the flash analogue)
# ---------------------------------------------------------------------------

def _attend_chunk(q, k, v, mask, scale: float):
    """q: (B, Hq, Qc, hd); k/v: (B, Hkv, S, hd); mask: (B, 1, Qc, S) or None.

    GQA keys/values are expanded to the query heads BEFORE the score einsum
    so the O(S^2) score/prob tensors carry the full ``heads`` axis (sharded
    over the model axis).  With the grouped (b, hkv, g, ...) layout a GQA
    model whose kv-head count is below the model-axis size leaves the score
    tensor REPLICATED across model shards — the dominant memory term at 32k
    (measured: 34 GB -> 2.1 GB per score buffer for granite-8b train_4k on
    the 16x16 mesh).
    """
    from repro.distributed.api import constrain
    b, hq, qc, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)          # (B, Hq, S, hd)
        v = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = constrain(scores, "batch", "heads", None, None)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = constrain(probs, "batch", "heads", None, None)
    out = jnp.einsum("bhqs,bhsd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def attention(q, k, v, *, causal: bool, q_positions, kv_positions,
              sliding_window: int = 0, q_chunk: int = DEFAULT_Q_CHUNK,
              save_residuals: bool = False):
    """Chunked multi-(grouped-)head attention with flash-style rematting.

    q: (B, Sq, Hq, hd), k/v: (B, Skv, Hkv, hd).  Returns (B, Sq, Hq, hd).
    q_positions: (Sq,), kv_positions: (Skv,) absolute positions for masking.
    By default the whole attention is wrapped in ``jax.checkpoint`` with
    ``nothing_saveable``: the O(S^2) score/prob tensors are recomputed in the
    backward pass instead of being saved across the layer scan (the XLA-path
    analogue of flash attention's memory behavior).
    """
    impl = partial(_attention_impl, causal=causal,
                   sliding_window=sliding_window, q_chunk=q_chunk)
    if not save_residuals:
        impl = jax.checkpoint(
            impl, policy=jax.checkpoint_policies.nothing_saveable)
    return impl(q, k, v, q_positions, kv_positions)


def _attention_impl(q, k, v, q_positions, kv_positions, *, causal: bool,
                    sliding_window: int = 0, q_chunk: int = DEFAULT_Q_CHUNK):
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qt = q.transpose(0, 2, 1, 3)          # (B, Hq, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    def mask_for(qpos):
        # (Qc, Skv) boolean valid mask
        m = None
        if causal:
            m = qpos[:, None] >= kv_positions[None, :]
        if sliding_window:
            w = qpos[:, None] - kv_positions[None, :] < sliding_window
            m = w if m is None else (m & w)
        return m

    if sq <= q_chunk or sq % q_chunk != 0:
        m = mask_for(q_positions)
        m = None if m is None else m[None, None]
        return _attend_chunk(qt, kt, vt, m, scale).transpose(0, 2, 1, 3)

    n_chunks = sq // q_chunk
    qc = qt.reshape(b, hq, n_chunks, q_chunk, hd).transpose(2, 0, 1, 3, 4)
    pc = q_positions.reshape(n_chunks, q_chunk)

    # checkpoint each chunk so the inner scan's backward re-derives the
    # chunk's scores/probs from (qi, k, v) instead of stacking all chunks'
    # probs as while-loop residuals (8 x 2.1 GB -> transient per chunk)
    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_fn(qi, qpos):
        m = mask_for(qpos)
        m = None if m is None else m[None, None]
        return _attend_chunk(qi, kt, vt, m, scale)

    def body(_, qp):
        qi, qpos = qp
        return None, chunk_fn(qi, qpos)

    _, out = jax.lax.scan(body, None, (qc, pc))
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, hd)
    return out.transpose(0, 2, 1, 3)


def decode_attention(q, k_cache, v_cache, cache_len, *, sliding_window: int = 0):
    """Single-token decode attention against a (B, S_max, Hkv, hd) cache.

    q: (B, 1, Hq, hd); cache_len: scalar int32 (tokens valid in cache).
    """
    b, _, hq, hd = q.shape
    s_max = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    kv_pos = jnp.arange(s_max)
    valid = kv_pos < cache_len
    if sliding_window:
        valid &= kv_pos >= cache_len - sliding_window
    qt = q.transpose(0, 2, 1, 3)
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    m = valid[None, None, None, :]
    out = _attend_chunk(qt, kt, vt, m, scale)
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jax.nn.gelu((x @ w_up) + b_up, approximate=True)
    return (h @ w_down) + b_down


def mlp_apply(p: Params, x, gated: bool):
    if gated:
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return gelu_mlp(x, p["w_up"], p["b_up"], p["w_down"], p["b_down"])


def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype) -> Params:
    ks = split_keys(key, 3)
    if gated:
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


# ---------------------------------------------------------------------------
# Attention params
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cross:
        p["gate"] = jnp.zeros((), dtype)      # llama-vision tanh gate
    return p


def qkv_proj(p: Params, x, cfg):
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(b, s, cfg.n_heads, hd),
            k.reshape(b, s, cfg.n_kv_heads, hd),
            v.reshape(b, s, cfg.n_kv_heads, hd))


def out_proj(p: Params, o):
    b, s, h, hd = o.shape
    return o.reshape(b, s, h * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels):
    """logits: (B, S, V) any float dtype; labels: (B, S) int32.  Mean nats."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)
