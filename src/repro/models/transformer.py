"""Transformer blocks: self/cross-attention decoder blocks, encoder blocks.

Pre-norm residual blocks parameterized entirely by ``ModelConfig`` (GQA via
n_kv_heads, RoPE theta, sliding window, QKV bias, gated vs plain MLP, MoE).
Each block has a training/prefill ``apply`` (full sequence) and a
``decode`` (single token + KV cache) path.

KV caches are per-layer dicts ``{"k": (B, S, Hkv, hd), "v": ...}`` written at
per-request positions (``cache_len`` is a (B,) vector so ragged serving
batches work — each aggregated request owns its slot, as in the paper's
aggregated buffers).  Sliding-window layers use rolling caches of window
size, which is what bounds ``long_500k`` decode memory for SWA archs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.models.common import (
    Params, apply_rope, attention, attn_init, decode_attention, dense_init,
    layernorm, mlp_apply, mlp_init, out_proj, qkv_proj, rmsnorm, split_keys,
)
from repro.models.moe import moe_ffn, moe_init


def _norm(p, x, cfg):
    if isinstance(p, dict):
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p, cfg.norm_eps)


def _norm_init(cfg, dtype):
    if not cfg.mlp_gated:      # GPT-style stacks use LayerNorm
        return {"w": jnp.ones((cfg.d_model,), dtype),
                "b": jnp.zeros((cfg.d_model,), dtype)}
    return jnp.ones((cfg.d_model,), dtype)


# ---------------------------------------------------------------------------
# block init
# ---------------------------------------------------------------------------

def block_init(key, cfg, dtype, kind: str = "self") -> Params:
    """kind: self | cross | encoder | moe."""
    ks = split_keys(key, 3)
    p: Params = {
        "ln1": _norm_init(cfg, dtype),
        "attn": attn_init(ks[0], cfg, dtype, cross=(kind == "cross")),
        "ln2": _norm_init(cfg, dtype),
    }
    if kind == "moe":
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype)
    return p


def decoder_layer_init(key, cfg, dtype) -> Params:
    """Decoder-with-cross-attention layer (enc-dec architectures)."""
    ks = split_keys(key, 2)
    p = block_init(ks[0], cfg, dtype, kind="self")
    p["ln_x"] = _norm_init(cfg, dtype)
    p["xattn"] = attn_init(ks[1], cfg, dtype, cross=True)
    return p


# ---------------------------------------------------------------------------
# full-sequence apply (training / prefill)
# ---------------------------------------------------------------------------

def _ffn(p, x, cfg, use_pallas_moe: bool = False):
    h = _norm(p["ln2"], x, cfg)
    h = constrain(h, "batch", "seq", "embed")
    if "moe" in p:
        out = moe_ffn(p["moe"], h, cfg, use_pallas=use_pallas_moe)
    else:
        out = mlp_apply(p["mlp"], h, cfg.mlp_gated)
    # residual stream between blocks is sequence-sharded (Megatron-SP):
    # XLA reduce-scatters the ffn output and all-gathers at the next block,
    # which shrinks the per-layer saved activations by the model-axis size.
    return constrain(x + constrain(out, "batch", "seq", "embed"),
                     "batch", "seq_sp", "embed")


def self_block_apply(p, x, cfg, positions, *, causal: bool = True,
                     use_rope: bool = True, use_pallas_moe: bool = False):
    h = _norm(p["ln1"], x, cfg)
    q, k, v = qkv_proj(p["attn"], h, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    o = attention(q, k, v, causal=causal, q_positions=positions,
                  kv_positions=positions, sliding_window=cfg.sliding_window)
    x = constrain(x + constrain(out_proj(p["attn"], o),
                                "batch", "seq", "embed"),
                  "batch", "seq_sp", "embed")
    return _ffn(p, x, cfg, use_pallas_moe)


def cross_block_apply(p, x, memory, cfg, *, gated: bool = True,
                      skip_ffn: bool = False):
    """Cross-attention block: queries from x, keys/values from memory."""
    b, s, _ = x.shape
    sm = memory.shape[1]
    h = _norm(p["ln1"], x, cfg)
    hd = cfg.resolved_head_dim
    q = (h @ p["attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (memory @ p["attn"]["wk"]).reshape(b, sm, cfg.n_kv_heads, hd)
    v = (memory @ p["attn"]["wv"]).reshape(b, sm, cfg.n_kv_heads, hd)
    if "bq" in p["attn"]:
        q = q + p["attn"]["bq"].reshape(cfg.n_heads, hd)
        k = k + p["attn"]["bk"].reshape(cfg.n_kv_heads, hd)
        v = v + p["attn"]["bv"].reshape(cfg.n_kv_heads, hd)
    o = attention(q, k, v, causal=False,
                  q_positions=jnp.zeros((s,), jnp.int32),
                  kv_positions=jnp.zeros((sm,), jnp.int32))
    o = out_proj(p["attn"], o)
    if gated and "gate" in p["attn"]:
        o = jnp.tanh(p["attn"]["gate"]).astype(o.dtype) * o
    x = x + o
    if skip_ffn:
        return x
    return _ffn(p, x, cfg)


def encdec_decoder_apply(p, x, memory, cfg, positions):
    """Self-attn + cross-attn + FFN decoder layer (enc-dec)."""
    h = _norm(p["ln1"], x, cfg)
    q, k, v = qkv_proj(p["attn"], h, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, causal=True, q_positions=positions,
                  kv_positions=positions)
    x = x + out_proj(p["attn"], o)
    # cross attention
    b, s, _ = x.shape
    sm = memory.shape[1]
    hd = cfg.resolved_head_dim
    h = _norm(p["ln_x"], x, cfg)
    q = (h @ p["xattn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (memory @ p["xattn"]["wk"]).reshape(b, sm, cfg.n_kv_heads, hd)
    v = (memory @ p["xattn"]["wv"]).reshape(b, sm, cfg.n_kv_heads, hd)
    o = attention(q, k, v, causal=False,
                  q_positions=jnp.zeros((s,), jnp.int32),
                  kv_positions=jnp.zeros((sm,), jnp.int32))
    x = x + out_proj(p["xattn"], o)
    return _ffn(p, x, cfg)


# ---------------------------------------------------------------------------
# decode (single token, KV cache)
# ---------------------------------------------------------------------------

def kv_cache_init(cfg, batch: int, max_len: int, dtype) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype),
    }


def _cache_write(cache, k_new, v_new, cache_len, sliding_window: int):
    """Write one token per request at its own position (rolling for SWA)."""
    b = k_new.shape[0]
    s = cache["k"].shape[1]
    pos = cache_len % s if sliding_window else jnp.minimum(cache_len, s - 1)
    k = cache["k"].at[jnp.arange(b), pos].set(k_new[:, 0])
    v = cache["v"].at[jnp.arange(b), pos].set(v_new[:, 0])
    return {"k": k, "v": v}


def self_block_decode(p, x, cfg, cache, cache_len, *, use_rope: bool = True,
                      use_pallas_attn: bool = False):
    """x: (B, 1, d); cache_len: (B,) tokens already in cache."""
    b = x.shape[0]
    h = _norm(p["ln1"], x, cfg)
    q, k, v = qkv_proj(p["attn"], h, cfg)
    if use_rope:
        pos = cache_len[:, None]                      # (B, 1) absolute
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    cache = _cache_write(cache, k, v, cache_len, cfg.sliding_window)
    s = cache["k"].shape[1]
    if cfg.sliding_window:
        # rolling cache: all written slots are valid
        valid_len = jnp.minimum(cache_len + 1, s)
    else:
        valid_len = cache_len + 1
    if use_pallas_attn:
        from repro.kernels.ops import decode_attention as da
        o = da(q[:, 0], cache["k"], cache["v"], valid_len)[:, None]
    else:
        from repro.kernels.ref import decode_attention_ref
        o = decode_attention_ref(q[:, 0], cache["k"], cache["v"],
                                 valid_len)[:, None]
    x = x + out_proj(p["attn"], o)
    h = _norm(p["ln2"], x, cfg)
    if "moe" in p:
        out = moe_ffn(p["moe"], h, cfg)
    else:
        out = mlp_apply(p["mlp"], h, cfg.mlp_gated)
    return x + out, cache


def cross_block_decode(p, x, cfg, cross_kv, *, gated: bool = True,
                       skip_ffn: bool = False):
    """Decode against precomputed (fixed) cross-attention KV."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h = _norm(p["ln1"], x, cfg)
    q = (h @ p["attn"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
    sm = cross_kv["k"].shape[1]
    full = jnp.full((b,), sm, jnp.int32)
    from repro.kernels.ref import decode_attention_ref
    o = decode_attention_ref(q[:, 0], cross_kv["k"], cross_kv["v"], full)[:, None]
    o = out_proj(p["attn"], o)
    if gated and "gate" in p["attn"]:
        o = jnp.tanh(p["attn"]["gate"]).astype(o.dtype) * o
    x = x + o
    if skip_ffn:
        return x
    h = _norm(p["ln2"], x, cfg)
    if "moe" in p:
        out = moe_ffn(p["moe"], h, cfg)
    else:
        out = mlp_apply(p["mlp"], h, cfg.mlp_gated)
    return x + out


def cross_kv_precompute(p, memory, cfg) -> Dict[str, jax.Array]:
    b, sm, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = (memory @ p["attn"]["wk"]).reshape(b, sm, cfg.n_kv_heads, hd)
    v = (memory @ p["attn"]["wv"]).reshape(b, sm, cfg.n_kv_heads, hd)
    return {"k": k, "v": v}


def encdec_decoder_decode(p, x, cfg, cache, cache_len, cross_kv):
    b = x.shape[0]
    h = _norm(p["ln1"], x, cfg)
    q, k, v = qkv_proj(p["attn"], h, cfg)
    pos = cache_len[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    cache = _cache_write(cache, k, v, cache_len, 0)
    from repro.kernels.ref import decode_attention_ref
    o = decode_attention_ref(q[:, 0], cache["k"], cache["v"],
                             cache_len + 1)[:, None]
    x = x + out_proj(p["attn"], o)
    # cross
    h = _norm(p["ln_x"], x, cfg)
    hd = cfg.resolved_head_dim
    q = (h @ p["xattn"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
    sm = cross_kv["k"].shape[1]
    o = decode_attention_ref(q[:, 0], cross_kv["k"], cross_kv["v"],
                             jnp.full((b,), sm, jnp.int32))[:, None]
    x = x + out_proj(p["xattn"], o)
    h = _norm(p["ln2"], x, cfg)
    out = mlp_apply(p["mlp"], h, cfg.mlp_gated)
    return x + out, cache


def xattn_kv_precompute(p, memory, cfg) -> Dict[str, jax.Array]:
    b, sm, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = (memory @ p["xattn"]["wk"]).reshape(b, sm, cfg.n_kv_heads, hd)
    v = (memory @ p["xattn"]["wv"]).reshape(b, sm, cfg.n_kv_heads, hd)
    return {"k": k, "v": v}
