"""TVD-RK3 time stepping over the sub-grid decomposition.

One time-step = three hydro-solver iterations (paper §VI-A: "each time-step
including three iterations"), each iteration being a ghost exchange followed
by per-sub-grid Reconstruct + Flux (the paper's two dominant kernels) and the
conserved-variable update.  ``courant_dt`` implements the Courant condition
(paper §IV-B).

``subgrid_rhs`` is THE task body: one fine-grained unit of work, sized for
one CPU core in Octo-Tiger's original design.  Every aggregation strategy in
``repro.core`` re-granularizes launches of this body (or of its Pallas
twin in ``repro.kernels``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import HydroConfig
from repro.hydro.euler import max_signal_speed
from repro.hydro.flux import flux_divergence
from repro.hydro.ppm import ppm_reconstruct_all
from repro.hydro.state import HydroState, assemble_global, extract_subgrids


def subgrid_rhs(u_padded, h: float, gamma: float, ghost: int, subgrid: int):
    """One task: PPM reconstruct + central-upwind flux on one padded sub-grid.

    u_padded: (F, P, P, P) -> dU/dt over the interior (F, S, S, S).
    """
    recon = ppm_reconstruct_all(u_padded)
    return flux_divergence(recon, h, gamma, ghost, subgrid)


def _rhs_global(u, cfg: HydroConfig, h: float, bc: str):
    subs = extract_subgrids(u, cfg.subgrid, cfg.ghost, bc)
    body = partial(subgrid_rhs, h=h, gamma=cfg.gamma,
                   ghost=cfg.ghost, subgrid=cfg.subgrid)
    dudt = jax.vmap(body)(subs)
    return assemble_global(dudt, cfg.subgrid)


def _rk3_body(u, dt, cfg: HydroConfig, bc: str):
    h = cfg.domain / u.shape[-1]
    l0 = _rhs_global(u, cfg, h, bc)
    u1 = u + dt * l0
    l1 = _rhs_global(u1, cfg, h, bc)
    u2 = 0.75 * u + 0.25 * (u1 + dt * l1)
    l2 = _rhs_global(u2, cfg, h, bc)
    return (1.0 / 3.0) * u + (2.0 / 3.0) * (u2 + dt * l2)


@partial(jax.jit, static_argnames=("cfg", "bc"))
def rk3_step(u, dt, cfg: HydroConfig, bc: str = "outflow"):
    """Shu-Osher TVD-RK3: three iterations of the hydro solver."""
    return _rk3_body(u, dt, cfg, bc)


@partial(jax.jit, static_argnames=("cfg", "n_steps", "bc"),
         donate_argnums=(0,))
def rk3_trajectory(u, dt, cfg: HydroConfig, n_steps: int,
                   bc: str = "outflow"):
    """``n_steps`` RK3 steps as ONE ``lax.scan`` program (fixed dt).

    The whole trajectory dispatches once; the state buffer is donated so
    XLA aliases the scan carry in place.  NOTE: donation invalidates the
    caller's ``u`` — pass a copy if the input must survive.  This is the
    fused-strategy upper bound extended over time (Table III's last row);
    ``run`` keeps the per-step loop because it recomputes the Courant dt
    between steps.
    """
    def body(v, _):
        return _rk3_body(v, dt, cfg, bc), None

    out, _ = jax.lax.scan(body, u, None, length=n_steps)
    return out


@partial(jax.jit, static_argnames=("cfg",))
def courant_dt(u, cfg: HydroConfig):
    h = cfg.domain / u.shape[-1]
    return cfg.cfl * h / max_signal_speed(u, cfg.gamma)


@jax.jit
def total_conserved(u, h):
    """(mass, Sx, Sy, Sz, E) integrals — conservation invariants."""
    return jnp.sum(u, axis=(1, 2, 3)) * h ** 3


def run(state: HydroState, cfg: HydroConfig, n_steps: int,
        bc: str = "outflow") -> HydroState:
    u, t = state.u, state.t
    for k in range(n_steps):
        dt = courant_dt(u, cfg)
        u = rk3_step(u, dt, cfg, bc)
        t = t + float(dt)
    return HydroState(u=u, t=t, step=state.step + n_steps)


def shock_radius(u, cfg: HydroConfig):
    """Radius of the density peak — the Sedov shock front location."""
    n = u.shape[-1]
    h = cfg.domain / n
    x = (jnp.arange(n) + 0.5) * h - 0.5 * cfg.domain
    X, Y, Z = jnp.meshgrid(x, x, x, indexing="ij")
    r = jnp.sqrt(X * X + Y * Y + Z * Z)
    rho = u[0]
    # mass-weighted radius of the over-dense shell
    w = jnp.maximum(rho - cfg.rho0, 0.0)
    return jnp.sum(w * r) / jnp.maximum(jnp.sum(w), 1e-30)
