"""TVD-RK3 time stepping over the sub-grid decomposition.

One time-step = three hydro-solver iterations (paper §VI-A: "each time-step
including three iterations"), each iteration being a ghost exchange followed
by per-sub-grid Reconstruct + Flux (the paper's two dominant kernels) and the
conserved-variable update.  ``courant_dt`` implements the Courant condition
(paper §IV-B).

``subgrid_rhs`` is THE task body: one fine-grained unit of work, sized for
one CPU core in Octo-Tiger's original design.  Every aggregation strategy in
``repro.core`` re-granularizes launches of this body (or of its Pallas
twin in ``repro.kernels``).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.configs.base import AMRHydroConfig, HydroConfig
from repro.hydro.euler import max_signal_speed
from repro.hydro.flux import flux_divergence
from repro.hydro.ppm import ppm_reconstruct_all
from repro.hydro.state import (
    AMRState, HydroState, assemble_global, extract_subgrids,
    extract_subgrids_multilevel, sync_coarse,
)


def subgrid_rhs(u_padded, h, gamma: float, ghost: int, subgrid: int):
    """One task: PPM reconstruct + central-upwind flux on one padded sub-grid.

    u_padded: (F, P, P, P) -> dU/dt over the interior (F, S, S, S).
    ``h`` may be a python float (baked at trace time) or a traced scalar —
    the multi-level runners pass it as a per-task argument so ONE compiled
    bucket serves every refinement level whose sub-grid shapes agree.
    """
    recon = ppm_reconstruct_all(u_padded)
    return flux_divergence(recon, h, gamma, ghost, subgrid)


@lru_cache(maxsize=None)
def level_batched_body(gamma: float, ghost: int, subgrid: int):
    """The shape-polymorphic aggregation-region body for one sub-grid size:
    ``(k, F, P, P, P), (k,) -> (k, F, S, S, S)`` with per-task traced h.
    Cached so every runner / reference sharing (gamma, ghost, subgrid) gets
    the SAME callable — and therefore the same compiled programs."""
    def body(u_padded, h):
        return subgrid_rhs(u_padded, h, gamma=gamma, ghost=ghost,
                           subgrid=subgrid)
    return jax.vmap(body)


@lru_cache(maxsize=None)
def level_batched_jit(gamma: float, ghost: int, subgrid: int):
    """Jitted twin of :func:`level_batched_body` (per-level fused launch)."""
    return jax.jit(level_batched_body(gamma, ghost, subgrid))


def rk_stage_epilogue(dudt, v_int, u0_int, c0, c1, dt):
    """The per-slot RK-stage epilogue (DESIGN.md §9): one Shu-Osher stage
    update over a task's interior, ``out = c0*u0 + c1*(v + dt*dudt)``
    (stage 1 is ``c0=0, c1=1``; stages 2/3 are ``0.75,0.25`` / ``1/3,2/3``).

    Declared on :class:`~repro.core.scenario.KernelFamily` so the epilogue
    traces *into* the bucketed aggregation program: gather -> Reconstruct+
    Flux -> stage axpy compile to ONE XLA program per bucket, and a time
    step becomes three launches instead of three launches plus global
    combine traffic.  Coefficients arrive as per-task traced scalars, so a
    single compiled bucket serves all three stages.  Every hydro-family
    scenario shares THIS epilogue — uniform Sedov, the per-level AMR
    twins (traced ``h`` rides through the fused body untouched) and the
    gravity scenario's hydro family (DESIGN.md §10).
    """
    return c0 * u0_int + c1 * (v_int + dt * dudt)


def stage_coeff_vectors(cache: dict, dt, c0: float, c1: float, n: int,
                        dtype):
    """Per-task ``(c0, c1, dt)`` coefficient vectors for one epilogue-fused
    RK stage, cached per ``(c0, c1, n)`` and rebuilt only when the ``dt``
    object changes: fixed-dt drivers re-hit three cached broadcasts per
    stage instead of dispatching three ``jnp.full``.  Shared by every
    scenario implementing ``stage_populations`` (the caller owns the
    cache dict, one per scenario instance)."""
    key = (c0, c1, n)
    hit = cache.get(key)
    if hit is None or hit[0] is not dt:
        hit = (dt, tuple(jnp.full((n,), c, dtype) for c in (c0, c1, dt)))
        cache[key] = hit
    return hit[1]


def _rhs_global(u, cfg: HydroConfig, h: float, bc: str):
    subs = extract_subgrids(u, cfg.subgrid, cfg.ghost, bc)
    body = partial(subgrid_rhs, h=h, gamma=cfg.gamma,
                   ghost=cfg.ghost, subgrid=cfg.subgrid)
    dudt = jax.vmap(body)(subs)
    return assemble_global(dudt, cfg.subgrid)


def _rk3_body(u, dt, cfg: HydroConfig, bc: str):
    h = cfg.domain / u.shape[-1]
    l0 = _rhs_global(u, cfg, h, bc)
    u1 = u + dt * l0
    l1 = _rhs_global(u1, cfg, h, bc)
    u2 = 0.75 * u + 0.25 * (u1 + dt * l1)
    l2 = _rhs_global(u2, cfg, h, bc)
    return (1.0 / 3.0) * u + (2.0 / 3.0) * (u2 + dt * l2)


@partial(jax.jit, static_argnames=("cfg", "bc"))
def rk3_step(u, dt, cfg: HydroConfig, bc: str = "outflow"):
    """Shu-Osher TVD-RK3: three iterations of the hydro solver."""
    return _rk3_body(u, dt, cfg, bc)


@partial(jax.jit, static_argnames=("cfg", "n_steps", "bc"),
         donate_argnums=(0,))
def rk3_trajectory(u, dt, cfg: HydroConfig, n_steps: int,
                   bc: str = "outflow"):
    """``n_steps`` RK3 steps as ONE ``lax.scan`` program (fixed dt).

    The whole trajectory dispatches once; the state buffer is donated so
    XLA aliases the scan carry in place.  NOTE: donation invalidates the
    caller's ``u`` — pass a copy if the input must survive.  This is the
    fused-strategy upper bound extended over time (Table III's last row);
    ``run`` keeps the per-step loop because it recomputes the Courant dt
    between steps.
    """
    def body(v, _):
        return _rk3_body(v, dt, cfg, bc), None

    out, _ = jax.lax.scan(body, u, None, length=n_steps)
    return out


@partial(jax.jit, static_argnames=("cfg",))
def courant_dt(u, cfg: HydroConfig):
    h = cfg.domain / u.shape[-1]
    return cfg.cfl * h / max_signal_speed(u, cfg.gamma)


@jax.jit
def total_conserved(u, h):
    """(mass, Sx, Sy, Sz, E) integrals — conservation invariants."""
    return jnp.sum(u, axis=(1, 2, 3)) * h ** 3


def run(state: HydroState, cfg: HydroConfig, n_steps: int,
        bc: str = "outflow") -> HydroState:
    u, t = state.u, state.t
    for k in range(n_steps):
        dt = courant_dt(u, cfg)
        u = rk3_step(u, dt, cfg, bc)
        t = t + float(dt)
    return HydroState(u=u, t=t, step=state.step + n_steps)


# ---------------------------------------------------------------------------
# Two-level AMR stepping
# ---------------------------------------------------------------------------

def amr_rk3_step(rhs_fn, uc, uf, dt, cfg: AMRHydroConfig):
    """TVD-RK3 over both levels in lockstep (shared dt).

    ``rhs_fn(uc, uf) -> (duc, duf)`` is a strategy runner's rhs or the
    reference below; the combine arithmetic here is the single shared code
    path, so runner-vs-reference equivalence reduces to rhs equivalence.
    The covered coarse cells are re-synced from the fine solution at the
    end of the step.
    """
    dc0, df0 = rhs_fn(uc, uf)
    uc1, uf1 = uc + dt * dc0, uf + dt * df0
    dc1, df1 = rhs_fn(uc1, uf1)
    uc2 = 0.75 * uc + 0.25 * (uc1 + dt * dc1)
    uf2 = 0.75 * uf + 0.25 * (uf1 + dt * df1)
    dc2, df2 = rhs_fn(uc2, uf2)
    uc_new = (1.0 / 3.0) * uc + (2.0 / 3.0) * (uc2 + dt * dc2)
    uf_new = (1.0 / 3.0) * uf + (2.0 / 3.0) * (uf2 + dt * df2)
    return sync_coarse(uc_new, uf_new, cfg), uf_new


def amr_reference_rhs(uc, uf, cfg: AMRHydroConfig, bc: str = "outflow"):
    """Per-level FUSED reference: each level's whole task batch as one
    vmapped launch with per-task traced h.  The equivalence oracle every
    aggregation strategy must match bit-identically."""
    subs_c, subs_f = extract_subgrids_multilevel(uc, uf, cfg, bc)
    dtype = subs_c.dtype
    hc = jnp.full((subs_c.shape[0],), cfg.h_coarse, dtype)
    hf = jnp.full((subs_f.shape[0],), cfg.h_fine, dtype)
    duc = level_batched_jit(cfg.gamma, cfg.ghost, cfg.coarse_subgrid)(
        subs_c, hc)
    duf = level_batched_jit(cfg.gamma, cfg.ghost, cfg.fine_subgrid)(
        subs_f, hf)
    return (assemble_global(duc, cfg.coarse_subgrid),
            assemble_global(duf, cfg.fine_subgrid))


def amr_reference_step(uc, uf, dt, cfg: AMRHydroConfig,
                       bc: str = "outflow"):
    """One RK3 step of the per-level fused reference."""
    return amr_rk3_step(lambda a, b: amr_reference_rhs(a, b, cfg, bc),
                        uc, uf, dt, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def amr_courant_dt(uc, uf, cfg: AMRHydroConfig):
    """Shared two-level Courant dt (the fine level is the binding one)."""
    return cfg.cfl * jnp.minimum(
        cfg.h_coarse / max_signal_speed(uc, cfg.gamma),
        cfg.h_fine / max_signal_speed(uf, cfg.gamma))


def amr_run(state: AMRState, cfg: AMRHydroConfig, n_steps: int,
            bc: str = "outflow") -> AMRState:
    uc, uf, t = state.uc, state.uf, state.t
    for _ in range(n_steps):
        dt = amr_courant_dt(uc, uf, cfg)
        uc, uf = amr_reference_step(uc, uf, dt, cfg, bc)
        t = t + float(dt)
    return AMRState(uc=uc, uf=uf, t=t, step=state.step + n_steps)


def shock_radius(u, cfg: HydroConfig):
    """Radius of the density peak — the Sedov shock front location."""
    n = u.shape[-1]
    h = cfg.domain / n
    x = (jnp.arange(n) + 0.5) * h - 0.5 * cfg.domain
    X, Y, Z = jnp.meshgrid(x, x, x, indexing="ij")
    r = jnp.sqrt(X * X + Y * Y + Z * Z)
    rho = u[0]
    # mass-weighted radius of the over-dense shell
    w = jnp.maximum(rho - cfg.rho0, 0.0)
    return jnp.sum(w * r) / jnp.maximum(jnp.sum(w), 1e-30)
