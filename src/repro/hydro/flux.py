"""Central-upwind fluxes at 9 quadrature points per face + Simpson quadrature.

For each face (axis a, between cells i and i+e_a) Octo-Tiger evaluates the
flux at the 3x3 quadrature points (face center, 4 edge midpoints, 4 vertices)
using the central-upwind scheme of Kurganov et al. (paper ref [40]) and
integrates with Newton-Cotes (Simpson) weights (1,4,1)x(1,4,1)/36.

The left state at quadrature point ``(+e_a, t)`` of cell ``i`` is the PPM
surface value of cell ``i`` toward ``d = e_a + t``; the right state is the
surface value of cell ``i+e_a`` toward ``-d' = -(e_a - t)``, since the same
physical point is reached from the neighbor with the transverse offset
preserved and the axis component flipped.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.hydro.euler import cons_to_prim, euler_flux, sound_speed
from repro.hydro.ppm import PAIR_INDEX, _shift

# (weight, transverse offset) for the 3-point Simpson rule
_W1D = {-1: 1.0 / 6.0, 0: 4.0 / 6.0, 1: 1.0 / 6.0}

# FACE_QUAD[axis] = list of (weight, d_canonical, take_plus_side_L,
#                            d'_canonical, take_plus_side_R)
# where the L value is pair[d][1 if plus else 0] of cell i, and the R value is
# pair[d'][...] of cell i+e_a.
FACE_QUAD = {}


def _canon(d: Tuple[int, int, int]):
    """Canonical pair representative and whether d is the + member."""
    for c in d:
        if c != 0:
            return (d, True) if c > 0 else (tuple(-x for x in d), False)
    raise ValueError(d)


def _build_face_quad():
    axes = [(1, 0, 0), (0, 1, 0), (0, 0, 1)]
    for a, e in enumerate(axes):
        entries = []
        for t1 in (-1, 0, 1):
            for t2 in (-1, 0, 1):
                # transverse offset in the two non-axis dims
                t = [0, 0, 0]
                dims = [i for i in range(3) if i != a]
                t[dims[0]], t[dims[1]] = t1, t2
                dL = tuple(e[i] + t[i] for i in range(3))
                dR = tuple(-e[i] + t[i] for i in range(3))
                cL, plusL = _canon(dL)
                cR, plusR = _canon(dR)
                w = _W1D[t1] * _W1D[t2]
                entries.append((w, PAIR_INDEX[cL], int(plusL),
                                PAIR_INDEX[cR], int(plusR)))
        FACE_QUAD[a] = entries


_build_face_quad()


def central_upwind(uL, uR, axis: int, gamma: float):
    """Kurganov-Noelle-Petrova central-upwind flux.  u*: (F, ...)."""
    rhoL, vxL, vyL, vzL, pL = cons_to_prim(uL, gamma)
    rhoR, vxR, vyR, vzR, pR = cons_to_prim(uR, gamma)
    vL = (vxL, vyL, vzL)[axis]
    vR = (vxR, vyR, vzR)[axis]
    cL = sound_speed(rhoL, pL, gamma)
    cR = sound_speed(rhoR, pR, gamma)
    ap = jnp.maximum(jnp.maximum(vL + cL, vR + cR), 0.0)
    am = jnp.minimum(jnp.minimum(vL - cL, vR - cR), 0.0)
    fL = euler_flux(uL, axis, gamma)
    fR = euler_flux(uR, axis, gamma)
    span = ap - am
    # guard the degenerate (vacuum-like) case
    inv = jnp.where(span > 1e-12, 1.0 / jnp.maximum(span, 1e-12), 0.0)
    flux = (ap * fL - am * fR) * inv + (ap * am) * inv * (uR - uL)
    return jnp.where(span > 1e-12, flux, 0.5 * (fL + fR))


def face_flux(recon, axis: int, gamma: float):
    """Simpson-integrated face flux at face i+1/2 along `axis`, for all cells.

    recon: (N_PAIRS, 2, F, X, Y, Z) PPM output (``ppm_reconstruct_all``).
    Returns (F, X, Y, Z): flux through the +axis face of cell i.
    """
    e = [(1, 0, 0), (0, 1, 0), (0, 0, 1)][axis]
    total = None
    for (w, pL, sL, pR, sR) in FACE_QUAD[axis]:
        uL = recon[pL, sL]
        uR = _shift(recon[pR, sR], e, 1)  # value of cell i+e_a
        f = central_upwind(uL, uR, axis, gamma)
        total = w * f if total is None else total + w * f
    return total


def flux_divergence(recon, h: float, gamma: float, ghost: int, subgrid: int):
    """-div(F) over the interior of one padded sub-grid.

    recon: (N_PAIRS, 2, F, P, P, P).  Returns dU/dt: (F, S, S, S).
    """
    g, s = ghost, subgrid
    out = None
    for axis in range(3):
        fp = face_flux(recon, axis, gamma)             # flux at +face of cell i
        lo = [g, g, g]
        hi = [g + s, g + s, g + s]
        # F_{i+1/2} for interior cells
        f_hi = fp[:, lo[0]:hi[0], lo[1]:hi[1], lo[2]:hi[2]]
        # F_{i-1/2} = +face flux of cell i-e_a
        lo[axis] -= 1
        hi[axis] -= 1
        f_lo = fp[:, lo[0]:hi[0], lo[1]:hi[1], lo[2]:hi[2]]
        d = (f_hi - f_lo) / h
        out = -d if out is None else out - d
    return out
