"""Global grid <-> sub-grid decomposition, Sedov IC, ghost-cell exchange.

With AMR off (the paper's benchmark configuration) the octree leaves form a
uniform ``G^3`` array of ``S^3`` sub-grids.  The per-sub-grid view
``(n_subgrids, F, P, P, P)`` with ``P = S + 2*ghost`` is the unit of work for
the aggregation strategies; ``assemble_global``/``extract_subgrids`` convert
between it and the assembled ``(F, N, N, N)`` grid.  The extract is the
ghost-exchange: in the distributed runtime it lowers to halo collectives, on
one device it is a pad + gather.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HydroConfig
from repro.hydro.euler import N_FIELDS, prim_to_cons


@dataclass
class HydroState:
    u: jax.Array          # (F, N, N, N) conserved, assembled global grid
    t: float
    step: int


def grid_coords(cfg: HydroConfig):
    n = cfg.grids_per_edge * cfg.subgrid
    h = cfg.domain / n
    x = (jnp.arange(n) + 0.5) * h - 0.5 * cfg.domain
    return jnp.meshgrid(x, x, x, indexing="ij"), h


def sedov_init(cfg: HydroConfig, dtype=jnp.float32) -> HydroState:
    """Sedov-Taylor blast wave: cold uniform medium, energy E dumped into a
    small sphere around the origin (paper ref [43])."""
    (X, Y, Z), h = grid_coords(cfg)
    r = jnp.sqrt(X * X + Y * Y + Z * Z)
    r0 = 3.5 * h
    in_blast = r < r0
    n_blast = jnp.maximum(jnp.sum(in_blast), 1)
    cell_vol = h ** 3
    # deposit E uniformly over the blast cells as internal energy
    e_dens = cfg.blast_energy / (n_blast * cell_vol)
    p_blast = (cfg.gamma - 1.0) * e_dens
    p_ambient = 1e-8
    rho = jnp.full(r.shape, cfg.rho0)
    p = jnp.where(in_blast, p_blast, p_ambient)
    zeros = jnp.zeros_like(rho)
    u = prim_to_cons(rho, zeros, zeros, zeros, p, cfg.gamma).astype(dtype)
    return HydroState(u=u, t=0.0, step=0)


# ---------------------------------------------------------------------------
# decomposition
# ---------------------------------------------------------------------------

def fill_ghosts(u, ghost: int, bc: str = "outflow"):
    """(F, N, N, N) -> (F, N+2g, N+2g, N+2g) with boundary condition."""
    g = ghost
    pads = [(0, 0), (g, g), (g, g), (g, g)]
    if bc == "periodic":
        return jnp.pad(u, pads, mode="wrap")
    return jnp.pad(u, pads, mode="edge")


@partial(jax.jit, static_argnames=("subgrid", "ghost", "bc"))
def extract_subgrids(u, subgrid: int, ghost: int, bc: str = "outflow"):
    """Assembled (F, N, N, N) -> per-task (G^3, F, P, P, P) padded sub-grids."""
    n = u.shape[-1]
    s, g = subgrid, ghost
    grids = n // s
    up = fill_ghosts(u, g, bc)

    idx = jnp.arange(grids) * s
    starts = jnp.stack(jnp.meshgrid(idx, idx, idx, indexing="ij"),
                       axis=-1).reshape(-1, 3)

    def one(st):
        return jax.lax.dynamic_slice(
            up, (0, st[0], st[1], st[2]),
            (u.shape[0], s + 2 * g, s + 2 * g, s + 2 * g))

    return jax.vmap(one)(starts)


@partial(jax.jit, static_argnames=("subgrid",))
def assemble_global(sub_interior, subgrid: int):
    """Per-task interiors (G^3, F, S, S, S) -> assembled (F, N, N, N)."""
    nsub, f, s = sub_interior.shape[0], sub_interior.shape[1], subgrid
    grids = round(nsub ** (1.0 / 3.0))
    x = sub_interior.reshape(grids, grids, grids, f, s, s, s)
    x = x.transpose(3, 0, 4, 1, 5, 2, 6)
    return x.reshape(f, grids * s, grids * s, grids * s)


def subgrid_starts(cfg: HydroConfig):
    idx = jnp.arange(cfg.grids_per_edge) * cfg.subgrid
    return jnp.stack(jnp.meshgrid(idx, idx, idx, indexing="ij"),
                     axis=-1).reshape(-1, 3)
