"""Global grid <-> sub-grid decomposition, Sedov IC, ghost-cell exchange.

With AMR off (the paper's benchmark configuration) the octree leaves form a
uniform ``G^3`` array of ``S^3`` sub-grids.  The per-sub-grid view
``(n_subgrids, F, P, P, P)`` with ``P = S + 2*ghost`` is the unit of work for
the aggregation strategies; ``assemble_global``/``extract_subgrids`` convert
between it and the assembled ``(F, N, N, N)`` grid.  The extract is the
ghost-exchange: in the distributed runtime it lowers to halo collectives, on
one device it is a pad + gather.

The two-level AMR section (DESIGN.md §7) adds a centred fine patch at
``refine_ratio`` x resolution: ``extract_subgrids_multilevel`` performs the
coarse-fine exchange (block-mean restriction onto the covered coarse cells,
piecewise-constant prolongation into the fine ghost band) and decomposes
BOTH levels into their per-task views — the mixed task population the
multi-region aggregation runtime serves.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AMRHydroConfig, HydroConfig
from repro.hydro.euler import N_FIELDS, prim_to_cons


@dataclass
class HydroState:
    u: jax.Array          # (F, N, N, N) conserved, assembled global grid
    t: float
    step: int


def grid_coords(cfg: HydroConfig):
    n = cfg.grids_per_edge * cfg.subgrid
    h = cfg.domain / n
    x = (jnp.arange(n) + 0.5) * h - 0.5 * cfg.domain
    return jnp.meshgrid(x, x, x, indexing="ij"), h


def sedov_init(cfg: HydroConfig, dtype=jnp.float32) -> HydroState:
    """Sedov-Taylor blast wave: cold uniform medium, energy E dumped into a
    small sphere around the origin (paper ref [43])."""
    (X, Y, Z), h = grid_coords(cfg)
    r = jnp.sqrt(X * X + Y * Y + Z * Z)
    r0 = 3.5 * h
    in_blast = r < r0
    n_blast = jnp.maximum(jnp.sum(in_blast), 1)
    cell_vol = h ** 3
    # deposit E uniformly over the blast cells as internal energy
    e_dens = cfg.blast_energy / (n_blast * cell_vol)
    p_blast = (cfg.gamma - 1.0) * e_dens
    p_ambient = 1e-8
    rho = jnp.full(r.shape, cfg.rho0)
    p = jnp.where(in_blast, p_blast, p_ambient)
    zeros = jnp.zeros_like(rho)
    u = prim_to_cons(rho, zeros, zeros, zeros, p, cfg.gamma).astype(dtype)
    return HydroState(u=u, t=0.0, step=0)


# ---------------------------------------------------------------------------
# decomposition
# ---------------------------------------------------------------------------

def fill_ghosts(u, ghost: int, bc: str = "outflow"):
    """(F, N, N, N) -> (F, N+2g, N+2g, N+2g) with boundary condition."""
    g = ghost
    pads = [(0, 0), (g, g), (g, g), (g, g)]
    if bc == "periodic":
        return jnp.pad(u, pads, mode="wrap")
    return jnp.pad(u, pads, mode="edge")


def _extract_padded(up, n_interior: int, subgrid: int, ghost: int):
    """Already-padded (F, N+2g, ...) -> per-task (G^3, F, P, P, P) views."""
    s, g = subgrid, ghost
    grids = n_interior // s

    idx = jnp.arange(grids) * s
    starts = jnp.stack(jnp.meshgrid(idx, idx, idx, indexing="ij"),
                       axis=-1).reshape(-1, 3)

    def one(st):
        return jax.lax.dynamic_slice(
            up, (0, st[0], st[1], st[2]),
            (up.shape[0], s + 2 * g, s + 2 * g, s + 2 * g))

    return jax.vmap(one)(starts)


@partial(jax.jit, static_argnames=("subgrid", "ghost", "bc"))
def extract_subgrids(u, subgrid: int, ghost: int, bc: str = "outflow"):
    """Assembled (F, N, N, N) -> per-task (G^3, F, P, P, P) padded sub-grids."""
    return _extract_padded(fill_ghosts(u, ghost, bc), u.shape[-1],
                           subgrid, ghost)


@partial(jax.jit, static_argnames=("subgrid",))
def assemble_global(sub_interior, subgrid: int):
    """Per-task interiors (G^3, F, S, S, S) -> assembled (F, N, N, N)."""
    nsub, f, s = sub_interior.shape[0], sub_interior.shape[1], subgrid
    grids = round(nsub ** (1.0 / 3.0))
    x = sub_interior.reshape(grids, grids, grids, f, s, s, s)
    x = x.transpose(3, 0, 4, 1, 5, 2, 6)
    return x.reshape(f, grids * s, grids * s, grids * s)


def subgrid_starts(cfg: HydroConfig):
    idx = jnp.arange(cfg.grids_per_edge) * cfg.subgrid
    return jnp.stack(jnp.meshgrid(idx, idx, idx, indexing="ij"),
                     axis=-1).reshape(-1, 3)


# ---------------------------------------------------------------------------
# Two-level AMR: coarse grid + one centred fine patch (refine_ratio x)
# ---------------------------------------------------------------------------

@dataclass
class AMRState:
    """Two-level refined state: assembled per-level conserved grids."""
    uc: jax.Array         # (F, Nc, Nc, Nc) coarse level, whole domain
    uf: jax.Array         # (F, Nf, Nf, Nf) fine level, centred patch
    t: float
    step: int


def restrict_fine(uf, ratio: int = 2):
    """Fine -> coarse: average each ratio^3 block (conservative for equal
    cell volumes within a block)."""
    f, n = uf.shape[0], uf.shape[-1]
    m = n // ratio
    x = uf.reshape(f, m, ratio, m, ratio, m, ratio)
    return x.mean(axis=(2, 4, 6))


def prolong_coarse(uc, ratio: int = 2):
    """Coarse -> fine: piecewise-constant injection (each coarse cell fills
    its ratio^3 children)."""
    for axis in (1, 2, 3):
        uc = jnp.repeat(uc, ratio, axis=axis)
    return uc


def _sync_coarse(uc, uf, cfg: AMRHydroConfig):
    """Overwrite the covered coarse cells with the restricted fine solution
    (the coarse level never free-runs under the patch)."""
    o, c = cfg.offset, cfg.cover
    return uc.at[:, o:o + c, o:o + c, o:o + c].set(
        restrict_fine(uf, cfg.refine_ratio))


def _fine_fill_ghosts(uc_synced, uf, cfg: AMRHydroConfig):
    """Fine (F, Nf, Nf, Nf) -> padded (F, Nf+2g, ...): the ghost band is
    prolongated from the surrounding (already fine-synced) coarse cells —
    the coarse-fine boundary exchange."""
    g, r = cfg.ghost, cfg.refine_ratio
    gc = cfg.coarse_ghost_pad
    o, c, nf = cfg.offset, cfg.cover, cfg.n_fine
    slab = uc_synced[:, o - gc:o + c + gc, o - gc:o + c + gc,
                     o - gc:o + c + gc]
    fp = prolong_coarse(slab, r)
    lo = gc * r - g                   # trim the prolongation to exactly g
    n = nf + 2 * g
    fp = fp[:, lo:lo + n, lo:lo + n, lo:lo + n]
    return fp.at[:, g:g + nf, g:g + nf, g:g + nf].set(uf)


@partial(jax.jit, static_argnames=("cfg", "bc"))
def extract_subgrids_multilevel(uc, uf, cfg: AMRHydroConfig,
                                bc: str = "outflow"):
    """Two-level ghost exchange + decomposition.

    Returns ``(subs_coarse, subs_fine)`` padded per-task arrays.  The
    coarse level sees the restricted fine solution under the patch; the
    fine level's boundary ghosts are prolongated from the coarse level.
    """
    ucs = _sync_coarse(uc, uf, cfg)
    subs_c = _extract_padded(fill_ghosts(ucs, cfg.ghost, bc),
                             cfg.n_coarse, cfg.coarse_subgrid, cfg.ghost)
    subs_f = _extract_padded(_fine_fill_ghosts(ucs, uf, cfg),
                             cfg.n_fine, cfg.fine_subgrid, cfg.ghost)
    return subs_c, subs_f


@partial(jax.jit, static_argnames=("cfg",))
def sync_coarse(uc, uf, cfg: AMRHydroConfig):
    """Public jitted wrapper of the fine->coarse overlap sync."""
    return _sync_coarse(uc, uf, cfg)


def amr_sedov_init(cfg: AMRHydroConfig, dtype=None) -> AMRState:
    """Sedov blast centred in the fine patch: the energy deposit lives
    entirely at fine resolution (r0 = 3.5 fine cells, well inside the
    patch); the coarse level starts ambient and is synced from the fine.
    State dtype follows ``cfg.dtype`` (overridable), keeping task
    signatures consistent with the runners' h vectors and warmup specs."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    hc, hf = cfg.h_coarse, cfg.h_fine
    nf = cfg.n_fine
    x0 = cfg.offset * hc - 0.5 * cfg.domain
    xf = x0 + (jnp.arange(nf) + 0.5) * hf
    Xf, Yf, Zf = jnp.meshgrid(xf, xf, xf, indexing="ij")
    r = jnp.sqrt(Xf * Xf + Yf * Yf + Zf * Zf)
    r0 = 3.5 * hf
    in_blast = r < r0
    n_blast = jnp.maximum(jnp.sum(in_blast), 1)
    e_dens = cfg.blast_energy / (n_blast * hf ** 3)
    p_blast = (cfg.gamma - 1.0) * e_dens
    p_ambient = 1e-8
    rho_f = jnp.full(r.shape, cfg.rho0)
    p_f = jnp.where(in_blast, p_blast, p_ambient)
    zeros_f = jnp.zeros_like(rho_f)
    uf = prim_to_cons(rho_f, zeros_f, zeros_f, zeros_f, p_f,
                      cfg.gamma).astype(dtype)

    nc = cfg.n_coarse
    rho_c = jnp.full((nc, nc, nc), cfg.rho0)
    zeros_c = jnp.zeros_like(rho_c)
    p_c = jnp.full((nc, nc, nc), p_ambient)
    uc = prim_to_cons(rho_c, zeros_c, zeros_c, zeros_c, p_c,
                      cfg.gamma).astype(dtype)
    uc = sync_coarse(uc, uf, cfg)
    return AMRState(uc=uc, uf=uf, t=0.0, step=0)
