"""Octo-Tiger-style hydro solver (the paper's application substrate).

Inviscid Euler equations on a uniform Cartesian grid decomposed into
fixed-size sub-grids (octree leaves with AMR off, as in the paper's Sedov
benchmark).  Piecewise-parabolic reconstruction at 26 quadrature points per
cell, Kurganov-Tadmor central-upwind fluxes integrated with Newton-Cotes
(Simpson) quadrature over each face, TVD-RK3 time stepping under a Courant
condition.
"""
from repro.hydro.euler import (
    N_FIELDS, cons_to_prim, prim_to_cons, sound_speed, euler_flux, max_signal_speed,
)
from repro.hydro.ppm import DIRECTIONS, DIR_PAIRS, ppm_reconstruct_all
from repro.hydro.flux import flux_divergence, FACE_QUAD
from repro.hydro.state import (
    HydroState, sedov_init, assemble_global, extract_subgrids, fill_ghosts,
)
from repro.hydro.stepper import courant_dt, rk3_step, subgrid_rhs, total_conserved

__all__ = [
    "N_FIELDS", "cons_to_prim", "prim_to_cons", "sound_speed", "euler_flux",
    "max_signal_speed", "DIRECTIONS", "DIR_PAIRS", "ppm_reconstruct_all",
    "flux_divergence", "FACE_QUAD", "HydroState", "sedov_init",
    "assemble_global", "extract_subgrids", "fill_ghosts", "courant_dt",
    "rk3_step", "subgrid_rhs", "total_conserved",
]
