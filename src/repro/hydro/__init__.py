"""Octo-Tiger-style hydro solver (the paper's application substrate).

Inviscid Euler equations on a uniform Cartesian grid decomposed into
fixed-size sub-grids (octree leaves with AMR off, as in the paper's Sedov
benchmark).  Piecewise-parabolic reconstruction at 26 quadrature points per
cell, Kurganov-Tadmor central-upwind fluxes integrated with Newton-Cotes
(Simpson) quadrature over each face, TVD-RK3 time stepping under a Courant
condition.
"""
from repro.hydro.euler import (
    N_FIELDS, cons_to_prim, prim_to_cons, sound_speed, euler_flux, max_signal_speed,
)
from repro.hydro.ppm import DIRECTIONS, DIR_PAIRS, ppm_reconstruct_all
from repro.hydro.flux import flux_divergence, FACE_QUAD
from repro.hydro.state import (
    AMRState, HydroState, amr_sedov_init, assemble_global, extract_subgrids,
    extract_subgrids_multilevel, fill_ghosts, prolong_coarse, restrict_fine,
    sedov_init, sync_coarse,
)
from repro.hydro.stepper import (
    amr_courant_dt, amr_reference_rhs, amr_reference_step, amr_rk3_step,
    amr_run, courant_dt, level_batched_body, level_batched_jit, rk3_step,
    subgrid_rhs, total_conserved,
)

__all__ = [
    "N_FIELDS", "cons_to_prim", "prim_to_cons", "sound_speed", "euler_flux",
    "max_signal_speed", "DIRECTIONS", "DIR_PAIRS", "ppm_reconstruct_all",
    "flux_divergence", "FACE_QUAD", "HydroState", "sedov_init",
    "assemble_global", "extract_subgrids", "fill_ghosts", "courant_dt",
    "rk3_step", "subgrid_rhs", "total_conserved",
    "AMRState", "amr_sedov_init", "extract_subgrids_multilevel",
    "prolong_coarse", "restrict_fine", "sync_coarse", "amr_courant_dt",
    "amr_reference_rhs", "amr_reference_step", "amr_rk3_step", "amr_run",
    "level_batched_body", "level_batched_jit",
]
