"""Piecewise-parabolic (PPM) reconstruction at 26 quadrature points per cell.

Octo-Tiger reconstructs the evolved variables at 26 points on each cell's
surface: the 6 face centers, 12 edge midpoints and 8 vertices (paper §IV-B).
Equivalently, for each of the 13 *direction pairs* ``{d, -d}`` with
``d in {-1,0,1}^3 \\ {0}`` (canonical representative has its first nonzero
component positive), a 1D PPM limited parabola is built along the sample line
``u(i + k*d), k = -2..2`` and evaluated at +-1/2 step, yielding the surface
values toward ``+d`` and ``-d``.

Reconstruction for cell ``i`` needs samples at ``i +- 2d``, so with the
paper's ghost width of 3 the reconstruction is valid on the interior plus one
ghost ring — exactly the paper's ``(S+2)^3`` work items (10^3 for the default
8^3 sub-grid).

Everything here operates on one padded sub-grid ``(F, P, P, P)`` and is
``vmap``-compatible over a leading slot axis (the aggregation axis).
Shifts use ``jnp.roll``; wrap-around only contaminates cells within 2 of the
array edge, which are ghost cells whose reconstructions are never consumed.
"""
from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp

# --- direction sets -------------------------------------------------------

def _canonical(d: Tuple[int, int, int]) -> bool:
    for c in d:
        if c != 0:
            return c > 0
    return False

# all 26 offsets; 13 canonical pair representatives, faces first then edges
# then vertices (sorted by |d|^2 = 1, 2, 3).
DIRECTIONS: List[Tuple[int, int, int]] = [
    (dx, dy, dz)
    for dx in (-1, 0, 1) for dy in (-1, 0, 1) for dz in (-1, 0, 1)
    if (dx, dy, dz) != (0, 0, 0)
]
DIR_PAIRS: List[Tuple[int, int, int]] = sorted(
    [d for d in DIRECTIONS if _canonical(d)],
    key=lambda d: (d[0] ** 2 + d[1] ** 2 + d[2] ** 2, d),
)
PAIR_INDEX = {d: i for i, d in enumerate(DIR_PAIRS)}
N_PAIRS = len(DIR_PAIRS)  # 13


def _shift(u, d: Tuple[int, int, int], k: int):
    """u(i + k*d) for (..., X, Y, Z) arrays (roll; edges are don't-care)."""
    if k == 0:
        return u
    return jnp.roll(u, shift=(-k * d[0], -k * d[1], -k * d[2]), axis=(-3, -2, -1))


def ppm_pair(u, d: Tuple[int, int, int]):
    """Limited-parabola surface values of every cell toward -d and +d.

    u: (..., X, Y, Z).  Returns (u_minus, u_plus), same shape as u.
    Colella & Woodward (1984): 4th-order interface interpolation followed by
    monotonicity limiting of the per-cell parabola.
    """
    um2 = _shift(u, d, -2)
    um1 = _shift(u, d, -1)
    up1 = _shift(u, d, 1)
    up2 = _shift(u, d, 2)

    # interface values u_{i-1/2}, u_{i+1/2} along the d-line
    ul = (7.0 / 12.0) * (um1 + u) - (1.0 / 12.0) * (um2 + up1)
    ur = (7.0 / 12.0) * (u + up1) - (1.0 / 12.0) * (um1 + up2)

    # --- CW84 limiter ---
    # 1) local extremum -> flatten to piecewise constant
    extremum = (ur - u) * (u - ul) <= 0.0
    # 2) parabola overshoot -> move the far endpoint
    du = ur - ul
    u6 = 6.0 * (u - 0.5 * (ul + ur))
    ul_new = jnp.where(du * u6 > du * du, 3.0 * u - 2.0 * ur, ul)
    ur_new = jnp.where(-(du * du) > du * u6, 3.0 * u - 2.0 * ul, ur)
    ul = jnp.where(extremum, u, ul_new)
    ur = jnp.where(extremum, u, ur_new)
    return ul, ur


def ppm_reconstruct_all(u):
    """Reconstruct all 13 direction pairs.

    u: (F, X, Y, Z) padded sub-grid (or (slots, F, X, Y, Z)).
    Returns (N_PAIRS, 2, F, X, Y, Z) (plus leading slot axes): index [p, 0]
    is the surface value toward ``-DIR_PAIRS[p]``, [p, 1] toward ``+``.
    """
    outs = []
    for d in DIR_PAIRS:
        um, up = ppm_pair(u, d)
        outs.append(jnp.stack([um, up]))
    return jnp.stack(outs)
