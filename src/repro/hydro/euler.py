"""Inviscid Euler equations: conserved <-> primitive maps and point fluxes.

Field layout (leading axis of every state array), matching Octo-Tiger's
hydro variables: ``U = (rho, Sx, Sy, Sz, E)`` with momentum ``S = rho*v`` and
total energy ``E = rho*e + 0.5*rho*|v|^2``.
"""
from __future__ import annotations

import jax.numpy as jnp

N_FIELDS = 5
RHO, SX, SY, SZ, EN = range(N_FIELDS)

# Density/pressure floors: the Sedov IC has near-zero pressure outside the
# blast, and limited reconstruction can undershoot.  Octo-Tiger applies the
# same kind of floors in its physics module.
RHO_FLOOR = 1e-10
P_FLOOR = 1e-12


def cons_to_prim(u, gamma: float):
    """(5, ...) conserved -> (rho, vx, vy, vz, p)."""
    rho = jnp.maximum(u[RHO], RHO_FLOOR)
    vx, vy, vz = u[SX] / rho, u[SY] / rho, u[SZ] / rho
    ke = 0.5 * rho * (vx * vx + vy * vy + vz * vz)
    p = jnp.maximum((gamma - 1.0) * (u[EN] - ke), P_FLOOR)
    return rho, vx, vy, vz, p


def prim_to_cons(rho, vx, vy, vz, p, gamma: float):
    e = p / (gamma - 1.0) + 0.5 * rho * (vx * vx + vy * vy + vz * vz)
    return jnp.stack([rho, rho * vx, rho * vy, rho * vz, e])


def sound_speed(rho, p, gamma: float):
    return jnp.sqrt(gamma * p / rho)


def euler_flux(u, axis: int, gamma: float):
    """Physical flux F_axis(U): (5, ...) -> (5, ...)."""
    rho, vx, vy, vz, p = cons_to_prim(u, gamma)
    v = (vx, vy, vz)[axis]
    f = jnp.stack([
        rho * v,
        u[SX] * v,
        u[SY] * v,
        u[SZ] * v,
        (u[EN] + p) * v,
    ])
    # pressure contribution to the momentum component along `axis`
    return f.at[SX + axis].add(p)


def max_signal_speed(u, gamma: float):
    """max over cells of (|v| + c) — the Courant-condition signal speed."""
    rho, vx, vy, vz, p = cons_to_prim(u, gamma)
    c = sound_speed(rho, p, gamma)
    vmag = jnp.sqrt(vx * vx + vy * vy + vz * vz)
    return jnp.max(vmag + c)


def signal_speed_axis(u, axis: int, gamma: float):
    """|v_axis| + c per cell (central-upwind local speed estimate)."""
    rho, vx, vy, vz, p = cons_to_prim(u, gamma)
    c = sound_speed(rho, p, gamma)
    v = (vx, vy, vz)[axis]
    return jnp.abs(v) + c
