"""Checkpoint / restart with elastic resharding.

Fault-tolerance contract (DESIGN.md §5):

* deterministic step-indexed saves: params + optimizer state + the data
  cursor (= step, because the pipeline is (seed, step)-addressable) + config
  identity; a restore at step k reproduces the exact training trajectory.
* atomic writes (tmp + rename) so a node failure mid-save never corrupts the
  latest checkpoint.
* **elastic restore**: arrays are saved as logical (unsharded) values; on
  restore they are ``device_put`` against whatever mesh/sharding the *new*
  job uses — a 512-chip checkpoint restores onto 256 or 1024 chips, which is
  the restart path after losing a pod (or gaining one).

Format: one ``.npz`` per step (flattened pytree, path-encoded keys) + a JSON
sidecar.  A real deployment would swap this layer for a distributed array
store; the interface (save/restore/reshard) is what the framework depends on.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "//"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz can't round-trip ml_dtypes; store fp32, restore re-casts
            # to the template's dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    tdef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = SEP.join(_path_str(p) for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jnp_astype(arr, leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(tdef, leaves)


def jnp_astype(arr: np.ndarray, dtype):
    import jax.numpy as jnp
    return np.asarray(jnp.asarray(arr).astype(dtype))


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state,
                    meta: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = {f"params{SEP}{k}": v for k, v in _flatten(params).items()}
    flat.update({f"opt{SEP}{k}": v for k, v in _flatten(opt_state).items()})
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.rename(tmp, path)                      # atomic publish
    side = {"step": step, **(meta or {})}
    with open(path + ".json", "w") as f:
        json.dump(side, f)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, params_template,
                       opt_template) -> Tuple[Any, Any, Dict[str, Any]]:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = dict(np.load(path).items())
    p_flat = {k[len(f"params{SEP}"):]: v for k, v in data.items()
              if k.startswith(f"params{SEP}")}
    o_flat = {k[len(f"opt{SEP}"):]: v for k, v in data.items()
              if k.startswith(f"opt{SEP}")}
    with open(path + ".json") as f:
        meta = json.load(f)
    return (_unflatten(params_template, p_flat),
            _unflatten(opt_template, o_flat), meta)


def restore_resharded(ckpt_dir: str, step: int, params_template,
                      opt_template, mesh, spec_fn):
    """Elastic restore: place restored logical arrays onto a (possibly
    different-size) mesh.  ``spec_fn(tree) -> tree of NamedSharding``."""
    params, opt, meta = restore_checkpoint(ckpt_dir, step, params_template,
                                           opt_template)
    p_shard = spec_fn(params)
    o_shard = spec_fn(opt)
    params = jax.tree_util.tree_map(jax.device_put, params, p_shard)
    opt = jax.tree_util.tree_map(jax.device_put, opt, o_shard)
    return params, opt, meta
