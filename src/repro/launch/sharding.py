"""Sharding-spec derivation for parameters, optimizer state, batches, caches.

Logical-axis rules (repro.distributed.api) are resolved against the mesh
with divisibility fallback, so the SAME rules serve every (arch x shape x
mesh) cell: 4-KV-head GQA simply replicates the kv-head dim on a 16-way
model axis, a 60-expert MoE falls back from expert- to ff-sharding, a
batch-1 long-context cache falls back from batch- to sequence-sharding.

Parameter rule: weight matrices shard (d_model -> fsdp = pod x data,
fan-out -> tp = model); this is ZeRO-3/FSDP — XLA all-gathers a layer's
weights just-in-time inside the scan-over-layers (overlapping with the
previous layer's compute) and reduce-scatters gradients.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.api import logical_rules, spec_for

# -- parameter leaf rules (base shapes; stacked-layer axes are prepended) ---
# fmt: off
_PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "emb": ("vocab", "fsdp"),
    "head": ("fsdp", "vocab"),
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "router": ("fsdp", None),
    "in_proj": ("fsdp", "tp"), "out_proj": ("tp", "fsdp"),
    "up_l": ("fsdp", "tp"), "up_r": ("fsdp", "tp"),
    "down": ("tp", "fsdp"),
    "w_x": ("fsdp", "tp"), "w_h": ("fsdp", "tp"),
    "w_if": ("fsdp", None),
}
_MOE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("expert", "fsdp", "tp"),
    "w_up": ("expert", "fsdp", "tp"),
    "w_down": ("expert", "tp", "fsdp"),
}
# fmt: on


def _leaf_key(path) -> Tuple[Sequence[str], str]:
    keys = [str(p.key) for p in path if hasattr(p, "key")]
    return keys, keys[-1] if keys else ""


def param_pspec(tree) -> Any:
    """PartitionSpec tree for a parameter pytree (inside a rules context)."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys, key = _leaf_key(path)
        in_moe = "moe" in keys and "shared" not in keys
        base = _MOE_AXES.get(key) if in_moe and key in _MOE_AXES else \
            _PARAM_AXES.get(key)
        shape = leaf.shape
        if base is None or len(base) > len(shape):
            out.append(P())
            continue
        extra = len(shape) - len(base)
        names = (None,) * extra + tuple(base)
        out.append(spec_for(shape, names))
    return jax.tree_util.tree_unflatten(tdef, out)


# -- cache leaf rules --------------------------------------------------------

def _cache_slot_axes(cache_shapes, probe_shapes) -> list:
    axes = []
    for a, b in zip(jax.tree_util.tree_leaves(cache_shapes),
                    jax.tree_util.tree_leaves(probe_shapes)):
        axes.append(next((i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                          if x != y), None))
    return axes


def cache_pspec(cache_shapes, probe_shapes) -> Any:
    """PartitionSpec tree for a decode cache.  ``probe_shapes`` is the same
    cache built at batch+1 (robust slot-axis identification)."""
    slot_axes = _cache_slot_axes(cache_shapes, probe_shapes)
    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for (path, leaf), slot in zip(flat, slot_axes):
        keys, key = _leaf_key(path)
        nd = len(leaf.shape)
        names: list = [None] * nd
        if slot is not None:
            names[slot] = "batch"
            rest = nd - slot - 1
            if key in ("k", "v") and rest >= 2:
                names[slot + 1] = "kv_seq"
                names[slot + 2] = "kv_heads"
            elif key in ("ssm", "C") and rest >= 1:
                names[slot + 1] = "heads"
            elif key in ("n", "m") and rest >= 1 and "mlstm" in keys:
                names[slot + 1] = "heads"
        out.append(spec_for(leaf.shape, names))
    return jax.tree_util.tree_unflatten(tdef, out)


def batch_pspec(batch_shapes) -> Any:
    """Batch inputs shard on the (pod, data) batch axis."""
    def one(leaf):
        names = ["batch"] + [None] * (len(leaf.shape) - 1)
        return spec_for(leaf.shape, names)
    return jax.tree_util.tree_map(one, batch_shapes)


def named(mesh: Mesh, spec_tree) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_pspec(param_spec_tree) -> Any:
    """Optimizer state mirrors params; step counter replicated."""
    return {"m": param_spec_tree,
            "v": param_spec_tree,
            "step": P()}


def rules_overrides(shape, cfg=None) -> Dict:
    """Logical-rule overrides for one shape cell.  The SAME overrides must be
    active while tracing/lowering the step so in-model ``constrain`` calls
    resolve (sharding constraints inside scan bodies are what keep while-loop
    residuals sharded — without them XLA drops the batch sharding on saved
    activations)."""
    ov: Dict = {}
    if shape.kind == "decode":
        # decode caches: the KV sequence absorbs whatever mesh axes the
        # request batch can't cover (model for batched decode, everything
        # for batch-1 long-context)
        ov.setdefault("kv_seq", ("pod", "data", "model"))
        # serving-mode weight sharding: there is no optimizer state to
        # shard, and FSDP-gathering weights EVERY decoded token is pure
        # collective overhead (measured 1.05 GB all-gather/step for
        # seamless multipod — §Perf hillclimb B).  Small models replicate
        # weights across the DP domain (zero steady-state collectives);
        # models too big for one chip keep the gather on the intra-pod
        # data axis only, never across the slow pod links.
        if cfg is not None:
            tp_bytes = cfg.param_count() * 2 / 16    # bf16, 16-way TP share
            ov.setdefault("fsdp",
                          None if tp_bytes < 6e9 else ("data",))
    return ov


def make_all_specs(cfg, shape, mesh: Mesh, *,
                   overrides: Optional[Dict] = None):
    """(param, opt, batch[, cache]) PartitionSpec trees for one cell."""
    from repro.data.pipeline import make_batch_specs
    from repro.models import model as model_mod

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sh = jax.eval_shape(partial(model_mod.init_params, cfg), key_sds)
    batch_sh = make_batch_specs(cfg, shape)

    ov = dict(overrides or {})
    ov.update(rules_overrides(shape, cfg))

    with logical_rules(mesh, ov):
        pspec = param_pspec(params_sh)
        ospec = opt_pspec(pspec)
        bspec = batch_pspec(batch_sh)
        if shape.kind == "decode":
            def build(params, b):
                batch = {"tokens": jnp.zeros((b, 1), jnp.int32)}
                if cfg.family == "vlm":
                    batch["vision"] = jnp.zeros(
                        (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
                if cfg.family == "audio":
                    batch["frames"] = jnp.zeros(
                        (b, 8 * cfg.encoder_seq_ratio, cfg.d_model),
                        jnp.bfloat16)
                return model_mod.init_cache(cfg, params, batch, b,
                                            shape.seq_len)
            cache_sh = jax.eval_shape(
                partial(build, b=shape.global_batch), params_sh)
            probe_sh = jax.eval_shape(
                partial(build, b=shape.global_batch + 1), params_sh)
            cspec = cache_pspec(cache_sh, probe_sh)
            return params_sh, batch_sh, cache_sh, pspec, ospec, bspec, cspec
    return params_sh, batch_sh, None, pspec, ospec, bspec, None
