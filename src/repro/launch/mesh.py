"""Production mesh factory.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod = 16x16 = 256 chips (v5e pod),
multi-pod = 2 pods = 512 chips with a leading "pod" axis whose collectives
cross the slow inter-pod links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over available devices (tests / examples)."""
    n = n_data * n_model
    assert n <= len(jax.devices()), (n, len(jax.devices()))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
