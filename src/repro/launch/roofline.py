"""Roofline-term derivation: analytic FLOPs/bytes + HLO collective parsing.

Why analytic FLOPs: ``compiled.cost_analysis()`` visits every HLO
computation ONCE, so a scan-over-layers body is counted for one layer and a
chunked-attention inner loop for one chunk — for a 36-layer model the
reported FLOPs are ~20-40x low (measured; see EXPERIMENTS.md §Roofline
methodology).  The compute/memory terms are therefore derived from explicit
per-family formulas (the napkin math is the point of a roofline), while the
collective term IS parsed from the compiled SPMD module with while-loop trip
counts folded in (``parse_collectives_with_trips``), because the collective
schedule — what XLA actually inserted — cannot be guessed analytically.

All hardware constants are TPU v5e-class, per chip.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI direction

REMAT_FACTOR = 4.0 / 3.0   # full remat: backward replays one extra forward


# ---------------------------------------------------------------------------
# analytic FLOPs (global, per step)
# ---------------------------------------------------------------------------

def _attn_flops_fwd(cfg, tokens: int, kv_len: float) -> float:
    """QK^T + PV matmul flops for `tokens` queries against kv_len keys."""
    hq, hd = cfg.n_heads, cfg.resolved_head_dim
    return 2.0 * 2.0 * tokens * kv_len * hq * hd


def _ssd_flops_fwd(cfg, tokens: int) -> float:
    """Mamba2 chunked SSD: intra-chunk (C B^T masked) + state path."""
    inner = cfg.ssm_expand * cfg.d_model
    h = inner // 64
    n, c = cfg.ssm_state, cfg.ssm_chunk
    # CB^T (T*c*n), decay-weighted matmul (T*c*h*p), state in/out (T*n*p*h)
    p = 64
    return 2.0 * tokens * (c * n + c * h * p + 2.0 * n * p * h)


def _mlstm_flops_fwd(cfg, tokens: int) -> float:
    inner = cfg.ssm_expand * cfg.d_model
    hd = inner // cfg.n_heads
    c = cfg.ssm_chunk
    # intra-chunk qk/pv (2 * T*c*inner each) + state path (T*hd*hd per head)
    return 2.0 * tokens * (2.0 * c * inner + cfg.n_heads * hd * hd)


def analytic_flops(cfg, shape) -> Dict[str, float]:
    """Global FLOPs per step, matmul-level accounting, per family."""
    n_params = cfg.param_count(active_only=bool(cfg.n_experts))
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, kv, fwd_mult = b * s, s / 2.0, 3.0 * REMAT_FACTOR
    elif shape.kind == "prefill":
        tokens, kv, fwd_mult = b * s, s / 2.0, 1.0
    else:
        tokens, kv, fwd_mult = b, float(s), 1.0

    mat = 2.0 * n_params * tokens          # one forward through all params
    fam = cfg.family
    mixer = 0.0
    if fam in ("dense", "moe", "vlm", "audio"):
        layers = cfg.n_layers
        if cfg.sliding_window:
            kv = min(kv, float(cfg.sliding_window))
        mixer += layers * _attn_flops_fwd(cfg, tokens, kv)
        if fam == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_every
            mixer += n_cross * _attn_flops_fwd(cfg, tokens, cfg.vision_tokens)
        if fam == "audio":
            enc_tok = tokens * cfg.encoder_seq_ratio if shape.kind != "decode" \
                else 0
            mixer += cfg.n_encoder_layers * _attn_flops_fwd(
                cfg, enc_tok, s * cfg.encoder_seq_ratio)
            mixer += cfg.n_layers * _attn_flops_fwd(
                cfg, tokens, s * cfg.encoder_seq_ratio)   # cross
    elif fam == "ssm":
        groups = cfg.n_layers // cfg.slstm_every
        mixer += (cfg.n_layers - groups) * _mlstm_flops_fwd(cfg, tokens)
        # sLSTM: sequential, 8*d^2 per token per layer (4 gates x W_x+W_h)
        mixer += groups * 2.0 * tokens * 8.0 * cfg.d_model ** 2
    elif fam == "hybrid":
        groups = cfg.n_layers // cfg.shared_attn_every
        mixer += cfg.n_layers * _ssd_flops_fwd(cfg, tokens)
        mixer += groups * _attn_flops_fwd(cfg, tokens, kv)

    total_fwd = mat + mixer
    return {"total": total_fwd * fwd_mult,
            "matmul_fwd": mat, "mixer_fwd": mixer,
            "model_flops": (6.0 if shape.kind == "train" else 2.0)
            * n_params * tokens}


# ---------------------------------------------------------------------------
# analytic HBM bytes (per device, per step)
# ---------------------------------------------------------------------------

def analytic_bytes(cfg, shape, chips: int, temp_bytes: int = 0) -> Dict[str, float]:
    """Per-device HBM traffic model.

    * params: each layer's weights are read for fwd, the remat re-forward and
      bwd (3x), grads+opt-state read/write (12 bytes/param fp32 m,v + grad)
      — FSDP means each device touches params/chips bytes.
    * activations: ~12 residual-stream-sized reads+writes per layer (qkv, o,
      norms, mlp in/out ...), bf16, batch+seq+model sharded (the SP layout);
      plus the score/prob traffic of chunked attention (f32, heads-sharded).
    """
    n_params = cfg.param_count(active_only=False)
    b, s = shape.global_batch, shape.seq_len
    dtype_b = 2
    if shape.kind == "train":
        param_traffic = n_params * (3 * dtype_b + 12)
        act_passes = 3.0
    elif shape.kind == "prefill":
        param_traffic = n_params * dtype_b
        act_passes = 1.0
    else:
        param_traffic = cfg.param_count(active_only=bool(cfg.n_experts)) \
            * dtype_b
        act_passes = 1.0

    tokens = b * (s if shape.kind != "decode" else 1)
    resid = tokens * cfg.d_model * dtype_b
    act_traffic = 12.0 * cfg.n_layers * resid * act_passes
    if cfg.family in ("dense", "moe", "vlm", "audio") and shape.kind != "decode":
        kv_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
        probs = tokens * kv_eff * cfg.n_heads * 4.0     # f32 scores once
        act_traffic += 2.0 * probs * act_passes
    if shape.kind == "decode":
        # decode reads the whole KV cache (or window/state) once per step
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        kv_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            act_traffic += 2.0 * cfg.n_layers * b * kv_eff * hkv * hd * dtype_b
        elif cfg.family == "hybrid":
            groups = cfg.n_layers // cfg.shared_attn_every
            inner = cfg.ssm_expand * cfg.d_model
            act_traffic += 2.0 * groups * b * kv_eff * hkv * hd * dtype_b
            act_traffic += cfg.n_layers * b * (inner // 64) * cfg.ssm_state \
                * 64 * 4.0
        elif cfg.family == "ssm":
            inner = cfg.ssm_expand * cfg.d_model
            hd2 = (inner // cfg.n_heads) ** 2
            act_traffic += cfg.n_layers * b * cfg.n_heads * hd2 * 4.0

    per_device = (param_traffic + act_traffic) / chips
    return {"total": per_device,
            "param_traffic_global": param_traffic,
            "act_traffic_global": act_traffic}


# ---------------------------------------------------------------------------
# HLO collective parsing with while-loop trip counts
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1, "c64": 8, "c128": 16}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """Split an HLO module into computations.  Headers look like
    ``%name (p: (s32[], bf16[...])) -> (...) {`` — parameter lists nest
    parentheses (tuples), so match on the name + trailing ``{`` only."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{") \
                and "->" in line:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Extract the loop bound from a while condition computation."""
    consts = []
    for ln in cond_lines:
        m = re.search(r"constant\((\d+)\)", ln)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def parse_collectives_with_trips(hlo: str) -> Dict[str, float]:
    """Per-device collective bytes with while-loop bodies multiplied by
    their trip counts (scan-over-layers collectives count once per layer)."""
    comps = _split_computations(hlo)

    def comp_bytes(name: str, seen) -> Dict[str, float]:
        if name in seen:            # defensive: HLO call graphs are acyclic
            return {k: 0.0 for k in _COLLECTIVES}
        seen = seen | {name}
        out = {k: 0.0 for k in _COLLECTIVES}
        for ln in comps.get(name, ()):
            s = ln.strip()
            wm = re.search(r"while\(.*?\).*condition=%?([\w.\-]+).*"
                           r"body=%?([\w.\-]+)", s)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                sub = comp_bytes(body, seen)
                for k in _COLLECTIVES:
                    out[k] += trips * sub[k]
                continue
            for kind in _COLLECTIVES:
                m = re.search(rf"= (.*?) {kind}(-start)?\(", s)
                if not m or f"{kind}-done" in s:
                    continue
                result_part = m.group(1)
                operand_part = s[m.end():]
                if kind == "all-gather":
                    out[kind] += _shape_bytes(result_part)
                else:
                    out[kind] += _shape_bytes(operand_part)
                break
        return out

    # entry computation name
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    if entry is None:
        return {k: 0.0 for k in _COLLECTIVES} | {"total": 0.0}
    out = comp_bytes(entry, frozenset())
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------

def roofline_terms(cfg, shape, chips: int, coll: Dict[str, float],
                   cross_pod_fraction: float = 0.0) -> Dict[str, Any]:
    fl = analytic_flops(cfg, shape)
    by = analytic_bytes(cfg, shape, chips)
    t_compute = fl["total"] / chips / PEAK_FLOPS
    t_memory = by["total"] / HBM_BW
    t_coll = coll["total"] / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mfu_at_bound = (fl["model_flops"] / chips / PEAK_FLOPS) / bound \
        if bound > 0 else 0.0
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "analytic_flops_global": fl["total"],
        "model_flops_global": fl["model_flops"],
        "useful_flop_ratio": fl["model_flops"] / fl["total"],
        "hbm_bytes_per_device": by["total"],
        "collective_bytes_per_device": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "roofline_bound_s": bound,
        "roofline_fraction": mfu_at_bound,
    }
