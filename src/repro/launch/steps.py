"""train_step / serve_step builders (the units the dry-run lowers).

``train_step``: loss -> grads -> AdamW update, optionally with gradient
accumulation over microbatches (the S1 knob at the training-loop level:
fewer, larger per-launch workloads vs. more, smaller ones).

``serve_step``: one aggregated decode launch over the request batch — the
serving engine's bucketed kernel, here lowered at the full production shape.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_mod
from repro.optim.adamw import OptConfig, opt_update


def make_train_step(cfg, opt_cfg: OptConfig, *, microbatch: int = 0
                    ) -> Callable:
    def loss_of(params, batch):
        return model_mod.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            # gradient accumulation: scan over microbatches
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mbatch):
                l, g = jax.value_and_grad(loss_of)(params, mbatch)
                carry = (carry[0] + l,
                         jax.tree_util.tree_map(jnp.add, carry[1], g))
                return carry, None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zero_g), mb)
            loss = loss / microbatch
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        new_p, new_s, metrics = opt_update(grads, opt_state, params, opt_cfg)
        return new_p, new_s, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg) -> Callable:
    """Forward at full sequence (the prefill cost proxy: logits for the last
    position only, hidden states for cache construction elided in dry-run)."""
    def prefill_step(params, batch):
        h = model_mod.forward_hidden(cfg, params, batch)
        # emit only the last position's logits (decode handoff)
        from repro.models.common import rmsnorm
        hl = rmsnorm(h[:, -1], params["embed"]["ln_f"], cfg.norm_eps)
        w = params["embed"]["emb"].T if cfg.tie_embeddings \
            else params["embed"]["head"]
        return hl @ w
    return prefill_step


def make_serve_step(cfg) -> Callable:
    def serve_step(params, cache, batch):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        return model_mod.decode_step(cfg, params, cache, tokens)
    return serve_step
