import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. derives parameter/optimizer/batch/cache shardings from the logical
     rules (repro.launch.sharding),
  3. ``jit(step).lower(...).compile()`` against ShapeDtypeStructs — no
     allocation; success proves the distribution config is coherent,
  4. records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
     (FLOPs / bytes) and the collective-bytes breakdown parsed from the
     optimized HLO — the three roofline terms of EXPERIMENTS.md §Roofline.

Results are cached as JSON under ``benchmarks/results/`` so reruns only
compile missing cells.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-15b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES_BY_NAME, get_config, shape_applicable
from repro.configs.base import ShapeConfig
from repro.distributed.api import logical_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import make_all_specs, named, rules_overrides
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.launch.roofline import (
    parse_collectives_with_trips, roofline_terms,
)
from repro.optim.adamw import OptConfig, opt_init

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")

def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    # Training-cell policy (EXPERIMENTS.md §Perf P1): Megatron-SP buys
    # activation memory but costs two activation all-gathers per layer per
    # pass (9.35 s collective vs 1.44 s compute for granite train under the
    # no-overlap model); gradient accumulation buys the same memory for 6x
    # fewer collective bytes.  MoE keeps SP — its dispatch needs both.
    microbatch = 0
    overrides: Dict[str, Any] = {}
    if shape.kind == "train":
        microbatch = 4
        if cfg.family != "moe":
            overrides["seq_sp"] = None

    (params_sh, batch_sh, cache_sh, pspec, ospec, bspec, cspec
     ) = make_all_specs(cfg, shape, mesh, overrides=overrides)

    opt_cfg = OptConfig()
    rules = dict(rules_overrides(shape, cfg))
    rules.update(overrides)
    # the logical-rules context must be live during tracing so that in-model
    # ``constrain`` calls resolve (keeps scan residuals sharded)
    with mesh, logical_rules(mesh, rules):
        if shape.kind == "train":
            step = make_train_step(cfg, opt_cfg, microbatch=microbatch)
            opt_sh = jax.eval_shape(opt_init, params_sh)
            rep = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            lowered = jax.jit(
                step,
                in_shardings=(named(mesh, pspec), named(mesh, ospec),
                              named(mesh, bspec)),
                out_shardings=(named(mesh, pspec), named(mesh, ospec),
                               {"loss": rep, "grad_norm": rep, "lr": rep}),
                donate_argnums=(0, 1),
            ).lower(params_sh, opt_sh, batch_sh)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            lowered = jax.jit(
                step,
                in_shardings=(named(mesh, pspec), named(mesh, bspec)),
            ).lower(params_sh, batch_sh)
        else:  # decode
            step = make_serve_step(cfg)
            tok_sh = batch_sh
            lowered = jax.jit(
                step,
                in_shardings=(named(mesh, pspec), named(mesh, cspec),
                              named(mesh, bspec)),
                out_shardings=(None, named(mesh, cspec)),
                donate_argnums=(1,),
            ).lower(params_sh, cache_sh, tok_sh)

        compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = parse_collectives_with_trips(hlo)

    mem_info: Dict[str, Any] = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_info[attr] = int(v)
        live = (mem_info.get("argument_size_in_bytes", 0)
                + mem_info.get("output_size_in_bytes", 0)
                + mem_info.get("temp_size_in_bytes", 0)
                - mem_info.get("alias_size_in_bytes", 0))
        mem_info["peak_bytes_per_device_est"] = live

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "chips": chips,
        "compile_s": round(compile_s, 1),
        "memory": mem_info,
        "roofline": roofline_terms(cfg, shape, chips, coll),
        # raw cost_analysis: CAVEAT — while-loop (scan) bodies are counted
        # once, so these under-report for scanned stacks; the roofline terms
        # above use the analytic model + trip-count-aware collective parse.
        "hlo_cost_analysis_raw": {
            "flops": float((cost or {}).get("flops", 0.0)),
            "bytes_accessed": float((cost or {}).get("bytes accessed", 0.0)),
        },
    }
    if verbose:
        print(json.dumps(result, indent=2))
        print(f"  memory_analysis: {mem}")
    return result


def result_path(arch: str, shape: str, mesh: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"dryrun_{mesh}_{arch}_{shape}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES_BY_NAME) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                path = result_path(arch, shape, mesh_name)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {mesh_name} {arch} {shape}")
                    continue
                print(f"[dryrun] {mesh_name} {arch} {shape} ...", flush=True)
                try:
                    res = dryrun_cell(arch, shape,
                                      multi_pod=(mesh_name == "multipod"))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    import traceback
                    traceback.print_exc()
                    failures.append((mesh_name, arch, shape, repr(e)))
                    continue
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("all requested cells compiled OK")


if __name__ == "__main__":
    main()
