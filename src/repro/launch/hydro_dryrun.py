import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Distributed dry-run of the paper's own scenario: the Sedov blast wave
sub-grids sharded across the production mesh.

Octo-Tiger distributes sub-grids across nodes via HPX parcels; here the
assembled grid's spatial axes shard over the DP mesh axes and the ghost
exchange (extract_subgrids) lowers to halo collectives inserted by XLA —
the distribution config of the hydro substrate is proven coherent the same
way the LM cells are.

  PYTHONPATH=src python -m repro.launch.hydro_dryrun [--multipod] [--levels 4]
"""
import argparse
import json
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import HydroConfig
from repro.hydro.stepper import rk3_step
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import parse_collectives_with_trips

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--levels", type=int, default=4,
                    help="4 -> 4096 sub-grids of 8^3 (2M cells)")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multipod)
    cfg = HydroConfig(subgrid=8, ghost=3, levels=args.levels)
    n = cfg.grids_per_edge * cfg.subgrid
    print(f"hydro dry-run: {cfg.n_subgrids} sub-grids of {cfg.subgrid}^3 "
          f"({n}^3 cells) on {mesh.size} chips")

    # spatial decomposition: x over data, y over model (and pod when
    # multi-pod) — the assembled-grid analogue of distributing sub-grids
    if args.multipod:
        spec = P(None, ("pod", "data"), "model", None)
    else:
        spec = P(None, "data", "model", None)
    u_sds = jax.ShapeDtypeStruct((5, n, n, n), jnp.float32)
    dt_sds = jax.ShapeDtypeStruct((), jnp.float32)

    step = partial(rk3_step, cfg=cfg, bc="periodic")
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(NamedSharding(mesh, spec), None),
            out_shardings=NamedSharding(mesh, spec),
            donate_argnums=(0,),
        ).lower(u_sds, dt_sds)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    coll = parse_collectives_with_trips(compiled.as_text())
    result = {
        "scenario": "sedov", "mesh": "multipod" if args.multipod else "pod",
        "chips": mesh.size, "cells": cfg.cells_total,
        "subgrids": cfg.n_subgrids,
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "halo_collective_bytes_per_device": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total"},
    }
    print(json.dumps(result, indent=2))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"hydro_dryrun_{result['mesh']}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(result, f, indent=2)
    print("OK: hydro step compiles on the production mesh")


if __name__ == "__main__":
    main()
