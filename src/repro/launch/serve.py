"""Production serving driver: the aggregation engine behind a request loop.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --reduced \
      --requests 32 --max-batch 8

On a TPU slice the same engine runs with the full config and the production
mesh (weights in serving-mode sharding — see launch/sharding.rules_overrides);
here a reduced config serves synthetic requests on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, get_config, reduced as reduce_cfg
from repro.configs.base import AggregationConfig
from repro.models import model as model_mod
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_len=args.max_len,
                        agg=AggregationConfig(max_aggregated=args.max_batch))

    reqs = [Request(i, [(7 * i + 3) % cfg.vocab_size], args.max_new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    # staggered arrival: drip requests in while the engine runs
    it = iter(reqs)
    for r in (next(it), next(it)):
        eng.submit(r)
    while eng.pending or eng.active or any(not r.done for r in reqs):
        for _ in range(2):
            r = next(it, None)
            if r is not None:
                eng.submit(r)
        if not eng.step() and not eng.pending:
            break
    wall = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    print(f"served {done}/{len(reqs)} requests, "
          f"{eng.stats['tokens']} tokens in {wall:.1f}s "
          f"({eng.stats['tokens'] / wall:.1f} tok/s incl. compile)")
    print(f"aggregated launches: {eng.stats['launches']} "
          f"histogram={eng.stats['aggregated_hist']}")


if __name__ == "__main__":
    main()
