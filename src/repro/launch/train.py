"""Production training driver: data -> train_step -> checkpoint, resilient.

Single entry point for both the laptop smoke run and the multi-pod job:

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
      --steps 100 --seq-len 512 --batch 8 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` shrinks the architecture (family-preserving) so the driver
runs on CPU; on a TPU pod the full config + production mesh is used with the
same code path.  Checkpoint/restart: the run resumes from the latest step in
``--ckpt-dir`` automatically; the (seed, step)-addressable pipeline makes
the trajectory exact across restarts.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, reduced as reduce_cfg
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.distributed.api import logical_rules
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_train_step
from repro.models import model as model_mod
from repro.optim.adamw import OptConfig, opt_init


def add_extra_inputs(cfg, batch, key):
    if cfg.family == "vlm":
        batch["vision"] = 0.02 * jax.random.normal(
            key, (batch["tokens"].shape[0], cfg.vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        b, s = batch["tokens"].shape
        batch["frames"] = 0.02 * jax.random.normal(
            key, (b, s * cfg.encoder_seq_ratio, cfg.d_model))
    return batch


def train(arch: str, steps: int, seq_len: int, batch_size: int,
          reduced: bool, ckpt_dir: str = "", save_every: int = 50,
          lr: float = 3e-4, microbatch: int = 0, log_every: int = 10):
    cfg = get_config(arch)
    if reduced:
        cfg = reduce_cfg(cfg)
    data = SyntheticLMStream(DataConfig(
        seq_len=seq_len, global_batch=batch_size, vocab_size=cfg.vocab_size))
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                        total_steps=steps)

    key = jax.random.PRNGKey(0)
    params = model_mod.init_params(cfg, key)
    opt_state = opt_init(params)
    start = 0
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            params, opt_state, meta = restore_checkpoint(
                ckpt_dir, last, params, opt_state)
            start = last
            print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, microbatch=microbatch),
                      donate_argnums=(0, 1))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"tokens/step={batch_size * seq_len}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch = add_extra_inputs(cfg, data.batch(step),
                                 jax.random.fold_in(key, step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % log_every == 0:
            dt = (time.perf_counter() - t0) / log_every
            print(f"step {step + 1:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt * 1e3:.0f} ms/step")
            t0 = time.perf_counter()
        if ckpt_dir and (step + 1) % save_every == 0:
            save_checkpoint(ckpt_dir, step + 1, params, opt_state,
                            meta={"arch": cfg.name})
    return params, opt_state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    args = ap.parse_args()
    _, _, losses = train(args.arch, args.steps, args.seq_len, args.batch,
                         args.reduced, args.ckpt_dir, args.save_every,
                         args.lr, args.microbatch)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
