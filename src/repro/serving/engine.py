"""Continuous-batching serving engine built on the aggregation executor.

Each decode request is a fine-grained task: one new token against that
request's KV cache.  Launching per-request decode kernels starves the device
exactly like Octo-Tiger's per-sub-grid kernels; the engine therefore
aggregates active requests into bucketed batched ``decode_step`` launches —
strategy 3 at the serving layer:

* requests are admitted into free slots of a slot-array cache between steps
  (continuous batching = dynamic add/remove of sub-grids in the paper's AMR
  rebalancing analogy);
* each engine step launches ONE aggregated kernel over the smallest
  power-of-two bucket covering the active slots (bucketed static shapes);
* per-request ``cache_len`` makes the aggregated batch ragged-correct — each
  task owns its chunk of the shared buffers.

On TPU the slot-array cache stays resident and the gather/scatter below is
a cheap on-device permutation; the bucket ladder bounds compilation to
log2(max_batch) shapes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AggregationConfig
from repro.core.faults import FaultInjector, poison_slots
from repro.core.tunestore import TuneStore
from repro.data.pipeline import length_bucket
from repro.models import model as model_mod


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    output: List[int] = field(default_factory=list)
    done: bool = False
    failed: bool = False              # evicted by the guard (DESIGN.md §11)
    error: Optional[str] = None       # why, when failed


class ServingEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_len: int = 256,
                 agg: Optional[AggregationConfig] = None,
                 fault_injector: Optional[FaultInjector] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.agg = agg or AggregationConfig(max_aggregated=max_batch)
        self.guard = getattr(self.agg, "guard", "off")
        if self.guard not in ("off", "finite"):
            raise ValueError(
                f"guard={self.guard!r} — expected 'off' or 'finite'")
        self._injector = fault_injector
        # persistent warm start (DESIGN.md §13): the engine's per-bucket
        # decode programs are exactly the restart-latency hot spot — with
        # a tune store configured, point JAX's persistent compilation
        # cache at it so a restarted server's bucket compiles (and the
        # prefill programs) are disk hits instead of fresh XLA runs
        self._store = TuneStore.open(getattr(self.agg, "tune_store", None))
        warm = (self._store.enable_compilation_cache()
                if self._store is not None else False)
        self.buckets = tuple(b for b in self.agg.bucket_sizes()
                             if b <= max_batch) or (max_batch,)

        self.cache = model_mod.init_cache(cfg, params, self._stub_batch(),
                                          max_batch, max_len)
        self._fresh_cache = jax.tree_util.tree_map(lambda x: x, self.cache)
        # identify each cache leaf's slot (request) axis by probing the cache
        # structure at a different batch size — layer-count == batch-size
        # collisions make shape matching alone unreliable
        probe = jax.eval_shape(
            lambda: model_mod.init_cache(cfg, params,
                                         self._stub_batch(max_batch + 1),
                                         max_batch + 1, max_len))
        self._slot_axes = []
        for a, b in zip(jax.tree_util.tree_leaves(self.cache),
                        jax.tree_util.tree_leaves(probe)):
            axis = next((i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                         if x != y), None)
            self._slot_axes.append(axis)
        self._treedef = jax.tree_util.tree_structure(self.cache)
        self.slots_free = list(range(max_batch))
        self.active: Dict[int, Request] = {}     # slot -> request
        self.pending: List[Request] = []
        self.next_token = np.zeros((max_batch,), np.int32)
        self._decode = {}                        # bucket -> jitted fn
        self._step_no = 0                        # launch counter ("wave" id)
        self.stats = {"launches": 0, "tokens": 0, "aggregated_hist": {},
                      "warm_start": warm,
                      "tune_store": (self._store.root
                                     if self._store is not None else None),
                      "faults": {"trips": 0, "evicted": 0}}

    def _stub_batch(self, b: Optional[int] = None):
        cfg = self.cfg
        b = b or self.max_batch
        batch = {"tokens": jnp.zeros((b, 1), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision"] = jnp.zeros((b, cfg.vision_tokens, cfg.d_model),
                                        jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((b, 8, cfg.d_model), jnp.float32)
        return batch

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue one request, rejecting malformed input AT SUBMIT time —
        a bad request found during an aggregated decode step costs the
        whole co-batch a guard trip; found here it costs one ValueError."""
        prompt = req.prompt
        if not isinstance(prompt, (list, tuple)) or not prompt:
            raise ValueError(
                f"request {req.rid}: prompt must be a non-empty list of "
                f"token ids, got {type(prompt).__name__}")
        vocab = int(getattr(self.cfg, "vocab_size", 0))
        for t in prompt:
            if isinstance(t, bool) or not isinstance(t, (int, np.integer)):
                raise ValueError(
                    f"request {req.rid}: prompt token {t!r} is not an int")
            if t < 0 or (vocab and t >= vocab):
                raise ValueError(
                    f"request {req.rid}: prompt token {int(t)} outside "
                    f"[0, {vocab})")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}")
        if len(prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({len(prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds the "
                f"engine's max_len {self.max_len}")
        self.pending.append(req)

    def _admit(self) -> None:
        while self.pending and self.slots_free:
            slot = self.slots_free.pop()
            req = self.pending.pop(0)
            self.active[slot] = req
            # reset this slot's cache_len and prefill the prompt
            self.cache["len"] = self.cache["len"].at[slot].set(0)
            self._zero_slot_states(slot)
            for tok in req.prompt[:-1]:
                self._prefill_token(slot, tok)
                if req.failed:        # guard evicted it mid-prefill
                    break
            if req.failed:
                continue              # slot already recycled by the guard
            self.next_token[slot] = req.prompt[-1]

    def _zero_slot_states(self, slot: int) -> None:
        """Reset one slot to its FRESH-cache values (not zeros: recurrent
        states like the mLSTM stabilizer initialize to -inf-like values, and
        zeroing them would corrupt the first decode of a reused slot)."""
        leaves = jax.tree_util.tree_leaves(self.cache)
        fresh = jax.tree_util.tree_leaves(self._fresh_cache)
        out = []
        for x, f, axis in zip(leaves, fresh, self._slot_axes):
            if axis is None:
                out.append(x)
            else:
                idx = (slice(None),) * axis + (slot,)
                out.append(x.at[idx].set(f[idx]))
        clen = self.cache["len"]
        self.cache = jax.tree_util.tree_unflatten(self._treedef, out)
        self.cache["len"] = clen

    def _prefill_token(self, slot: int, tok: int) -> None:
        """Single-slot prefill through the bucket-1 decode path (simple and
        correct; a production engine would run chunked prefill)."""
        self._launch(np.array([slot]), np.array([tok], np.int32))

    # -- the aggregated decode launch ---------------------------------------
    def _decode_fn(self, bucket: int):
        fn = self._decode.get(bucket)
        if fn is None:
            cfg, params = self.cfg, self.params

            def fwd(cache, slot_idx, toks):
                leaves = jax.tree_util.tree_leaves(cache)
                sub_leaves = [
                    x if ax is None else jnp.take(x, slot_idx, axis=ax)
                    for x, ax in zip(leaves, self._slot_axes)]
                sub = jax.tree_util.tree_unflatten(self._treedef, sub_leaves)
                logits, sub = model_mod.decode_step(cfg, params, sub,
                                                    toks[:, None])
                new_leaves = []
                for full, part, ax in zip(leaves,
                                          jax.tree_util.tree_leaves(sub),
                                          self._slot_axes):
                    if ax is None:
                        new_leaves.append(full)
                    else:
                        sl = (slice(None),) * ax + (slot_idx,)
                        new_leaves.append(full.at[sl].set(part))
                new_cache = jax.tree_util.tree_unflatten(self._treedef,
                                                         new_leaves)
                return logits, new_cache

            fn = jax.jit(fwd)
            self._decode[bucket] = fn
        return fn

    def _launch(self, slots: np.ndarray, toks: np.ndarray) -> np.ndarray:
        n = len(slots)
        bucket = length_bucket(n, self.buckets)
        pad = bucket - n
        if pad:
            # pad lanes target a FREE slot (one must exist when n < bucket
            # <= max_batch): they scatter garbage into a slot whose cache is
            # reset on admission, never into a live request's chunk.
            spare = next(s for s in range(self.max_batch)
                         if s not in set(slots.tolist()))
            slots_in = np.concatenate([slots, np.full(pad, spare, np.int64)])
            toks_in = np.concatenate([toks, np.zeros(pad, np.int32)])
        else:
            slots_in, toks_in = slots, toks
        logits, new_cache = self._decode_fn(bucket)(
            self.cache, jnp.asarray(slots_in), jnp.asarray(toks_in))
        logits = logits[:n]
        self._step_no += 1
        if self._injector is not None:
            # payload site at the serving layer: one tenant's logits row
            # goes non-finite (a poisoned request), keyed by request id
            rids = [self.active[s].rid for s in slots.tolist()]
            hit = self._injector.poison_positions("decode", self._step_no,
                                                  rids)
            if hit:
                logits = poison_slots(logits, sorted(hit), hit)
        if self.guard == "finite":
            logits = self._guard_rows(slots, logits)
        self.stats["launches"] += 1
        h = self.stats["aggregated_hist"]
        h[bucket] = h.get(bucket, 0) + 1
        self.cache = new_cache
        return np.asarray(jnp.argmax(logits, axis=-1))

    def _guard_rows(self, slots: np.ndarray, logits) -> jnp.ndarray:
        """ONE scalar finite-check per aggregated launch; only a trip pays
        for the per-row verdict.  A non-finite row belongs to exactly one
        request (slot-array decode is batch-exact): that request is marked
        failed and EVICTED, its slot recycled, while the co-batched
        tenants' rows — untouched by the offender — decode on normally.
        The evicted slot's cache garbage is harmless: admission re-zeroes
        a slot's state before reuse."""
        n = int(logits.shape[0])
        if bool(jnp.all(jnp.isfinite(logits))):
            return logits
        self.stats["faults"]["trips"] += 1
        row_ok = np.asarray(jnp.all(jnp.isfinite(logits.reshape(n, -1)),
                                    axis=1))
        for i, slot in enumerate(slots.tolist()):
            if row_ok[i]:
                continue
            req = self.active[slot]
            req.failed = True
            req.done = True
            req.error = (f"request {req.rid}: non-finite logits at decode "
                         f"step {self._step_no} (slot {slot}) — evicted")
            del self.active[slot]
            self.slots_free.append(slot)
            self.stats["faults"]["evicted"] += 1
        # keep argmax well-defined on the dead rows (their token is never
        # delivered — the owning request is already gone)
        return jnp.nan_to_num(logits, nan=0.0, posinf=0.0, neginf=0.0)

    # -- engine loop ---------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, aggregate, launch, collect."""
        self._admit()
        if not self.active:
            return 0
        slots = np.array(sorted(self.active.keys()))
        toks = self.next_token[slots]
        out = self._launch(slots, toks)
        finished = []
        for i, slot in enumerate(slots):
            req = self.active.get(slot)
            if req is None:           # evicted by the guard mid-launch
                continue
            tok = int(out[i])
            req.output.append(tok)
            self.next_token[slot] = tok
            if len(req.output) >= req.max_new_tokens:
                req.done = True
                finished.append(slot)
        for slot in finished:
            del self.active[slot]
            self.slots_free.append(slot)
        self.stats["tokens"] += len(slots)
        return len(slots)

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if not self.pending and not self.active:
                break
            self.step()
