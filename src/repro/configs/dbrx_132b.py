"""DBRX-132B [hf:databricks/dbrx-base] (fine-grained MoE).

40L, d_model 6144, 48H GQA (8 KV), per-expert d_ff 10752, vocab 100352,
16 experts with top-4 routing.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
)
