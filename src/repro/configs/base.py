"""Config dataclasses for models, shapes, parallelism and aggregation.

Every assigned architecture is expressed as a ``ModelConfig``; the paper's own
hydro scenario is a ``HydroConfig``.  ``ShapeConfig`` captures the assigned
(seq_len, global_batch, kind) cells.  ``reduced()`` shrinks any ModelConfig to
a CPU-smoke-testable size while preserving the family-specific structure.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Optional, Tuple


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | encdec | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    sliding_window: int = 0           # 0 -> full attention; >0 -> SWA (h2o-danube)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    shared_expert_d_ff: int = 0       # qwen2-moe shared expert width
    # --- SSM / xLSTM / Mamba2 ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256              # chunked-scan block size (S1 knob)
    slstm_every: int = 0              # xlstm: every k-th block is sLSTM
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0        # one shared attn+MLP block every k layers
    # --- enc-dec (seamless backbone) ---
    n_encoder_layers: int = 0
    encoder_seq_ratio: int = 1        # encoder frames per decoder token (stub)
    # --- vlm ---
    cross_attn_every: int = 0         # every k-th layer is an image cross-attn layer
    vision_tokens: int = 0            # stub patch-embedding count
    mlp_gated: bool = True            # SwiGLU (3 mats) vs plain MLP (2 mats)
    # --- numerics ---
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for 6ND model flops) ----------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts routed experts
        at top_k/n_experts utilisation (MoE active params)."""
        d, h = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        attn = d * (nq * h) + 2 * d * (nkv * h) + (nq * h) * d
        n_ff_mats = 3 if self.mlp_gated else 2
        if self.family in ("ssm", "hybrid"):
            # mamba2 / mLSTM block: in_proj (2*expand*d + extras) + out_proj
            inner = self.ssm_expand * d
            mixer = d * (2 * inner + 2 * self.ssm_state + self.n_heads) + inner * d
        else:
            mixer = attn
        if self.n_experts:
            ff_one = n_ff_mats * d * self.d_ff              # SwiGLU expert
            routed = self.n_experts * ff_one
            if active_only:
                routed = self.top_k * ff_one
            shared = self.n_shared_experts * n_ff_mats * d * (self.shared_expert_d_ff or self.d_ff)
            ff = routed + shared + d * self.n_experts       # router
        elif self.d_ff:
            ff = n_ff_mats * d * self.d_ff
        else:
            ff = 0
        if self.shared_attn_every:
            # zamba2: FFN lives only in the *shared* attn+MLP block (1 copy).
            total = self.n_layers * (mixer + 2 * d) + (attn + ff + 2 * d)
        else:
            per_layer = mixer + ff + 2 * d
            total = self.n_layers * per_layer
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + ff + 2 * d)
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(total)


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a shape cell applies to an architecture (per spec rules)."""
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.sliding_window > 0
        )
        if not sub_quadratic:
            return False, "pure full-attention arch: 500k dense-KV decode excluded per spec"
    return True, ""


# ---------------------------------------------------------------------------
# Parallelism / aggregation configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    """How to map a model onto the mesh (axes: optional pod, data, model)."""
    fsdp: bool = True                 # shard params/opt-state over data axis
    tensor_parallel: bool = True      # shard heads/ffn over model axis
    expert_parallel: bool = True      # shard MoE experts over model axis
    sequence_parallel: bool = False   # shard long sequences over data axis
    remat_policy: str = "dots"        # "none" | "dots" | "full"
    grad_compression: str = "none"    # "none" | "int8"
    microbatch: int = 0               # 0 -> no gradient accumulation


@dataclass(frozen=True)
class AggregationConfig:
    """The paper's three strategies, expressed as runtime knobs.

    strategy 1: ``subgrid_size`` (hydro) / ``ssm_chunk`` / microbatch (LM)
    strategy 2: ``n_executors``  — concurrent small launches
    strategy 3: ``max_aggregated`` — on-the-fly fusion cap (bucketed)
    """
    strategy: str = "s3"              # "s1" | "s2" | "s3" | "s2+s3"
    n_executors: int = 1
    max_aggregated: int = 32
    buckets: Tuple[int, ...] = ()     # () -> powers of two up to max_aggregated
    launch_watermark: int = 1         # queue depth that forces a launch
    # How aggregated task inputs reach the bucketed kernel (DESIGN.md §3):
    # "device" — slot-ring / indexed-gather staging, fully device-resident;
    # "host"   — the seed's slice -> host-stack -> launch cycle (kept as the
    #            measurable baseline for benchmarks/launch_overhead.py).
    staging: str = "device"
    # Per-region ladder auto-tuning (DESIGN.md §9): after ``autotune_warmup``
    # complete waves, each region re-derives its bucket ladder from the
    # observed queue-length histogram, minimizing expected launches per wave
    # under an AOT-compile budget of ``compile_budget`` distinct bucket
    # programs (bucket 1 is always kept: no-padding invariant).
    autotune: bool = False
    autotune_warmup: int = 2          # complete waves per region before retune
    compile_budget: int = 4           # max distinct bucket sizes per ladder
    # Mega-bucket evaluation: a bucketed program evaluates its body over the
    # slot axis in sequential chunks of ``inner_chunk`` slots (one lax.map
    # inside ONE launch) instead of one flat vmap.  0 = flat; "auto" = timed
    # selection at warmup.  Chunked evaluation is bit-identical to flat
    # (elementwise batch split; tests pin it) but keeps the working set of
    # stencil-heavy bodies cache-sized, which is what lets one bucket-64
    # launch beat 64 per-task launches.
    inner_chunk: object = 0           # int, or "auto"
    # Epilogue fusion (DESIGN.md §9): strategies that implement ``run_stage``
    # drive RK stages through each family's epilogue-fused twin (gather ->
    # body -> stage update as ONE program per bucket) when the scenario
    # declares per-slot epilogues.  Off by default: the fused path is
    # bit-identical to its own fused reference but reassociates ~1e-5
    # relative to the eager global stage arithmetic.
    fuse_epilogue: bool = False
    # Measured cost-model tuning (DESIGN.md §10): with ``cost_model=True``,
    # warmup/retune TIME each drain-reachable bucket program per region
    # (median of ``cost_samples`` runs on zero-filled inputs) and
    # ``derive_ladder`` minimizes *predicted wall time per wave* instead of
    # launch count — the device's cost structure, not a proxy.  Retune also
    # re-sweeps ``inner_chunk="auto"`` (the warmup-only choice of §9 is
    # superseded under this flag).  The per-region table is persisted into
    # ``stats["regions"][fam]["cost_model"]``.
    cost_model: bool = False
    cost_samples: int = 3             # timed runs per bucket (median taken)
    # When an underlying executor goes idle below the cap, should a partial
    # queue drain early?  "eager" — always (the paper's launch criterion,
    # the default); "watermark" — only once the queue reaches the region's
    # *learned* wave peak (adaptive watermark: partial buckets stop leaking
    # once the steady wave size is known); "cost" — consult the measured
    # cost model and drain early only when the predicted wall time of the
    # split drain beats waiting for the fuller bucket.  Policies affect
    # WHEN launches fire, never submission order, so results stay
    # bit-identical to eager (flush() drains every queue regardless).
    # May also be a mapping {kernel: policy} for per-family policies
    # (resolved via resolve_family_option: exact kernel, then the "+epi"
    # stage twin's base kernel, then the "*" wildcard, then "eager").
    flush_policy: object = "eager"    # policy name, or {kernel: policy}
    # Per-family strategy routing (DESIGN.md §12): the "mixed" strategy
    # routes each kernel family independently to "s2" (scatter ring),
    # "s3" (bucketed aggregation through the executor) or "fused" (one
    # whole-family launch).  ``None`` / missing kernels mean "auto": pick
    # from measured cost (``select_strategy``) when ``cost_model=True``,
    # else default to "s3".  Keys resolve like flush_policy mappings.
    family_strategies: Optional[Mapping[str, str]] = None
    # Blast-radius containment (DESIGN.md §11): with ``guard="finite"``,
    # ``flush()`` runs ONE scalar all-finite check per drained launch; a
    # tripped bucket is re-executed by bisection down the ladder until the
    # offending slot(s) are isolated — surviving futures are fulfilled
    # bit-identically (batch decomposition is exact), only culprits are
    # marked failed.  "off" (default) adds zero work to the hot path.
    guard: str = "off"                # "off" | "finite"
    # Degraded-mode policy: a launch-site failure is retried up to
    # ``max_bucket_retries`` times (exponential backoff from
    # ``retry_backoff_s``); a bucket whose compile fails — or whose
    # launches keep failing past the retries — is banned from the ladder
    # and its tasks re-drained through smaller rungs (bucket 1 is never
    # banned: it is the per-task degraded floor).  A task index tripping
    # the guard ``quarantine_threshold`` times is quarantined: later
    # bisections short-circuit it straight to a per-task re-execution.
    max_bucket_retries: int = 2
    retry_backoff_s: float = 0.0
    quarantine_threshold: int = 2
    # Persistent warm start (DESIGN.md §13): ``tune_store`` roots the
    # on-disk TuneStore (a directory path or TuneStore instance; None
    # consults the REPRO_TUNE_STORE env var, unset = cold start).  A
    # populated store lets ``warmup`` LOAD each region's tuned state
    # (ladder, inner chunk, cost tables, strategy selection) instead of
    # measuring it, and points JAX's persistent compilation cache at the
    # store dir so bucket compiles become disk hits; ``retune()`` writes
    # refreshed measurements back.  ``prior="roofline"`` seeds regions
    # the store cannot warm (first contact) with analytical
    # bytes-moved/FLOPs estimates, so ``derive_ladder`` has a sane
    # wall-time objective before the first measured wave.
    tune_store: object = None         # path | TuneStore | None
    prior: str = "off"                # "off" | "roofline"

    def bucket_sizes(self) -> Tuple[int, ...]:
        if self.buckets:
            return validate_ladder(self.buckets, self.max_aggregated)
        out, b = [], 1
        while b < self.max_aggregated:
            out.append(b)
            b *= 2
        out.append(self.max_aggregated)
        return tuple(dict.fromkeys(out))


def validate_ladder(buckets, cap: int) -> Tuple[int, ...]:
    """Validate a custom bucket ladder: positive ints, deduped, sorted
    ascending, containing 1, none above the ``max_aggregated`` cap.

    Bucket 1 is non-negotiable: the greedy drain covers any queue length k
    exactly only if a remainder of 1 has a bucket — a ladder like (4, 8)
    with 3 queued tasks would otherwise launch a 4-bucket over one garbage
    slot (the ``_largest_bucket`` over-launch bug this guard exists for).
    """
    b = tuple(int(x) for x in buckets)
    problems = []
    if any(x <= 0 for x in b):
        problems.append("all bucket sizes must be positive")
    if len(set(b)) != len(b):
        problems.append("bucket sizes must be unique")
    if list(b) != sorted(b):
        problems.append("bucket sizes must be sorted ascending")
    if 1 not in b:
        problems.append(
            "the ladder must contain bucket size 1 — the greedy drain "
            "needs it to cover remainders exactly (no padding, no launch "
            "over garbage slots)")
    if b and max(b) > cap:
        problems.append(
            f"bucket {max(b)} exceeds max_aggregated={cap} and could "
            f"never launch — raise max_aggregated or drop the bucket")
    if problems:
        raise ValueError(
            f"invalid bucket ladder {buckets!r}: " + "; ".join(problems))
    return b


# valid targets of per-family strategy routing (the "mixed" strategy);
# "auto" defers to the measured cost model (DESIGN.md §12)
FAMILY_STRATEGY_CHOICES = ("s2", "s3", "fused", "auto")


def resolve_family_option(value, kernel: str, default):
    """Resolve a possibly per-family (mapping-valued) config knob for one
    kernel family.  Lookup order: the exact kernel id, then — for an
    epilogue-fused stage twin ``<base>+epi`` — its base kernel, then the
    ``"*"`` wildcard, then ``default``.  A plain (non-mapping) value
    applies to every family; ``None`` means ``default``."""
    if value is None:
        return default
    if not isinstance(value, Mapping):
        return value
    if kernel in value:
        return value[kernel]
    if kernel.endswith("+epi"):
        base = kernel[:-len("+epi")]
        if base in value:
            return value[base]
    return value.get("*", default)


# ---------------------------------------------------------------------------
# Hydro (paper scenario) config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HydroConfig:
    """Octo-Tiger-style Sedov blast-wave scenario (paper Table II)."""
    name: str = "sedov"
    subgrid: int = 8                  # cells per edge (strategy-1 knob)
    ghost: int = 3                    # ghost-layer thickness (PPM needs 3)
    levels: int = 3                   # octree levels with AMR off
    n_fields: int = 5                 # rho, Sx, Sy, Sz, E
    gamma: float = 7.0 / 5.0
    cfl: float = 0.4
    blast_energy: float = 1.0
    rho0: float = 1.0
    domain: float = 1.0               # cube edge length
    # paper runs double precision on GPU; the TPU adaptation uses fp32
    # (conservation still holds to fp32 machine precision — tests enforce)
    dtype: str = "float32"

    @property
    def grids_per_edge(self) -> int:
        # AMR off: full octree with `levels` refinement levels below the root
        # has 2^levels leaf sub-grids per edge.  Paper Table II: 3 levels of
        # 8^3 grids -> 512 leaves; 2 levels of 16^3 -> 64 leaves (same cells).
        return 2 ** self.levels

    @property
    def n_subgrids(self) -> int:
        return self.grids_per_edge ** 3

    @property
    def cells_total(self) -> int:
        return self.n_subgrids * self.subgrid ** 3

    @property
    def padded(self) -> int:
        return self.subgrid + 2 * self.ghost


@dataclass(frozen=True)
class AMRHydroConfig:
    """Two-level refined Sedov scenario: a coarse grid over the whole domain
    plus one centred fine patch at ``refine_ratio``-times the resolution
    (the smallest genuinely adaptive task structure — the regime the paper's
    aggregation machinery exists for, per the follow-up AMR work
    arXiv:2412.15518).

    The fine level covers the central ``cover`` coarse cells per edge.  Each
    level decomposes into its own sub-grids; per-level cell width ``h`` is a
    *traced* task argument, so levels whose sub-grid shapes agree share one
    compiled bucket family (one ``TaskSignature``), while mixed sub-grid
    sizes produce two families aggregating concurrently through one
    executor.
    """
    name: str = "amr_sedov"
    coarse_subgrid: int = 8           # cells per coarse sub-grid edge
    fine_subgrid: int = 8             # cells per fine sub-grid edge
    ghost: int = 3                    # ghost-layer thickness (PPM needs 3)
    coarse_grids_per_edge: int = 2    # coarse level: (2*8)^3 cells
    cover: int = 8                    # coarse cells per edge under the patch
    refine_ratio: int = 2
    n_fields: int = 5
    gamma: float = 7.0 / 5.0
    cfl: float = 0.4
    blast_energy: float = 1.0
    rho0: float = 1.0
    domain: float = 1.0
    dtype: str = "float32"

    def __post_init__(self):
        if self.n_fine % self.fine_subgrid:
            raise ValueError("fine grid not divisible into fine sub-grids")
        if (self.n_coarse - self.cover) % 2:
            raise ValueError("fine patch cannot be centred on the coarse grid")
        # the prolongation ghost band must stay inside the coarse domain
        if self.offset < self.coarse_ghost_pad:
            raise ValueError("fine patch too close to the domain boundary "
                             "for the coarse-fine ghost exchange")

    @property
    def n_coarse(self) -> int:
        return self.coarse_grids_per_edge * self.coarse_subgrid

    @property
    def n_fine(self) -> int:
        return self.cover * self.refine_ratio

    @property
    def fine_grids_per_edge(self) -> int:
        return self.n_fine // self.fine_subgrid

    @property
    def offset(self) -> int:
        """Fine-patch origin, in coarse cells."""
        return (self.n_coarse - self.cover) // 2

    @property
    def h_coarse(self) -> float:
        return self.domain / self.n_coarse

    @property
    def h_fine(self) -> float:
        return self.h_coarse / self.refine_ratio

    @property
    def coarse_ghost_pad(self) -> int:
        """Coarse cells needed to prolongate one fine ghost band (ceil)."""
        return -(-self.ghost // self.refine_ratio)

    @property
    def n_subgrids_coarse(self) -> int:
        return self.coarse_grids_per_edge ** 3

    @property
    def n_subgrids_fine(self) -> int:
        return self.fine_grids_per_edge ** 3


@dataclass(frozen=True)
class GravityHydroConfig:
    """Self-gravitating Sedov scenario: every iteration submits TWO kernel
    families — the hydro Reconstruct+Flux tasks and a per-sub-grid gravity
    solve (``repro.kernels.gravity``) — interleaved through one
    ``AggregationExecutor``, the cross-solver aggregation Octo-Tiger's
    runtime performs with its hydro and FMM kernels.
    """
    name: str = "gravity_sedov"
    hydro: HydroConfig = field(default_factory=HydroConfig)
    g_const: float = 1.0              # gravitational constant (scaled units)
    relax_iters: int = 8              # Jacobi sweeps per gravity task


__all__ = [
    "ModelConfig", "ShapeConfig", "ParallelConfig", "AggregationConfig",
    "validate_ladder", "FAMILY_STRATEGY_CHOICES", "resolve_family_option",
    "HydroConfig", "AMRHydroConfig", "GravityHydroConfig",
    "ALL_SHAPES", "SHAPES_BY_NAME",
    "shape_applicable",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
