"""SeamlessM4T-large v2 text backbone [arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large].

Encoder-decoder transformer backbone: 24 encoder + 24 decoder layers,
d_model 1024, 16H (MHA), d_ff 8192, vocab 256206.  The audio frontend
(w2v-BERT conformer) is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings at d_model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,               # decoder layers
    n_encoder_layers=24,
    encoder_seq_ratio=2,       # stub: 2 audio frames per target token
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    mlp_gated=False,
    vocab_size=256206,
)
