"""Llama-3.2-Vision-90B backbone [hf:meta-llama/Llama-3.2-90B-Vision].

100 total layers (80 self-attention + 20 image cross-attention, every 5th),
d_model 8192, 64H GQA (8 KV), d_ff 28672, vocab 128256.  The vision tower is
a STUB per the assignment: ``input_specs`` provides precomputed patch/tile
embeddings already projected to d_model (4 tiles x 1601 patches).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    vision_tokens=6404,       # 4 tiles x 1601 patches
    rope_theta=500_000.0,
)
