"""H2O-Danube-1.8B [arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base].

Llama+Mistral mix with sliding-window attention: 24L, d_model 2560, 32H GQA
(8 KV), d_ff 6912, vocab 32000, window 4096.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10_000.0,
)
