"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16H (MHA: kv=16), per-expert d_ff 1408, vocab 151936,
60 routed experts top-4 + 4 shared experts (shared width 5632).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    top_k=4,
    # 4 shared experts of width 1408 (= 5632 fused); the implementation fuses
    # them into one SwiGLU GEMM -- the paper's aggregation applied to the
    # always-on experts.
    n_shared_experts=4,
    shared_expert_d_ff=1408,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
