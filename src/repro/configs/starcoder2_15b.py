"""StarCoder2-15B [arXiv:2402.19173; hf:bigcode/starcoder2-15b].

Dense decoder LM: 40L, d_model 6144, 48 query heads with GQA (4 KV heads),
d_ff 24576, vocab 49152, RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=100_000.0,
    mlp_gated=False,
)
