"""Two-level refined Sedov blast (the genuinely adaptive workload).

Two instances:

* ``CONFIG``       — both levels use 8^3 sub-grids.  Per-task shapes agree,
  so coarse and fine tasks share ONE ``TaskSignature`` family: the same
  compiled bucket programs serve both levels (per-level cell width ``h`` is
  a traced task argument, not a compile-time constant).
* ``CONFIG_MIXED`` — the coarse level is a single 16^3 sub-grid while the
  fine level stays 8^3: two distinct ``TaskSignature`` families aggregate
  concurrently through one executor (distinct rings, buckets and compile
  caches — the multi-region runtime's raison d'etre).

Both refine the central half of the domain at 2x resolution, which fully
contains the Sedov blast sphere.
"""
from repro.configs.base import AMRHydroConfig

CONFIG = AMRHydroConfig()

CONFIG_MIXED = AMRHydroConfig(name="amr_sedov_mixed", coarse_subgrid=16,
                              coarse_grids_per_edge=1)
