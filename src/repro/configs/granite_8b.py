"""IBM Granite-8B-Code [arXiv:2405.04324; hf:ibm-granite/granite-8b-code-base].

Llama-architecture dense LM: 36L, d_model 4096, 32H GQA (8 KV), d_ff 14336,
vocab 49152, SwiGLU + RMSNorm + RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
)
