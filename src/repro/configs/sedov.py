"""The paper's own benchmark scenario: Sedov-Taylor blast wave, AMR off.

Paper Table II: 8^3 sub-grids / 3 levels -> 512 leaves (262144 cells);
16^3 sub-grids / 2 levels -> 64 leaves (same 262144 cells).
"""
from repro.configs.base import HydroConfig

CONFIG = HydroConfig(name="sedov", subgrid=8, ghost=3, levels=3)
CONFIG_16 = HydroConfig(name="sedov16", subgrid=16, ghost=3, levels=2)
