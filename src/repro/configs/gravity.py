"""Self-gravitating Sedov blast (the cross-solver aggregation workload).

Two instances:

* ``CONFIG``       — 64 sub-grids of 8^3 (levels=2): the benchmark size.
* ``CONFIG_SMALL`` — 8 sub-grids of 8^3 (levels=1): CI/test size, where the
  greedy drain puts each family's whole iteration into one bucket-8 launch
  (making bit-exactness against the per-family fused reference directly
  assertable).

Both submit hydro ("hydro_rhs") and gravity ("gravity") tasks interleaved
into ONE ``AggregationExecutor`` per iteration — two ``TaskSignature``
families aggregating concurrently, per DESIGN.md §8.
"""
from repro.configs.base import GravityHydroConfig, HydroConfig

CONFIG = GravityHydroConfig(hydro=HydroConfig(name="sedov", subgrid=8,
                                              ghost=3, levels=2))

CONFIG_SMALL = GravityHydroConfig(
    name="gravity_sedov_small",
    hydro=HydroConfig(name="sedov", subgrid=8, ghost=3, levels=1))
