"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

One module per assigned architecture (public-literature configs), plus the
paper's own Sedov hydro scenario.
"""
from __future__ import annotations

from repro.configs.base import (
    ALL_SHAPES, SHAPES_BY_NAME, AggregationConfig, HydroConfig, ModelConfig,
    ParallelConfig, ShapeConfig, shape_applicable,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

from repro.configs.starcoder2_15b import CONFIG as starcoder2_15b
from repro.configs.granite_8b import CONFIG as granite_8b
from repro.configs.qwen1_5_32b import CONFIG as qwen1_5_32b
from repro.configs.h2o_danube_1_8b import CONFIG as h2o_danube_1_8b
from repro.configs.dbrx_132b import CONFIG as dbrx_132b
from repro.configs.qwen2_moe_a2_7b import CONFIG as qwen2_moe_a2_7b
from repro.configs.xlstm_125m import CONFIG as xlstm_125m
from repro.configs.seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from repro.configs.zamba2_2_7b import CONFIG as zamba2_2_7b
from repro.configs.llama_3_2_vision_90b import CONFIG as llama_3_2_vision_90b
from repro.configs.sedov import CONFIG as sedov, CONFIG_16 as sedov_16

ARCHS = {
    c.name: c for c in (
        starcoder2_15b, granite_8b, qwen1_5_32b, h2o_danube_1_8b,
        dbrx_132b, qwen2_moe_a2_7b, xlstm_125m, seamless_m4t_large_v2,
        zamba2_2_7b, llama_3_2_vision_90b,
    )
}


def get_config(name: str) -> ModelConfig:
    key = name.replace("-", "_").replace(".", "_")
    for cfg in ARCHS.values():
        if cfg.name == name or cfg.name.replace("-", "_").replace(".", "_") == key:
            return cfg
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to a CPU-smoke-testable size, preserving family
    structure (MoE stays MoE with fewer experts, hybrid keeps its period,
    enc-dec keeps both stacks, ...)."""
    kw = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        remat=False,
        dtype="float32",
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2),
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  shared_expert_d_ff=128 if cfg.shared_expert_d_ff else 0,
                  d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_chunk=16)
    if cfg.slstm_every:
        kw.update(slstm_every=2)
    if cfg.shared_attn_every:
        kw.update(shared_attn_every=2, n_layers=4)
    if cfg.n_encoder_layers:
        kw.update(n_encoder_layers=2)
    if cfg.cross_attn_every:
        kw.update(cross_attn_every=2, n_layers=4, vision_tokens=8)
    if cfg.sliding_window:
        kw.update(sliding_window=8)
    return cfg.replace(**kw)


__all__ = [
    "ARCHS", "get_config", "reduced",
    "ModelConfig", "ShapeConfig", "ParallelConfig", "AggregationConfig",
    "HydroConfig", "ALL_SHAPES", "SHAPES_BY_NAME", "shape_applicable",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "sedov", "sedov_16",
]
