"""xLSTM-125M [arXiv:2405.04517].

12 blocks, d_model 768, 4 heads, vocab 50304 (GPT-NeoX tokenizer, padded).
xLSTM[7:1]-style mix: every 4th block is an sLSTM block, the rest are mLSTM
(matrix-memory, chunked-parallel).  d_ff=0: blocks carry their own
up/down projections (proj_factor 2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=0,
    d_ff=0,
    vocab_size=50304,
    ssm_state=0,          # mLSTM memory is (head_dim x head_dim); no extra state dim
    ssm_expand=2,
    ssm_chunk=256,
    slstm_every=4,
    tie_embeddings=True,
)
