"""Zamba2-2.7B [arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B].

Hybrid: 54 Mamba2 (SSD) layers, d_model 2560, ssm_state 64, with one *shared*
attention+MLP block (32 heads, d_ff 10240) invoked every 6 Mamba layers
(9 invocations sharing one set of weights).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=256,
    shared_attn_every=6,
    tie_embeddings=True,
)
