from repro.distributed.api import (
    ShardingRules, constrain, current_rules, logical_rules, spec_for,
)
from repro.distributed.fault_tolerance import (
    SimulatedFailure, make_dp_train_step, rescale_state, residual_init,
    resilient_loop,
)

__all__ = [
    "ShardingRules", "constrain", "current_rules", "logical_rules",
    "spec_for", "SimulatedFailure", "make_dp_train_step", "rescale_state",
    "residual_init", "resilient_loop",
]
