"""Logical-axis sharding API (MaxText-style, mesh-agnostic model code).

Model code calls ``constrain(x, "batch", "seq", "embed")``; a context manager
installs the logical->mesh translation.  Outside any context this is a no-op,
so smoke tests and single-device runs never touch device state.

Divisibility-aware: a logical axis only maps to mesh axes whose size divides
the corresponding array dimension — otherwise that dimension is replicated
(needed e.g. for 4-KV-head GQA on a 16-way model axis, or vocab 256206).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]

# Default logical rules.  "pod" and "data" jointly form the DP/FSDP domain;
# "model" is the TP/EP domain.
DEFAULT_RULES: Dict[str, AxisSpec] = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data"),    # flattened batch*seq (MoE dispatch)
    "seq": None,                  # activations inside a block: full sequence
    "seq_sp": ("model",),         # residual stream BETWEEN blocks: Megatron-SP
    "kv_seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "fsdp": ("pod", "data"),      # parameter sharding domain (ZeRO-3)
    "tp": ("model",),
    "subgrid": ("pod", "data"),   # hydro: sub-grids distribute like batch
    # expert-capacity rows: model-axis fallback when the expert count
    # doesn't divide it.  NOT the DP axes: the dispatch scatter's source is
    # token-sharded over (pod, data), and XLA SPMD replicates scatters whose
    # source and destination are sharded over the same axis on different
    # dims (measured: 428 GB/device for dbrx — see EXPERIMENTS.md §Perf,
    # refuted hypothesis A2).
    "capacity": ("model",),
    "state": None,
    "replicated": None,
}


@dataclass
class ShardingRules:
    mesh: Optional[Mesh] = None
    rules: Dict[str, AxisSpec] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def axis_size(self, spec: AxisSpec) -> int:
        if spec is None or self.mesh is None:
            return 1
        names = (spec,) if isinstance(spec, str) else spec
        n = 1
        for a in names:
            n *= self.mesh.shape.get(a, 1)
        return n


_tls = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def logical_rules(mesh: Optional[Mesh], overrides: Optional[Dict[str, AxisSpec]] = None):
    prev = current_rules()
    r = ShardingRules(mesh=mesh)
    if overrides:
        r.rules.update(overrides)
    _tls.rules = r
    try:
        yield r
    finally:
        _tls.rules = prev


def _resolve(ctx: ShardingRules, dim_size: int, name: Optional[str],
             used: set) -> AxisSpec:
    if name is None:
        return None
    spec = ctx.rules.get(name)
    if spec is None:
        return None
    names = (spec,) if isinstance(spec, str) else tuple(spec)
    # keep the longest sub-sequence of *available* mesh axes whose product
    # divides the dimension (axes already used by another dim are skipped,
    # not fatal — e.g. kv_seq=(pod,data,model) falls back to (model,) when
    # batch took pod+data)
    kept = []
    prod = 1
    for a in names:
        if a in used:
            continue
        sz = ctx.mesh.shape.get(a, 1) if ctx.mesh else 1
        if sz == 1:
            continue
        if dim_size % (prod * sz) == 0:
            kept.append(a)
            prod *= sz
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def spec_for(shape: Sequence[int], names: Sequence[Optional[str]]) -> P:
    ctx = current_rules()
    assert ctx is not None
    assert len(shape) == len(names), (shape, names)
    used = set()
    out = []
    for d, n in zip(shape, names):
        s = _resolve(ctx, d, n, used)
        if s is not None:
            flat = (s,) if isinstance(s, str) else s
            used.update(flat)
        out.append(s)
    return P(*out)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a logical-axis sharding constraint; no-op without a context."""
    ctx = current_rules()
    if ctx is None or ctx.mesh is None:
        return x
    spec = spec_for(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
