"""Fault tolerance, elastic scaling, and distributed-optimization tricks.

Design for 1000+ nodes (DESIGN.md §5):

* **Checkpoint/restart** — `repro.checkpoint` writes atomic, step-indexed,
  *logically-shaped* checkpoints; restart re-sharding onto a different mesh
  (grow/shrink by pods) is ``restore_resharded``.  The data pipeline is
  (seed, step)-addressable so the restored trajectory is bit-exact.
* **Failure detection & retry** — ``resilient_step`` wraps the train step:
  on a device/runtime error it reloads the last checkpoint and replays.
  Synchronous SPMD means a lost chip is a lost *job* without this outer
  loop; the checkpoint cadence bounds lost work to ``save_every`` steps.
* **Straggler mitigation** — synchronous pjit collectives make per-step
  progress the min over chips.  The knobs here: (a) bucketed static shapes
  (no recompile jitter — the aggregation ladder), (b) backup-worker
  speculation is NOT applicable inside one XLA program, so mitigation moves
  to the *data* layer: deterministic batches mean any replacement worker can
  recompute a shard without coordination.
* **Gradient compression** — ``make_dp_train_step`` is the explicit-DP
  variant (shard_map over the data axis) that int8-compresses the cross-pod
  gradient all-reduce with error feedback (repro.optim.compression): 4x
  fewer bytes on the slowest links, the dominant §Roofline collective term
  for multi-pod training.
* **Compute/communication overlap** — the pjit path leans on XLA latency
  hiding (scan-over-layers lets weight all-gathers for layer i+1 overlap
  layer i's compute); the explicit path interleaves per-leaf compressed
  reductions with the optimizer update loop.
"""
from __future__ import annotations

import logging
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim.adamw import OptConfig, opt_update
from repro.optim.compression import compressed_allreduce

log = logging.getLogger("repro.ft")


# ---------------------------------------------------------------------------
# explicit-DP train step with compressed gradient reduction
# ---------------------------------------------------------------------------

def make_dp_train_step(loss_fn: Callable, opt_cfg: OptConfig, mesh: Mesh,
                       axis: str = "data", compress: bool = True):
    """shard_map DP train step: per-shard grads, (optionally int8) all-reduce,
    replicated update.  ``loss_fn(params, batch) -> scalar``."""
    from jax.experimental.shard_map import shard_map

    def step(params, opt_state, residual, batch):
        def shard_body(params, opt_state, residual, batch):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch))(params)
            loss = jax.lax.pmean(loss, axis)
            flat_g, tdef = jax.tree_util.tree_flatten(grads)
            flat_r = jax.tree_util.tree_leaves(residual)
            reduced, new_res = [], []
            for g, r in zip(flat_g, flat_r):
                if compress:
                    m, nr = compressed_allreduce(
                        g.astype(jnp.float32), axis, r)
                else:
                    m, nr = jax.lax.pmean(g.astype(jnp.float32), axis), r
                reduced.append(m)
                new_res.append(nr)
            grads = jax.tree_util.tree_unflatten(tdef, reduced)
            residual = jax.tree_util.tree_unflatten(tdef, new_res)
            new_p, new_s, metrics = opt_update(grads, opt_state, params,
                                               opt_cfg)
            return new_p, new_s, residual, loss, metrics

        rep = P()
        dp = P(axis)
        batch_spec = jax.tree_util.tree_map(lambda _: dp, batch)
        param_spec = jax.tree_util.tree_map(lambda _: rep, params)
        opt_spec = jax.tree_util.tree_map(lambda _: rep, opt_state)
        res_spec = jax.tree_util.tree_map(lambda _: rep, residual)
        return shard_map(
            shard_body, mesh=mesh,
            in_specs=(param_spec, opt_spec, res_spec, batch_spec),
            out_specs=(param_spec, opt_spec, res_spec, rep,
                       {"grad_norm": rep, "lr": rep}),
            check_rep=False,
        )(params, opt_state, residual, batch)

    return jax.jit(step, donate_argnums=(0, 1, 2))


def residual_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# resilient outer loop
# ---------------------------------------------------------------------------

class SimulatedFailure(RuntimeError):
    pass


def resilient_loop(step_fn: Callable, state: Tuple, n_steps: int, *,
                   save_every: int = 10,
                   save_fn: Optional[Callable] = None,
                   restore_fn: Optional[Callable] = None,
                   failure_hook: Optional[Callable[[int], None]] = None,
                   max_retries: int = 3) -> Tuple[Tuple, Dict[str, Any]]:
    """Run ``state = step_fn(state, step)`` with checkpoint/replay recovery.

    ``failure_hook(step)`` may raise ``SimulatedFailure`` (tests inject node
    loss); real deployments see ``jax.errors.JaxRuntimeError`` from a dead
    chip.  Recovery = restore last checkpoint + replay (deterministic data
    makes the replay exact).
    """
    stats = {"failures": 0, "restores": 0, "saved_steps": []}
    step = 0
    last_saved = None
    retries = 0
    while step < n_steps:
        try:
            if failure_hook is not None:
                failure_hook(step)
            state = step_fn(state, step)
            if save_fn is not None and (step + 1) % save_every == 0:
                save_fn(state, step + 1)
                last_saved = step + 1
                stats["saved_steps"].append(step + 1)
                retries = 0
            step += 1
        except (SimulatedFailure, jax.errors.JaxRuntimeError) as e:
            stats["failures"] += 1
            retries += 1
            if retries > max_retries:
                raise RuntimeError(
                    f"unrecoverable: {retries} consecutive failures") from e
            if restore_fn is not None and last_saved is not None:
                log.warning("step %d failed (%s); restoring step %d",
                            step, e, last_saved)
                state = restore_fn(last_saved)
                step = last_saved
                stats["restores"] += 1
            else:
                log.warning("step %d failed (%s); replaying step", step, e)
    return state, stats


# ---------------------------------------------------------------------------
# elastic re-scale
# ---------------------------------------------------------------------------

def rescale_state(params, opt_state, new_mesh: Mesh, spec_fn: Callable):
    """Re-place (params, opt_state) onto a new mesh (pod gained/lost).

    ``spec_fn(tree, mesh) -> tree of NamedSharding`` — the same rules used at
    startup, evaluated against the new mesh.
    """
    p_spec = spec_fn(params, new_mesh)
    o_spec = spec_fn(opt_state, new_mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, p_spec)
    opt_state = jax.tree_util.tree_map(jax.device_put, opt_state, o_spec)
    return params, opt_state
