from repro.data.pipeline import (
    DataConfig, SyntheticLMStream, make_batch_specs, length_bucket,
)

__all__ = ["DataConfig", "SyntheticLMStream", "make_batch_specs",
           "length_bucket"]
