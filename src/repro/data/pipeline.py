"""Deterministic synthetic data pipeline with aggregation-aware bucketing.

Production frameworks stream tokenized shards; offline we generate a
deterministic Zipf-distributed token stream with local n-gram structure (so
the loss actually decreases) keyed by ``(seed, step)``.  Determinism by step
index is what makes checkpoint/restart exact: the data "cursor" is just the
step counter, no iterator state to snapshot.

``length_bucket`` mirrors the paper's bucketing: variable-length requests
are rounded up to the nearest power-of-two bucket so a small set of compiled
shapes serves an unbounded request distribution (the static-shape analogue
of on-the-fly aggregation; same bucket ladder as AggregationConfig).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 256
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLMStream:
    """Deterministic (seed, step)-addressable LM batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = jnp.asarray(p / p.sum(), jnp.float32)
        # fixed "grammar": each token prefers a few successors
        key = jax.random.PRNGKey(cfg.seed ^ 0x5EED)
        self._succ = jax.random.randint(key, (cfg.vocab_size, 4), 0,
                                        cfg.vocab_size)

    def batch(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s = cfg.global_batch, cfg.seq_len
        base = jax.random.categorical(
            k1, jnp.log(self._p)[None, None, :], shape=(b, s))
        # 50% of positions follow the grammar: succ(prev_token)
        pick = jax.random.randint(k2, (b, s), 0, 4)
        follow = jax.random.bernoulli(k3, 0.5, (b, s))
        prev = jnp.roll(base, 1, axis=1)
        grammar = jnp.take_along_axis(self._succ[prev], pick[..., None],
                                      axis=-1)[..., 0]
        tokens = jnp.where(follow, grammar, base)
        labels = jnp.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels}


def length_bucket(n: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket >= n (static-shape aggregation ladder)."""
    for b in sorted(buckets):
        if b >= n:
            return b
    return max(buckets)


def make_batch_specs(cfg, shape, extra_dtype=jnp.bfloat16):
    """ShapeDtypeStructs for one (arch, shape) batch — used by the dry-run.

    Returns the dict of inputs ``train_step``/``serve_step`` consume.
    """
    from repro.configs.base import ModelConfig, ShapeConfig  # noqa
    b, s = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train" or shape.kind == "prefill":
        batch = {"tokens": sd((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = sd((b, s), jnp.int32)
        if cfg.family == "vlm":
            batch["vision"] = sd((b, cfg.vision_tokens, cfg.d_model),
                                 extra_dtype)
        if cfg.family == "audio":
            batch["frames"] = sd((b, s * cfg.encoder_seq_ratio, cfg.d_model),
                                 extra_dtype)
    else:  # decode: one new token against a seq_len cache
        batch = {"tokens": sd((b, 1), jnp.int32)}
    return batch
