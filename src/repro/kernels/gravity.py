"""Per-sub-grid gravity kernel (compact Poisson-relaxation body + Pallas twin).

Octo-Tiger aggregates TWO kernel families through the same runtime: the
hydro Reconstruct+Flux pair and the gravity (FMM) solver.  This module is
the gravity family for the repro: a compact per-sub-grid Poisson solve —
``n_iter`` Jacobi relaxation sweeps of ``laplace(phi) = 4 pi G rho`` on one
padded sub-grid with zero-Dirichlet values on the pad frame, followed by a
central-difference gradient — standing in for one FMM leaf interaction.
Like ``subgrid_rhs`` it is ONE fine-grained task body, sized for one core,
that every aggregation strategy re-granularizes; unlike the global FMM it
needs no cross-task coupling, which is exactly what makes it aggregable.

The cell width ``h`` is a *traced* per-task argument (matching
``repro.hydro.stepper.level_batched_body``'s convention), so one compiled
bucket serves every refinement level whose sub-grid shapes agree and the
body opens its own ``TaskSignature`` family — distinct from hydro's by
kernel id — when both are submitted to one ``AggregationExecutor``.

The Pallas twin (``gravity_pallas``, slot_grid layout) runs the same block
math with the aggregated-task axis as the kernel grid, validated bit-exact
against the jnp oracle in interpret mode (tests/test_gravity.py).
"""
from __future__ import annotations

import functools
from functools import lru_cache

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interior_mask(p: int):
    """(p, p, p) bool: True off the one-cell Dirichlet frame (2D+ iota only,
    Pallas-safe)."""
    ii = jax.lax.broadcasted_iota(jnp.int32, (p, p, p), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (p, p, p), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (p, p, p), 2)

    def inner(x):
        return (x > 0) & (x < p - 1)

    return inner(ii) & inner(jj) & inner(kk)


def _gravity_block(rho, h, *, ghost: int, subgrid: int, g_const: float,
                   n_iter: int):
    """Shared block math: (P, P, P) density + scalar h -> (4, S, S, S).

    Output fields are [phi, gx, gy, gz] over the interior, with
    ``g = -grad(phi)`` by central differences.  ``n_iter`` is static (the
    sweep loop unrolls); ``h`` may be traced.
    """
    p = rho.shape[-1]
    mask = _interior_mask(p)
    rhs = (4.0 * jnp.pi * g_const) * rho * (h * h)
    phi = jnp.zeros_like(rho)
    for _ in range(n_iter):
        nb = (jnp.roll(phi, 1, -3) + jnp.roll(phi, -1, -3)
              + jnp.roll(phi, 1, -2) + jnp.roll(phi, -1, -2)
              + jnp.roll(phi, 1, -1) + jnp.roll(phi, -1, -1))
        phi = jnp.where(mask, (nb - rhs) / 6.0, 0.0)
    inv2h = 0.5 / h
    gx = (jnp.roll(phi, 1, -3) - jnp.roll(phi, -1, -3)) * inv2h
    gy = (jnp.roll(phi, 1, -2) - jnp.roll(phi, -1, -2)) * inv2h
    gz = (jnp.roll(phi, 1, -1) - jnp.roll(phi, -1, -1)) * inv2h
    g, s = ghost, subgrid
    sl = (slice(g, g + s),) * 3
    return jnp.stack([phi[sl], gx[sl], gy[sl], gz[sl]])


def subgrid_gravity(u_padded, h, *, ghost: int, subgrid: int,
                    g_const: float = 1.0, n_iter: int = 8):
    """One gravity task: (F, P, P, P) conserved sub-grid -> (4, S, S, S)
    [phi, gx, gy, gz].  Only the density field feeds the solve, but the
    body takes the full padded sub-grid so hydro and gravity tasks can
    reference the SAME ghost-exchanged parent array."""
    return _gravity_block(u_padded[0], h, ghost=ghost, subgrid=subgrid,
                          g_const=g_const, n_iter=n_iter)


def gravity_source_update(u, dudt, pg, scale=None):
    """Add the gravity source to a hydro update: momentum gains
    ``rho * g`` and energy gains ``S . g`` — the coupling Octo-Tiger
    applies between its hydro and FMM solver families.  Pointwise, so it
    serves assembled global grids and per-slot interiors alike.

    ``scale=None`` adds the raw source (the rhs combine — kept
    multiplication-free so that path's bits never move); a traced scalar
    scales every term, which is how the epilogue-fused stage combine
    folds its ``c1 * dt`` factor in (DESIGN.md §10):
    ``c0*u0 + c1*(v + dt*(dudt + src)) == stage(dudt) + c1*dt*src``.
    """
    rho = u[0]
    gx, gy, gz = pg[1], pg[2], pg[3]
    terms = (rho * gx, rho * gy, rho * gz,
             u[1] * gx + u[2] * gy + u[3] * gz)
    if scale is not None:
        terms = tuple(scale * t for t in terms)
    return (dudt.at[1].add(terms[0])
                .at[2].add(terms[1])
                .at[3].add(terms[2])
                .at[4].add(terms[3]))


@lru_cache(maxsize=None)
def gravity_batched_body(ghost: int, subgrid: int, g_const: float = 1.0,
                         n_iter: int = 8):
    """The aggregation-region body: ``(k, F, P, P, P), (k,) -> (k, 4, S, S,
    S)`` with per-task traced h.  Cached so every runner / reference
    sharing the parameters gets the SAME callable (and compiled programs),
    mirroring ``repro.hydro.stepper.level_batched_body``."""
    def body(u_padded, h):
        return subgrid_gravity(u_padded, h, ghost=ghost, subgrid=subgrid,
                               g_const=g_const, n_iter=n_iter)
    return jax.vmap(body)


@lru_cache(maxsize=None)
def gravity_batched_jit(ghost: int, subgrid: int, g_const: float = 1.0,
                        n_iter: int = 8):
    """Jitted twin of :func:`gravity_batched_body` (per-family fused launch)."""
    return jax.jit(gravity_batched_body(ghost, subgrid, g_const, n_iter))


# ---------------------------------------------------------------------------
# Pallas kernel (slot_grid layout, per-slot traced h)
# ---------------------------------------------------------------------------

def _kernel_gravity_slot_grid_h(u_ref, h_ref, out_ref, *, ghost, subgrid,
                                g_const, n_iter):
    u = u_ref[0]                                  # (F, P, P, P)
    h = h_ref[0, 0]
    out_ref[0] = _gravity_block(u[0], h, ghost=ghost, subgrid=subgrid,
                                g_const=g_const, n_iter=n_iter)


def gravity_pallas(u_slots: jax.Array, h_slots: jax.Array, *, ghost: int,
                   subgrid: int, g_const: float = 1.0, n_iter: int = 8,
                   interpret: bool = True) -> jax.Array:
    """Aggregated gravity kernel: (slots, F, P, P, P) -> (slots, 4, S, S, S).

    slot_grid layout (one task per grid step, as in ``hydro_rhs_pallas``);
    per-slot cell widths stage through SMEM-shaped ``(1, 1)`` blocks.
    """
    n, f, p = u_slots.shape[0], u_slots.shape[1], u_slots.shape[2]
    s = subgrid
    h2d = jnp.reshape(h_slots, (n, 1))
    return pl.pallas_call(
        functools.partial(_kernel_gravity_slot_grid_h, ghost=ghost,
                          subgrid=subgrid, g_const=g_const, n_iter=n_iter),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, f, p, p, p), lambda i: (i, 0, 0, 0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 4, s, s, s), lambda i: (i, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 4, s, s, s), u_slots.dtype),
        interpret=interpret,
    )(u_slots, h2d)


def pallas_gravity_batched_body_h(ghost: int, subgrid: int,
                                  g_const: float = 1.0, n_iter: int = 8,
                                  interpret: bool = True):
    """Pallas-backed drop-in for :func:`gravity_batched_body` (same
    ``(u_slots, h_slots)`` calling convention) — registers as the gravity
    family's aggregation-region body on real TPU."""
    def batched(u_slots, h_slots):
        return gravity_pallas(u_slots, h_slots, ghost=ghost, subgrid=subgrid,
                              g_const=g_const, n_iter=n_iter,
                              interpret=interpret)
    return batched
