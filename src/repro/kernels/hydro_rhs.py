"""Aggregated hydro RHS Pallas kernel (Reconstruct + Flux, fused).

The paper's two dominant GPU kernels operate on one sub-grid each and write
the 26-direction reconstruction to device memory between them.  The
TPU-native adaptation fuses them: reconstruction values are recomputed
per-quadrature-entry inside VMEM instead of being staged through HBM.

Napkin math (8^3 sub-grid, f32): the unfused pair moves
``26*5*14^3*4 B = 1.43 MB`` of reconstruction data per sub-grid through HBM
twice (write + read); the fused kernel moves only the ``55 KB`` input and
``10 KB`` output — a ~50x cut in HBM traffic for ~2x recompute of the cheap
VPU stencil math.  On a 819 GB/s part this turns a memory-bound kernel pair
into a compute-bound single kernel.

Two block layouts are provided:

* ``slot_grid``  — grid iterates aggregated tasks; block = one padded
  sub-grid ``(1, F, P, P, P)``.  This is the direct port of the paper's GPU
  kernel (one block of work per task).
* ``slot_lane``  — the aggregated-task axis is the *minor (lane)* dimension:
  block ``(F, P, P, P, T)`` with T tasks vectorized across the 128 VPU
  lanes.  Aggregation does not just fill the device with blocks, it fills
  the vector unit — the TPU-native reading of "turn fine-grained tasks into
  one larger kernel".  (P=14 is lane-hostile: 14 pads to 128 lanes, wasting
  9x; slot-lane instead pads T to 8/128 which the bucket sizes match.)

Validated in interpret mode against ``ref.py`` (the pure-jnp oracle used by
the production XLA path).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.hydro.euler import N_FIELDS
from repro.hydro.flux import FACE_QUAD
from repro.hydro.ppm import DIR_PAIRS

_AXIS_VECS = [(1, 0, 0), (0, 1, 0), (0, 0, 1)]


def _shift(u, d: Tuple[int, int, int], k: int, axes: Tuple[int, int, int]):
    """u(i + k*d) via roll over the given spatial axes."""
    if k == 0 or d == (0, 0, 0):
        return u
    return jnp.roll(u, shift=(-k * d[0], -k * d[1], -k * d[2]), axis=axes)


def _ppm_side(u, d, side: int, axes):
    """CW84 limited-parabola surface value toward -d (side=0) or +d (side=1)."""
    um2 = _shift(u, d, -2, axes)
    um1 = _shift(u, d, -1, axes)
    up1 = _shift(u, d, 1, axes)
    up2 = _shift(u, d, 2, axes)
    ul = (7.0 / 12.0) * (um1 + u) - (1.0 / 12.0) * (um2 + up1)
    ur = (7.0 / 12.0) * (u + up1) - (1.0 / 12.0) * (um1 + up2)
    extremum = (ur - u) * (u - ul) <= 0.0
    du = ur - ul
    u6 = 6.0 * (u - 0.5 * (ul + ur))
    ul_lim = jnp.where(du * u6 > du * du, 3.0 * u - 2.0 * ur, ul)
    ur_lim = jnp.where(-(du * du) > du * u6, 3.0 * u - 2.0 * ul, ur)
    ul = jnp.where(extremum, u, ul_lim)
    ur = jnp.where(extremum, u, ur_lim)
    return ur if side else ul


def _prim(u, gamma):
    """u: (F, ...) -> rho, vx, vy, vz, p (field axis leading)."""
    rho = jnp.maximum(u[0], 1e-10)
    vx, vy, vz = u[1] / rho, u[2] / rho, u[3] / rho
    ke = 0.5 * rho * (vx * vx + vy * vy + vz * vz)
    p = jnp.maximum((gamma - 1.0) * (u[4] - ke), 1e-12)
    return rho, vx, vy, vz, p


def _phys_flux(u, axis, gamma):
    rho, vx, vy, vz, p = _prim(u, gamma)
    v = (vx, vy, vz)[axis]
    f = [rho * v, u[1] * v, u[2] * v, u[3] * v, (u[4] + p) * v]
    f[1 + axis] = f[1 + axis] + p
    return jnp.stack(f)


def _central_upwind(uL, uR, axis, gamma):
    rhoL, vxL, vyL, vzL, pL = _prim(uL, gamma)
    rhoR, vxR, vyR, vzR, pR = _prim(uR, gamma)
    vL = (vxL, vyL, vzL)[axis]
    vR = (vxR, vyR, vzR)[axis]
    cL = jnp.sqrt(gamma * pL / rhoL)
    cR = jnp.sqrt(gamma * pR / rhoR)
    ap = jnp.maximum(jnp.maximum(vL + cL, vR + cR), 0.0)
    am = jnp.minimum(jnp.minimum(vL - cL, vR - cR), 0.0)
    fL = _phys_flux(uL, axis, gamma)
    fR = _phys_flux(uR, axis, gamma)
    span = ap - am
    inv = jnp.where(span > 1e-12, 1.0 / jnp.maximum(span, 1e-12), 0.0)
    flux = (ap * fL - am * fR) * inv + (ap * am) * inv * (uR - uL)
    return jnp.where(span > 1e-12, flux, 0.5 * (fL + fR))


def _rhs_field_block(u, h: float, gamma: float, ghost: int, subgrid: int,
                     axes: Tuple[int, int, int]):
    """Fused Reconstruct+Flux on one block with field axis 0.

    u: (F, P, P, P[, T]); `axes` are the three spatial axes.
    Returns (F, S, S, S[, T]).
    """
    g, s = ghost, subgrid
    acc = None
    for axis in range(3):
        e = _AXIS_VECS[axis]
        face = None
        for (w, pL, sL, pR, sR) in FACE_QUAD[axis]:
            uL = _ppm_side(u, DIR_PAIRS[pL], sL, axes)
            uR = _shift(_ppm_side(u, DIR_PAIRS[pR], sR, axes), e, 1, axes)
            f = w * _central_upwind(uL, uR, axis, gamma)
            face = f if face is None else face + f
        # divergence over the interior
        def _slice(arr, lo):
            idx = [slice(None)] * arr.ndim
            for dim, ax in enumerate(axes):
                idx[ax] = slice(lo[dim], lo[dim] + s)
            return arr[tuple(idx)]
        hi_lo = [g, g, g]
        lo_lo = [g, g, g]
        lo_lo[axis] -= 1
        d = (_slice(face, hi_lo) - _slice(face, lo_lo)) / h
        acc = -d if acc is None else acc - d
    return acc


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _kernel_slot_grid(u_ref, out_ref, *, h, gamma, ghost, subgrid):
    u = u_ref[0]                                  # (F, P, P, P)
    out_ref[0] = _rhs_field_block(u, h, gamma, ghost, subgrid,
                                  axes=(-3, -2, -1))


def _kernel_slot_grid_h(u_ref, h_ref, out_ref, *, gamma, ghost, subgrid):
    """Per-slot traced cell width: h rides in as a (1, 1) block per task."""
    u = u_ref[0]                                  # (F, P, P, P)
    h = h_ref[0, 0]
    out_ref[0] = _rhs_field_block(u, h, gamma, ghost, subgrid,
                                  axes=(-3, -2, -1))


def _kernel_slot_lane(u_ref, out_ref, *, h, gamma, ghost, subgrid):
    u = u_ref[...]                                # (F, P, P, P, T)
    out_ref[...] = _rhs_field_block(u, h, gamma, ghost, subgrid,
                                    axes=(-4, -3, -2))


def _kernel_slot_lane_h(u_ref, h_ref, out_ref, *, gamma, ghost, subgrid):
    u = u_ref[...]                                # (F, P, P, P, T)
    h = h_ref[...][:, 0]                          # (T,) broadcasts over lanes
    out_ref[...] = _rhs_field_block(u, h, gamma, ghost, subgrid,
                                    axes=(-4, -3, -2))


def hydro_rhs_pallas(u_slots: jax.Array, *, h: Optional[float] = None,
                     h_slots: Optional[jax.Array] = None, gamma: float,
                     ghost: int, subgrid: int, layout: str = "slot_grid",
                     lane_tile: int = 8, interpret: bool = True) -> jax.Array:
    """Aggregated RHS kernel: (slots, F, P, P, P) -> (slots, F, S, S, S).

    Cell width comes in one of two forms:

    * ``h``       — a python float baked into the program (uniform grid);
    * ``h_slots`` — a traced ``(slots,)`` array, one width per aggregated
      task, staged through SMEM-shaped ``(1, 1)`` blocks.  This is the
      multi-level mode: one compiled kernel serves every refinement level
      whose sub-grid shapes agree (matching the XLA path's traced-h bodies).
    """
    if (h is None) == (h_slots is None):
        raise ValueError("pass exactly one of h / h_slots")
    n, f, p = u_slots.shape[0], u_slots.shape[1], u_slots.shape[2]
    s = subgrid
    kw = dict(gamma=gamma, ghost=ghost, subgrid=subgrid)
    if h_slots is not None:
        h2d = jnp.reshape(h_slots, (n, 1))

    if layout == "slot_grid":
        if h_slots is None:
            return pl.pallas_call(
                functools.partial(_kernel_slot_grid, h=h, **kw),
                grid=(n,),
                in_specs=[pl.BlockSpec((1, f, p, p, p),
                                       lambda i: (i, 0, 0, 0, 0))],
                out_specs=pl.BlockSpec((1, f, s, s, s),
                                       lambda i: (i, 0, 0, 0, 0)),
                out_shape=jax.ShapeDtypeStruct((n, f, s, s, s),
                                               u_slots.dtype),
                interpret=interpret,
            )(u_slots)
        return pl.pallas_call(
            functools.partial(_kernel_slot_grid_h, **kw),
            grid=(n,),
            in_specs=[pl.BlockSpec((1, f, p, p, p),
                                   lambda i: (i, 0, 0, 0, 0)),
                      pl.BlockSpec((1, 1), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, f, s, s, s),
                                   lambda i: (i, 0, 0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((n, f, s, s, s), u_slots.dtype),
            interpret=interpret,
        )(u_slots, h2d)

    if layout == "slot_lane":
        # tasks on the minor (lane) axis: (F, P, P, P, slots).  The tile
        # must divide the bucket; auto-tuned ladders produce non-power-of-
        # two buckets (DESIGN.md §9), so degrade the tile instead of
        # asserting — lane utilization drops, correctness does not.
        t = min(lane_tile, n)
        while n % t:
            t -= 1
        u_t = u_slots.transpose(1, 2, 3, 4, 0)
        if h_slots is None:
            out = pl.pallas_call(
                functools.partial(_kernel_slot_lane, h=h, **kw),
                grid=(n // t,),
                in_specs=[pl.BlockSpec((f, p, p, p, t),
                                       lambda i: (0, 0, 0, 0, i))],
                out_specs=pl.BlockSpec((f, s, s, s, t),
                                       lambda i: (0, 0, 0, 0, i)),
                out_shape=jax.ShapeDtypeStruct((f, s, s, s, n),
                                               u_slots.dtype),
                interpret=interpret,
            )(u_t)
        else:
            out = pl.pallas_call(
                functools.partial(_kernel_slot_lane_h, **kw),
                grid=(n // t,),
                in_specs=[pl.BlockSpec((f, p, p, p, t),
                                       lambda i: (0, 0, 0, 0, i)),
                          pl.BlockSpec((t, 1), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((f, s, s, s, t),
                                       lambda i: (0, 0, 0, 0, i)),
                out_shape=jax.ShapeDtypeStruct((f, s, s, s, n),
                                               u_slots.dtype),
                interpret=interpret,
            )(u_t, h2d)
        return out.transpose(4, 0, 1, 2, 3)

    raise ValueError(f"unknown layout {layout!r}")


# -- slot-ring integration --------------------------------------------------

def hydro_rhs_pallas_prefix(ring: jax.Array, start, bucket: int, *,
                            h: float, gamma: float, ghost: int, subgrid: int,
                            layout: str = "slot_grid",
                            interpret: bool = True) -> jax.Array:
    """Run the aggregated kernel on a slot-ring prefix, staging-free.

    ``ring`` is the AggregationExecutor's device-resident staging ring
    ``(capacity, F, P, P, P)``; the filled prefix ``[start, start+bucket)``
    is sliced *inside* the program (one fused op, no host copies) and fed to
    the Pallas kernel.  ``bucket`` is static — one compiled program per
    bucket size, matching the executor's bucket ladder.
    """
    u = jax.lax.dynamic_slice_in_dim(ring, start, bucket, axis=0)
    return hydro_rhs_pallas(u, h=h, gamma=gamma, ghost=ghost,
                            subgrid=subgrid, layout=layout,
                            interpret=interpret)


def pallas_batched_body(cfg, h: float, layout: str = "slot_grid",
                        interpret: bool = True):
    """Factory: a batched task body backed by the Pallas kernel, drop-in for
    ``UniformSedovScenario(batched_body=...)`` / ``AggregationExecutor`` —
    the path that runs the paper's GPU kernels through the slot-ring
    aggregation pipeline instead of the XLA oracle."""
    def batched(u_slots):
        return hydro_rhs_pallas(u_slots, h=h, gamma=cfg.gamma,
                                ghost=cfg.ghost, subgrid=cfg.subgrid,
                                layout=layout, interpret=interpret)
    return batched


def pallas_batched_body_h(gamma: float, ghost: int, subgrid: int,
                          layout: str = "slot_grid", interpret: bool = True):
    """Traced-h twin of :func:`pallas_batched_body`: signature
    ``(u_slots, h_slots) -> out_slots``, drop-in as a multi-level
    aggregation-region body (matches ``repro.hydro.stepper
    .level_batched_body``'s calling convention, Pallas-backed)."""
    def batched(u_slots, h_slots):
        return hydro_rhs_pallas(u_slots, h_slots=h_slots, gamma=gamma,
                                ghost=ghost, subgrid=subgrid,
                                layout=layout, interpret=interpret)
    return batched


# -- split kernels (paper-faithful two-kernel structure) --------------------

def _kernel_reconstruct(u_ref, out_ref, *, axes=(-3, -2, -1)):
    """Reconstruct only: writes all 26 surface values (paper kernel 1)."""
    u = u_ref[0]
    outs = []
    for d in DIR_PAIRS:
        outs.append(jnp.stack([_ppm_side(u, d, 0, axes),
                               _ppm_side(u, d, 1, axes)]))
    out_ref[0] = jnp.stack(outs)


def hydro_reconstruct_pallas(u_slots: jax.Array, *, interpret: bool = True):
    """(slots, F, P, P, P) -> (slots, 13, 2, F, P, P, P)."""
    n, f, p = u_slots.shape[0], u_slots.shape[1], u_slots.shape[2]
    npairs = len(DIR_PAIRS)
    return pl.pallas_call(
        _kernel_reconstruct,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, f, p, p, p), lambda i: (i, 0, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, npairs, 2, f, p, p, p),
                               lambda i: (i, 0, 0, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, npairs, 2, f, p, p, p),
                                       u_slots.dtype),
        interpret=interpret,
    )(u_slots)


def _kernel_flux(recon_ref, out_ref, *, h, gamma, ghost, subgrid):
    """Flux only: consumes the staged reconstruction (paper kernel 2)."""
    recon = recon_ref[0]                          # (13, 2, F, P, P, P)
    g, s = ghost, subgrid
    axes = (-3, -2, -1)
    acc = None
    for axis in range(3):
        e = _AXIS_VECS[axis]
        face = None
        for (w, pL, sL, pR, sR) in FACE_QUAD[axis]:
            uL = recon[pL, sL]
            uR = _shift(recon[pR, sR], e, 1, axes)
            f = w * _central_upwind(uL, uR, axis, gamma)
            face = f if face is None else face + f
        hi = face[:, g:g + s, g:g + s, g:g + s]
        lo_idx = [slice(g, g + s)] * 3
        lo_idx[axis] = slice(g - 1, g - 1 + s)
        lo = face[(slice(None),) + tuple(lo_idx)]
        d = (hi - lo) / h
        acc = -d if acc is None else acc - d
    out_ref[0] = acc


def hydro_flux_pallas(recon: jax.Array, *, h: float, gamma: float,
                      ghost: int, subgrid: int, interpret: bool = True):
    """(slots, 13, 2, F, P, P, P) -> (slots, F, S, S, S)."""
    n, npairs, _, f, p = recon.shape[:5]
    s = subgrid
    return pl.pallas_call(
        functools.partial(_kernel_flux, h=h, gamma=gamma, ghost=ghost,
                          subgrid=subgrid),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, npairs, 2, f, p, p, p),
                               lambda i: (i, 0, 0, 0, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, f, s, s, s), lambda i: (i, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f, s, s, s), recon.dtype),
        interpret=interpret,
    )(recon)
