"""Jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the kernels compile natively; everywhere else they run in interpret
mode (the kernel body executed in Python on CPU), which is how correctness
is validated in this repository.  ``use_pallas=False`` routes to the pure-jnp
oracle — the "Kokkos vs native" portability axis of the paper, reproduced as
Pallas-vs-XLA (benchmarks/portability.py).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.grouped_gemm import grouped_gemm as _gg_pallas
from repro.kernels.hydro_rhs import hydro_rhs_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("h", "gamma", "ghost", "subgrid",
                                   "layout", "use_pallas"))
def hydro_rhs(u_slots, *, h, gamma, ghost, subgrid, layout="slot_grid",
              use_pallas=True):
    if not use_pallas:
        return _ref.hydro_rhs_ref(u_slots, h=h, gamma=gamma, ghost=ghost,
                                  subgrid=subgrid)
    return hydro_rhs_pallas(u_slots, h=h, gamma=gamma, ghost=ghost,
                            subgrid=subgrid, layout=layout,
                            interpret=not on_tpu())


@partial(jax.jit, static_argnames=("use_pallas",))
def grouped_gemm(x, w, group_len, use_pallas=True):
    if not use_pallas:
        return _ref.grouped_gemm_ref(x, w, group_len)
    return _gg_pallas(x, w, group_len, interpret=not on_tpu())


@partial(jax.jit, static_argnames=("use_pallas",))
def decode_attention(q, k_cache, v_cache, cache_len, use_pallas=True):
    if not use_pallas:
        return _ref.decode_attention_ref(q, k_cache, v_cache, cache_len)
    return _decode_pallas(q, k_cache, v_cache, cache_len,
                          interpret=not on_tpu())
