"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel has three pieces:
  <name>.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper with backend dispatch (interpret on CPU)
  ref.py    — pure-jnp oracle used for allclose validation and as the
              production XLA path where the kernel isn't warranted

Kernels:
  hydro_rhs        — fused Reconstruct+Flux over aggregated sub-grid slots
                     (slot-grid and slot-lane layouts)
  grouped_gemm     — MoE expert-aggregated GEMM with dead-tile skipping
  decode_attention — bucketed flash-decode GQA attention for the serving
                     engine's aggregated request batches
"""
from repro.kernels.ops import decode_attention, grouped_gemm, hydro_rhs

__all__ = ["decode_attention", "grouped_gemm", "hydro_rhs"]
