"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.hydro.flux import flux_divergence
from repro.hydro.ppm import ppm_reconstruct_all
from repro.hydro.stepper import subgrid_rhs


def hydro_rhs_ref(u_slots, *, h, gamma, ghost, subgrid):
    """(slots, F, P, P, P) -> (slots, F, S, S, S)."""
    body = partial(subgrid_rhs, h=h, gamma=gamma, ghost=ghost, subgrid=subgrid)
    return jax.vmap(body)(u_slots)


def hydro_reconstruct_ref(u_slots):
    """(slots, F, P, P, P) -> (slots, 13, 2, F, P, P, P)."""
    return jax.vmap(ppm_reconstruct_all)(u_slots)


def hydro_flux_ref(recon, *, h, gamma, ghost, subgrid):
    """(slots, 13, 2, F, P, P, P) -> (slots, F, S, S, S)."""
    body = partial(flux_divergence, h=h, gamma=gamma, ghost=ghost,
                   subgrid=subgrid)
    return jax.vmap(body)(recon)


def grouped_gemm_ref(x, w, group_len):
    """Capacity-layout grouped GEMM oracle.

    x: (E, C, K), w: (E, K, N), group_len: (E,) valid rows per expert.
    Rows >= group_len[e] are masked to zero in the output.
    """
    y = jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    c = x.shape[1]
    mask = jnp.arange(c)[None, :] < group_len[:, None]
    return (y * mask[..., None]).astype(x.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """Bucketed GQA decode-attention oracle.

    q: (B, Hq, D); k_cache/v_cache: (B, S, Hkv, D); cache_len: (B,) int32.
    Returns (B, Hq, D).

    The einsums contract directly against the (B, S, Hkv, D) cache layout —
    no transpose of the (potentially huge) cache is ever materialized, and
    the cache's sequence sharding is preserved through the contraction
    (XLA reduces partial attention with a psum when S is sharded).
    """
    from repro.distributed.api import constrain
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg,
                        k_cache.astype(jnp.float32)) * scale
    scores = constrain(scores, "batch", None, None, "kv_seq")
    valid = jnp.arange(s)[None, :] < cache_len[:, None]       # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    # streaming-softmax form: max/exp stay sequence-sharded, the two
    # reductions are the only cross-shard ops
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    out = out / denom
    return out.reshape(b, hq, d).astype(q.dtype)
