"""Grouped (expert-aggregated) GEMM Pallas kernel.

This is the paper's strategy 3 applied at the kernel level inside MoE layers:
each expert's GEMM over its routed tokens is a fine-grained task (for DBRX,
16 experts x top-4 means each expert sees ~1/4 of the tokens — small, skewed
matmuls); launching them separately starves the MXU exactly like Octo-Tiger's
8^3 sub-grid kernels starved the A100.  The aggregated launch fuses all E
per-expert GEMMs into one kernel over a (expert, token-tile, n-tile, k-tile)
grid, with per-expert valid-row masking — the "slot index" the paper adds to
its aggregated kernels is the expert id here.

Capacity layout: ``x (E, C, K) @ w (E, K, N) -> y (E, C, N)`` with
``group_len (E,)`` valid rows; tiles whose token range lies entirely beyond
``group_len[e]`` skip the MXU work (ragged/dropless behavior within a static
shape — the bucketed-static-shape adaptation of dynamic aggregation).

Block shapes default to MXU-aligned (128, 512, 128) tiles; the fp32
accumulator lives in VMEM scratch across the k-loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gg_kernel(gl_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int, bc: int):
    ci = pl.program_id(1)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile is live if any of its rows belong to the expert's group
    live = ci * bc < gl_ref[0]

    @pl.when(live)
    def _mac():
        acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _store():
        rows = ci * bc + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        mask = rows < gl_ref[0]
        o_ref[0] = jnp.where(mask, acc_ref[...], 0.0).astype(o_ref.dtype)


def grouped_gemm(x: jax.Array, w: jax.Array, group_len: jax.Array, *,
                 bc: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = True) -> jax.Array:
    """x: (E, C, K) @ w: (E, K, N) -> (E, C, N), rows masked by group_len."""
    e, c, k = x.shape
    n = w.shape[2]
    bc, bn, bk = min(bc, c), min(bn, n), min(bk, k)
    assert c % bc == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape)
    n_k = k // bk
    grid = (e, c // bc, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_gg_kernel, n_k=n_k, bc=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ei, ci, ni, ki: (ei,)),
            pl.BlockSpec((1, bc, bk), lambda ei, ci, ni, ki: (ei, ci, ki)),
            pl.BlockSpec((1, bk, bn), lambda ei, ci, ni, ki: (ei, ki, ni)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda ei, ci, ni, ki: (ei, ci, ni)),
        out_shape=jax.ShapeDtypeStruct((e, c, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        interpret=interpret,
    )(group_len, x, w)
