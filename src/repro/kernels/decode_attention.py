"""Bucketed flash-decode GQA attention Pallas kernel.

The serving engine's aggregated launch: B decode requests (each a
fine-grained task — one new token against its KV cache) are fused into one
kernel with a request axis, the serving-level instance of the paper's
strategy 3.  Online-softmax over KV tiles keeps VMEM usage at
``(G, D) + (bs, D)`` per step; tiles entirely beyond a request's
``cache_len`` skip their compute (so aggregated requests of different
lengths do not pay for the longest one — the ragged analogue of the paper's
"tasks share the kernel but own their chunk").

q: (B, Hq, D); k/v cache: (B, S, Hkv, D); cache_len: (B,).  Grid is
(B, Hkv, S/bs); each (b, h) pair owns a G=Hq/Hkv query group, carried
running max / denominator / accumulator live in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bs: int, n_s: int, scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[0]
    live = si * bs < cache_len

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bs)
        pos = si * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < cache_len, s, NEG_INF)
        m_prev = m_ref[...]                            # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                         # (G, bs)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, bs: int = 512,
                     interpret: bool = True) -> jax.Array:
    """(B, Hq, D) x (B, S, Hkv, D) caches -> (B, Hq, D)."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    bs = min(bs, s)
    assert s % bs == 0, (s, bs)
    n_s = s // bs
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, bs=bs, n_s=n_s, scale=scale),
        grid=(b, hkv, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, si: (bi,)),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qg, k_cache, v_cache)
    return out.reshape(b, hq, d)
