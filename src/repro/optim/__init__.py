from repro.optim.adamw import (
    OptConfig, opt_init, opt_update, cosine_lr, global_norm, clip_by_global_norm,
)
from repro.optim.compression import (
    int8_compress, int8_decompress, compressed_allreduce,
)

__all__ = [
    "OptConfig", "opt_init", "opt_update", "cosine_lr", "global_norm",
    "clip_by_global_norm", "int8_compress", "int8_decompress",
    "compressed_allreduce",
]
