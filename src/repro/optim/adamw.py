"""AdamW + cosine schedule + global-norm clipping (pure pytree, shardable).

Optimizer state mirrors the parameter pytree, so the FSDP sharding specs for
params apply verbatim to ``m``/``v`` — ZeRO-style sharded optimizer state
falls out of the sharding rules, no special casing.  Master state is fp32
regardless of param dtype (bf16-safe).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0


def cosine_lr(cfg: OptConfig, step):
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def opt_init(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    import copy
    return {"m": zeros,
            "v": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), tree), norm


def opt_update(grads, state, params, cfg: OptConfig
               ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1.0)
    bc2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
