"""Gradient compression for cross-pod reduction (int8 with error feedback).

At 512+ chips the ``pod`` axis crosses the slow inter-pod links (DCI), so
gradient all-reduce bytes there dominate the collective roofline term.
``compressed_allreduce`` quantizes gradients to int8 (per-tensor scale),
all-reduces the int8 payload in int32 accumulation, and dequantizes — a 4x
cut of cross-pod bytes.  Error feedback (the residual is carried to the next
step) keeps the scheme convergent (1-bit-Adam-style argument).

Used by the explicit-DP ``shard_map`` training path
(``repro/distributed/fault_tolerance.make_dp_train_step``); the default pjit
path leaves reduction to XLA (and this module documents the delta for
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_allreduce(g: jax.Array, axis_name: str,
                         residual: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """int8 all-reduce with error feedback.  Call inside shard_map.

    Returns (mean gradient, new residual)."""
    if residual is not None:
        g = g + residual
    # one shared scale across the axis so the int8 payloads are summable
    local_scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    # int32 accumulation avoids overflow for up to 2^23 summands
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
    new_residual = g - q.astype(jnp.float32) * scale
    return mean, new_residual
