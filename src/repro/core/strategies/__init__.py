"""Work-aggregation strategy plugins (the paper's S1 / S2 / S3 and combos).

Each strategy is one module, registered by name; ``StrategyRunner`` drives
any :class:`~repro.core.scenario.Scenario` under any of them:

* ``s1``   — larger sub-problems: not a runtime mode but a *config* (16^3
             sub-grids via ``repro.configs.sedov.CONFIG_16``); every runner
             accepts any config, so s1 is "same runner, bigger blocks".
* ``s2``   — implicit aggregation (``s2.py``): one launch per task over a
             pre-allocated executor pool, donated scatter-ring assembly.
* ``s3``   — explicit aggregation (``s3.py``): tasks fused on-the-fly into
             bucketed batched kernels by the multi-region
             ``AggregationExecutor``; populations submit interleaved, so
             heterogeneous families aggregate concurrently.
* ``s2+s3``— s3 over a multi-executor pool (the paper's best rows).
* ``mixed``— per-family routing (``mixed.py``): each kernel family goes
             to s2, s3 or fused independently — explicitly via
             ``AggregationConfig(family_strategies=...)`` or from the
             measured cost model (DESIGN.md §12).
* ``fused``— whole-graph upper bound (``fused.py``), plus the ``lax.scan``
             whole-trajectory driver on the runner.

All strategies are bit-identical in results to the scenario's fused
per-family reference (tested); only launch structure differs.
"""
from repro.core.strategies.base import (
    RunContext, Strategy, available_strategies, get_strategy_class,
    register_strategy,
)
from repro.core.strategies import fused, mixed, s2, s3  # noqa: F401 (register)
from repro.core.strategies.runner import (
    AMRStrategyRunner, HydroStrategyRunner, StrategyRunner,
)
from repro.core.scenario import xla_task_body     # noqa: F401  (legacy path)

__all__ = [
    "RunContext", "Strategy", "available_strategies", "get_strategy_class",
    "register_strategy", "StrategyRunner",
    "AMRStrategyRunner", "HydroStrategyRunner", "xla_task_body",
]
