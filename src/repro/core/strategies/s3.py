"""``s3`` / ``s2+s3``: explicit on-the-fly aggregation through the
multi-region ``AggregationExecutor``.

Tasks from ALL of the scenario's populations are submitted **interleaved**
into ONE executor: the region registry routes each task by ``TaskSignature``
to its family's slot ring / queue / bucket ladder, so heterogeneous families
— coarse+fine AMR levels, or the hydro and gravity solvers — aggregate
concurrently instead of serializing.  Device staging submits each population
as ONE bulk range entry (``TaskPopulation.submit_to`` ->
``AggregationExecutor.submit_range``): the per-task Python loop — n
``TaskFuture`` allocations, n signature routings, n queue appends per wave —
collapses to one queue entry per family backed by one ``RangeFuture``, and
``gather_futures`` hands the full-range batch back zero-copy.  Populations
that SHARE a kernel (e.g. two AMR levels with equal sub-grid shapes) submit
their ranges sequentially: a launch gathers from one parent set, so the
executor's parent-switch flush keeps each population's buckets whole.
``s2+s3`` is the same strategy over a multi-executor pool (the paper's best
rows).

The seed's slice -> host-stack -> launch cycle survives as
``staging="host"`` (per-task submissions, measurable baseline for
benchmarks/launch_overhead.py).  When the scenario declares per-slot
epilogues, ``run_stage`` drives whole RK stages through the epilogue-fused
twin families (DESIGN.md §9) — and a stage wave may carry SEVERAL
families at once: the AMR scenario submits one range per level twin, the
gravity scenario its hydro twin AND the plain gravity family interleaved
in the same wave (DESIGN.md §10), with any cross-family coupling applied
by ``assemble_stage``.  Stats report per-call DELTAS — the executor's own
counters are cumulative, so the wave is snapshotted around the
submissions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aggregation import gather_futures
from repro.core.faults import TaskFailedError
from repro.core.strategies.base import RunContext, Strategy, register_strategy


@register_strategy("s3", "s2+s3")
class S3Strategy(Strategy):
    name = "s3"
    uses_executor = True

    def _submit_populations(self, exe, pops, host: bool):
        """One wave: bulk range per population (device staging), round-robin
        per-task interleave across families (host staging)."""
        futs = [[] for _ in pops]
        if not host:
            # one range entry per population; same-kernel populations stay
            # contiguous by construction (each range is one entry)
            for pi, pop in enumerate(pops):
                if pop.n_tasks:
                    futs[pi].append(pop.submit_to(exe))
            return futs
        # flatten each kernel family's populations into one ordered task
        # list, then round-robin one submission per family per turn
        lanes = {}
        for pi, pop in enumerate(pops):
            lanes.setdefault(pop.kernel, []).extend(
                (pi, pop, i) for i in range(pop.n_tasks))
        cursors = [iter(lane) for lane in lanes.values()]
        while cursors:
            live = []
            for cur in cursors:                   # interleave the families
                nxt = next(cur, None)
                if nxt is None:
                    continue
                pi, pop, i = nxt
                futs[pi].append(exe.submit(
                    *(par[i] for par in pop.parents), kernel=pop.kernel))
                live.append(cur)
            cursors = live
        return futs

    def _drain(self, scenario, exe, pops, futs):
        exe.flush()
        # a population may legitimately be empty this iteration (dynamic
        # task structure, e.g. a refinement level with no patches): hand
        # assemble a zero-length batch instead of gathering nothing
        outs = []
        for pop, f in zip(pops, futs):
            if f:
                try:
                    outs.append(gather_futures(f))
                except TaskFailedError as err:
                    # translate the executor's wave-relative task ids into
                    # the scenario's own vocabulary before propagating —
                    # the physicist debugging a tripped wave should read
                    # "subgrid (i, j)", not a slot number (DESIGN.md §11)
                    what = ", ".join(
                        scenario.describe_task(pop.kernel, tid)
                        for tid in err.task_ids) or "unknown task"
                    raise TaskFailedError(
                        f"{what} failed during aggregated execution: {err}",
                        task_ids=err.task_ids,
                        kernel=pop.kernel) from err
            else:
                spec = jax.eval_shape(
                    scenario.family(pop.kernel).batched_body, *pop.parents)
                outs.append(jnp.zeros(spec.shape, spec.dtype))
        return outs

    def run_iteration(self, scenario, state, ctx: RunContext):
        exe = ctx.executor
        pops = scenario.populations(state)
        before_launches = exe.stats["launches"]
        before_staging = exe.stats["staging_s"]
        futs = self._submit_populations(exe, pops,
                                        host=ctx.config.staging == "host")
        outs = self._drain(scenario, exe, pops, futs)
        ctx.stats["staging_s"] += exe.stats["staging_s"] - before_staging
        ctx.stats["kernel_launches"] += (exe.stats["launches"]
                                         - before_launches)
        return scenario.assemble(state, outs)

    def run_stage(self, scenario, u0, v, dt, c0, c1, ctx: RunContext):
        if ctx.config.staging == "host":
            return None                  # baseline path stays per-task
        pops = scenario.stage_populations(u0, v, dt, c0, c1)
        if pops is None:
            return None
        exe = ctx.executor
        before_launches = exe.stats["launches"]
        before_staging = exe.stats["staging_s"]
        futs = self._submit_populations(exe, pops, host=False)
        outs = self._drain(scenario, exe, pops, futs)
        ctx.stats["staging_s"] += exe.stats["staging_s"] - before_staging
        ctx.stats["kernel_launches"] += (exe.stats["launches"]
                                         - before_launches)
        return scenario.assemble_stage(v, outs, dt, c0, c1)
