"""``s3`` / ``s2+s3``: explicit on-the-fly aggregation through the
multi-region ``AggregationExecutor``.

Tasks from ALL of the scenario's populations are submitted **interleaved**
(round-robin across kernel families, slot order within each family) into
ONE executor: the region registry routes each task by ``TaskSignature`` to
its family's slot ring / queue / bucket ladder, so heterogeneous families
— coarse+fine AMR levels, or the hydro and gravity solvers — aggregate
concurrently instead of serializing.  Populations that SHARE a kernel
(e.g. two AMR levels with equal sub-grid shapes) submit sequentially
within their family's round-robin turn: a launch gathers from one parent
set, so alternating their parents task-by-task would shatter every bucket
via the executor's parent-switch flush.  ``s2+s3`` is the same strategy
over a multi-executor pool (the paper's best rows).

Inputs stage by slot index (``submit_indexed``: one gather or prefix slice
per launch over the already-device-resident parents, DESIGN.md §3); the
seed's slice -> host-stack -> launch cycle survives as ``staging="host"``
so benchmarks/launch_overhead.py can measure the win.  Stats report
per-call DELTAS — the executor's own counters are cumulative, so the wave
is snapshotted around the submissions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aggregation import gather_futures
from repro.core.strategies.base import RunContext, Strategy, register_strategy


@register_strategy("s3", "s2+s3")
class S3Strategy(Strategy):
    name = "s3"
    uses_executor = True

    def run_iteration(self, scenario, state, ctx: RunContext):
        exe = ctx.executor
        pops = scenario.populations(state)
        before_launches = exe.stats["launches"]
        before_staging = exe.stats["staging_s"]
        host = ctx.config.staging == "host"
        futs = [[] for _ in pops]
        # flatten each kernel family's populations into one ordered task
        # list, then round-robin one submission per family per turn
        lanes = {}
        for pi, pop in enumerate(pops):
            lanes.setdefault(pop.kernel, []).extend(
                (pi, pop, i) for i in range(pop.n_tasks))
        cursors = [iter(lane) for lane in lanes.values()]
        while cursors:
            live = []
            for cur in cursors:                   # interleave the families
                nxt = next(cur, None)
                if nxt is None:
                    continue
                pi, pop, i = nxt
                if host:
                    futs[pi].append(exe.submit(
                        *(par[i] for par in pop.parents), kernel=pop.kernel))
                else:
                    futs[pi].append(exe.submit_indexed(pop.parents, i,
                                                       kernel=pop.kernel))
                live.append(cur)
            cursors = live
        exe.flush()
        # a population may legitimately be empty this iteration (dynamic
        # task structure, e.g. a refinement level with no patches): hand
        # assemble a zero-length batch instead of gathering nothing
        outs = []
        for pop, f in zip(pops, futs):
            if f:
                outs.append(gather_futures(f))
            else:
                spec = jax.eval_shape(
                    scenario.family(pop.kernel).batched_body, *pop.parents)
                outs.append(jnp.zeros(spec.shape, spec.dtype))
        ctx.stats["staging_s"] += exe.stats["staging_s"] - before_staging
        ctx.stats["kernel_launches"] += (exe.stats["launches"]
                                         - before_launches)
        return scenario.assemble(state, outs)
