"""``fused``: the whole-graph upper bound — one jitted launch per family.

What a static whole-graph compiler can do when the task structure is known
ahead of time; the paper's dynamic AMR setting is precisely where this is
NOT generally available.  Uses the scenario's shared jitted bodies, so the
fused strategy IS the bit-exact reference (``Scenario.reference_rhs``) by
construction.
"""
from __future__ import annotations

from repro.core.strategies.base import RunContext, Strategy, register_strategy


@register_strategy("fused")
class FusedStrategy(Strategy):
    name = "fused"

    def run_iteration(self, scenario, state, ctx: RunContext):
        outs = []
        for pop in scenario.populations(state):
            outs.append(scenario.jitted_body(pop.kernel)(*pop.parents))
            ctx.stats["kernel_launches"] += 1
        return scenario.assemble(state, outs)

    def run_stage(self, scenario, u0, v, dt, c0, c1, ctx: RunContext):
        """The fused stage IS the scenario's bit-exact stage reference
        (one jitted launch of each epilogue-fused family)."""
        pops = scenario.stage_populations(u0, v, dt, c0, c1)
        if pops is None:
            return None
        outs = []
        for pop in pops:
            outs.append(scenario.jitted_body(pop.kernel)(*pop.parents))
            ctx.stats["kernel_launches"] += 1
        return scenario.assemble_stage(v, outs, dt, c0, c1)
