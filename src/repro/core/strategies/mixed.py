"""``mixed``: cost-driven per-family strategy routing (DESIGN.md §12).

Octo-Tiger does not force one launch strategy on every kernel type — the
hydro solver aggregates while gravity runs fused, because per-kernel-type
tuning is what carries real scenarios (the paper's follow-up,
PAPERS.md).  This strategy reproduces that: each :class:`KernelFamily`
routes independently to

* ``"s3"``    — bucketed aggregation through the shared multi-region
                ``AggregationExecutor`` (ranges submitted, ladder drained);
* ``"s2"``    — the donated scatter ring at the measured coalesce width
                (``S2Strategy.launch_population``);
* ``"fused"`` — one jitted whole-family launch.

The route comes from ``AggregationConfig(family_strategies={...})``
(exact kernel id, the ``"+epi"`` twin's base kernel, or the ``"*"``
wildcard), and missing/``"auto"`` entries from the executor's measured
``select_strategy`` — the per-family s2/s3/fused wall-time comparison the
extended :class:`BucketCostModel` makes honest.  Routes resolve once per
run context and are persisted (with the cost numbers that justified
them) into ``stats["regions"][fam]["selected_strategy"]``.

Bit-identity: every route runs the family's SAME traced batched body —
only the batch decomposition differs — so mixed results are bit-identical
to the fused reference for every assignment (tests/test_mixed.py sweeps
the product).

Guard compatibility (DESIGN.md §11 × §12): s3-routed families keep the
executor's full containment (bisection isolates the culprit task);
s2/fused-routed families have no bucket structure to bisect, so the
strategy applies the per-family tripwire itself — a non-finite output
raises :class:`NonFiniteStateError` naming the family and its route.
Injected payload faults fire on non-executor routes too (same
deterministic schedule, wave-relative task ids), so fault tests cover
every route.
"""
from __future__ import annotations

import jax

from repro.configs.base import (
    FAMILY_STRATEGY_CHOICES, resolve_family_option,
)
from repro.core.aggregation import TaskSignature
from repro.core.faults import NonFiniteStateError, all_finite, poison_slots
from repro.core.strategies.base import RunContext, Strategy, register_strategy
from repro.core.strategies.s2 import S2Strategy
from repro.core.strategies.s3 import S3Strategy


@register_strategy("mixed")
class MixedStrategy(Strategy):
    name = "mixed"
    uses_executor = True

    def __init__(self):
        self._s2 = S2Strategy()
        self._s3 = S3Strategy()

    # -- routing -----------------------------------------------------------
    def _route(self, kernel: str, ctx: RunContext) -> str:
        key = ("mixed_route", kernel)
        choice = ctx.caches.get(key)
        if choice is not None:
            return choice
        choice = resolve_family_option(
            getattr(ctx.config, "family_strategies", None), kernel, "auto")
        if choice not in FAMILY_STRATEGY_CHOICES:
            raise ValueError(
                f"family_strategies[{kernel!r}] = {choice!r} — valid "
                f"assignments: {FAMILY_STRATEGY_CHOICES}")
        if choice == "auto":
            choice = ctx.executor.select_strategy(kernel)
        else:
            ctx.executor.record_selection(kernel, choice)
        ctx.caches[key] = choice
        return choice

    def routes(self, scenario, ctx: RunContext) -> dict:
        """The resolved per-family assignment (kernel -> strategy) for
        every family the scenario can launch — the BENCH observability
        surface."""
        kernels = [f.kernel for f in scenario.families()]
        kernels += [f.kernel for f in scenario.stage_families()]
        return {k: self._route(k, ctx) for k in kernels}

    # -- one wave ----------------------------------------------------------
    def _run_wave(self, scenario, pops, ctx: RunContext):
        """Route one submission wave: s3 populations enter the executor as
        bulk ranges first (their queue fills while the other routes
        dispatch), then s2/fused populations launch directly on the pool,
        then the executor drains.  Outputs come back in population order."""
        exe = ctx.executor
        routes = [self._route(pop.kernel, ctx) for pop in pops]
        before_launches = exe.stats["launches"]
        before_staging = exe.stats["staging_s"]
        s3_idx = [i for i, r in enumerate(routes) if r == "s3"]
        s3_pops = [pops[i] for i in s3_idx]
        futs = self._s3._submit_populations(
            exe, s3_pops, host=ctx.config.staging == "host")
        outs = [None] * len(pops)
        for i, (pop, route) in enumerate(zip(pops, routes)):
            if route == "s2":
                outs[i] = self._s2.launch_population(scenario, pop, ctx)
            elif route == "fused":
                outs[i] = self._launch_fused(scenario, pop, ctx)
        for i, out in zip(s3_idx, self._s3._drain(scenario, exe, s3_pops,
                                                  futs)):
            outs[i] = out
        ctx.stats["staging_s"] += exe.stats["staging_s"] - before_staging
        ctx.stats["kernel_launches"] += (exe.stats["launches"]
                                         - before_launches)
        self._audit(pops, routes, outs, ctx)
        return outs

    def _launch_fused(self, scenario, pop, ctx: RunContext):
        out = ctx.pool.get().launch(scenario.jitted_body(pop.kernel),
                                    *pop.parents, family=pop.kernel)
        ctx.stats["kernel_launches"] += 1
        # stats parity: the same TaskSignature family key the executor and
        # the s2 route use, so BENCH helpers read one key per family
        key = ("mixed_desc", pop.kernel,
               tuple((tuple(p.shape), str(p.dtype)) for p in pop.parents))
        desc = ctx.caches.get(key)
        if desc is None:
            task_specs = tuple(jax.ShapeDtypeStruct(p.shape[1:], p.dtype)
                               for p in pop.parents)
            desc = TaskSignature.from_args(pop.kernel, task_specs).describe()
            ctx.caches[key] = desc
        stats = ctx.stats.setdefault("regions", {}).setdefault(
            desc, {"submitted": 0, "launches": 0,
                   "aggregated_hist": {}})
        stats["submitted"] += pop.n_tasks
        stats["launches"] += 1
        hist = stats["aggregated_hist"]
        hist[pop.n_tasks] = hist.get(pop.n_tasks, 0) + 1
        stats.setdefault("selected_strategy", "fused")
        return out

    def _audit(self, pops, routes, outs, ctx: RunContext) -> None:
        """Fault injection + guard tripwire for the non-executor routes
        (s3-routed families are audited inside the executor's flush)."""
        exe = ctx.executor
        injector = exe._injector
        guard = getattr(ctx.config, "guard", "off") == "finite"
        if injector is None and not guard:
            return
        for i, (pop, route) in enumerate(zip(pops, routes)):
            if route == "s3" or outs[i] is None:
                continue
            if injector is not None:
                wave_key = ("mixed_wave", pop.kernel)
                wave = ctx.caches.get(wave_key, 0)
                ctx.caches[wave_key] = wave + 1
                poisons = injector.poison_positions(
                    pop.kernel, wave, list(range(pop.n_tasks)))
                if poisons:
                    outs[i] = poison_slots(outs[i], sorted(poisons), poisons)
            if guard and not all_finite(outs[i]):
                raise NonFiniteStateError(
                    f"non-finite output in family {pop.kernel!r} routed to "
                    f"{route!r} under 'mixed' — only aggregated (s3-routed) "
                    f"families can bisect; assign the family to 's3' in "
                    f"family_strategies to isolate the task")

    # -- strategy protocol -------------------------------------------------
    def run_iteration(self, scenario, state, ctx: RunContext):
        pops = scenario.populations(state)
        return scenario.assemble(state, self._run_wave(scenario, pops, ctx))

    def run_stage(self, scenario, u0, v, dt, c0, c1, ctx: RunContext):
        if ctx.config.staging == "host":
            return None                  # baseline path stays per-task
        pops = scenario.stage_populations(u0, v, dt, c0, c1)
        if pops is None:
            return None
        outs = self._run_wave(scenario, pops, ctx)
        return scenario.assemble_stage(v, outs, dt, c0, c1)
