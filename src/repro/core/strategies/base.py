"""Strategy plugin base: the registry, the ``Strategy`` interface and the
shared ``RunContext``.

A strategy decides HOW a scenario's task populations launch; it is
registered by name and implements ``run_iteration(scenario, state, ctx)``.
Adding a strategy is one file in this package:

    @register_strategy("mine")
    class MyStrategy(Strategy):
        def run_iteration(self, scenario, state, ctx):
            ...
            return scenario.assemble(state, outs)

``StrategyRunner`` (``runner.py``) validates names against the registry at
construction — unknown strategies fail fast with the valid names listed,
not on the first ``rhs()`` call deep inside an iteration.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

from repro.configs.base import AggregationConfig
from repro.core.aggregation import AggregationExecutor
from repro.core.executor import ExecutorPool

_REGISTRY: Dict[str, Type["Strategy"]] = {}


def register_strategy(*names: str):
    """Class decorator: register a Strategy under one or more names."""
    def deco(cls: Type["Strategy"]) -> Type["Strategy"]:
        for name in names:
            _REGISTRY[name] = cls
        return cls
    return deco


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_strategy_class(name: str) -> Type["Strategy"]:
    """Resolve a strategy name, failing fast with the valid names listed."""
    cls = _REGISTRY.get(name)
    if cls is None:
        raise ValueError(
            f"unknown strategy {name!r} — valid strategies: "
            f"{', '.join(available_strategies())}")
    return cls


@dataclass
class RunContext:
    """Everything a strategy shares across iterations: the launch config,
    the executor pool, the (optional) aggregation executor, the unified
    stats dict and a private compiled-program cache."""

    config: AggregationConfig
    pool: ExecutorPool
    executor: Optional[AggregationExecutor]
    stats: Dict[str, Any]
    caches: Dict[Any, Any] = field(default_factory=dict)


class Strategy:
    """One launch structure.  Stateless by convention: per-run compiled
    programs live in ``ctx.caches`` so a strategy instance can serve any
    scenario (behavioral knobs — executor count, bucket cap, staging mode —
    arrive via ``ctx.config``, which is how "s3" and "s2+s3" share one
    class).  ``uses_executor`` tells the runner to construct (and register
    the scenario's families with) an ``AggregationExecutor``."""

    name: ClassVar[str] = ""
    uses_executor: ClassVar[bool] = False

    def run_iteration(self, scenario, state, ctx: RunContext):
        """One solver iteration: launch every population, assemble d(state)."""
        raise NotImplementedError

    def run_stage(self, scenario, u0, v, dt, c0, c1, ctx: RunContext):
        """One epilogue-fused RK stage: launch the scenario's stage
        populations (gather -> body -> stage axpy as ONE program per
        bucket) and return the next stage's state.  A scenario may
        declare several stage populations — per-level twins (AMR) or a
        fused twin plus an un-fused partner family submitted in the same
        wave (gravity, DESIGN.md §10); ``assemble_stage`` owns any
        cross-family coupling.  ``None`` = this strategy has no
        fused-stage path; the runner falls back to ``run_iteration`` +
        the global combine."""
        return None
