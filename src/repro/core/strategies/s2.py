"""``s2``: implicit aggregation — one launch per task, round-robin over a
pre-allocated executor pool; the runtime is left to overlap them (paper
finding: works iff the runtime can — reproduced here).

Each launch slices a task span out of the population's parent arrays and
scatters its result into a donated output slot ring, all inside one
compiled program (``lax.dynamic_slice`` + ``lax.dynamic_update_slice`` on
an in-place buffer) — ZERO host-side slicing or concatenation.  The
classic s2 runs the body at width 1 (one task per launch, the paper's
implicit aggregation); under ``cost_model=True`` the scatter-ring sizing
is *measured* (DESIGN.md §12): the per-width scatter program is timed at
warm-up and the coalesce width minimizing the predicted per-wave wall
time is chosen — same body, same values, fewer launches.  Every width is
bit-identical to width 1 by the bucket invariant (the batched body is
elementwise over the slot axis).

Tradeoff: the donated carry chains launches at the device level, which
costs nothing on XLA:CPU/TPU (one program at a time per core — only host
dispatch pipelining matters, and enqueues still return immediately) but
would forfeit inter-stream concurrency on a CUDA-like backend; DESIGN.md §3.

Stats parity (DESIGN.md §12): per-family launch counters, width
histograms and the measured s2 cost table land in
``ctx.stats["regions"][fam]`` under the same family keys the aggregation
executor uses, so s2 rows in the BENCH files are comparable
family-by-family with s3/mixed rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aggregation import (
    BucketCostModel, TaskSignature, make_s2_scatter, measure_s2_widths,
    s2_width_candidates,
)
from repro.core.strategies.base import RunContext, Strategy, register_strategy


@register_strategy("s2")
class S2Strategy(Strategy):
    name = "s2"

    def _plan_for(self, scenario, pop, ctx: RunContext):
        """The per-(kernel, parent shapes) launch plan: chosen coalesce
        width, compiled scatter programs, output-ring spec and the
        family's stats dict.  Built once, cached on the run context."""
        shapes = tuple((tuple(p.shape), str(p.dtype)) for p in pop.parents)
        key = ("s2_plan", pop.kernel, shapes)
        plan = ctx.caches.get(key)
        if plan is not None:
            return plan
        fam = scenario.family(pop.kernel)
        task_specs = tuple(jax.ShapeDtypeStruct(p.shape[1:], p.dtype)
                           for p in pop.parents)
        desc = TaskSignature.from_args(pop.kernel, task_specs).describe()
        spec = jax.eval_shape(fam.batched_body, *pop.parents)
        stats = ctx.stats.setdefault("regions", {}).setdefault(
            desc, {"submitted": 0, "launches": 0, "aggregated_hist": {}})
        width, scatters = 1, {}
        if getattr(ctx.config, "cost_model", False):
            model = None
            exe = getattr(ctx, "executor", None)
            if exe is not None:
                # under ``mixed`` the executor already timed the widths at
                # warmup (the table that routed the family here) — reuse
                # it instead of re-compiling every scatter program
                region = exe._primary_region(pop.kernel)
                if region is not None and region.cost.measured("s2"):
                    model = region.cost
            if model is None:
                model = BucketCostModel()
                times = measure_s2_widths(
                    fam.batched_body, pop.parents,
                    s2_width_candidates(pop.n_tasks),
                    samples=max(1,
                                int(getattr(ctx.config, "cost_samples", 3))),
                    cache=scatters)
                for w, t in times.items():
                    model.record(w, t, path="s2")
            best = model.predict_s2_wave(pop.n_tasks)
            if best is not None:
                width = best[0]
            if model.measured("s2"):
                stats["cost_model_paths"] = {"s2": model.as_stats("s2")}
        if width not in scatters:
            scatters[width] = make_s2_scatter(fam.batched_body, width)
        if pop.n_tasks % width and 1 not in scatters:
            scatters[1] = make_s2_scatter(fam.batched_body, 1)
        stats["selected_strategy"] = "s2"
        stats["s2_width"] = width
        plan = (width, scatters, spec, stats)
        ctx.caches[key] = plan
        return plan

    def launch_population(self, scenario, pop, ctx: RunContext):
        """Run ONE population through the scatter ring (shared with the
        ``mixed`` router's s2-routed families): width-w launches over the
        divisible span, width-1 over the remainder."""
        width, scatters, spec, stats = self._plan_for(scenario, pop, ctx)
        ring = jnp.zeros(spec.shape, spec.dtype)
        n = pop.n_tasks
        main = n - n % width
        for i in range(0, main, width):
            ring = ctx.pool.get().launch(scatters[width], ring, jnp.int32(i),
                                         *pop.parents, family=pop.kernel)
        for i in range(main, n):
            ring = ctx.pool.get().launch(scatters[1], ring, jnp.int32(i),
                                         *pop.parents, family=pop.kernel)
        launches = main // width + (n - main)
        ctx.stats["kernel_launches"] += launches
        stats["submitted"] += n
        stats["launches"] += launches
        hist = stats["aggregated_hist"]
        if main:
            hist[width] = hist.get(width, 0) + main // width
        if n - main:
            hist[1] = hist.get(1, 0) + (n - main)
        return ring

    def run_iteration(self, scenario, state, ctx: RunContext):
        outs = [self.launch_population(scenario, pop, ctx)
                for pop in scenario.populations(state)]
        return scenario.assemble(state, outs)
