"""``s2``: implicit aggregation — one launch per task, round-robin over a
pre-allocated executor pool; the runtime is left to overlap them (paper
finding: works iff the runtime can — reproduced here).

Each launch slices task ``i`` out of the population's parent arrays and
scatters its result into a donated output slot ring, all inside one
compiled program (``lax.dynamic_slice`` + ``lax.dynamic_update_slice`` on
an in-place buffer) — ZERO host-side slicing or concatenation.  The body
runs at bucket size 1, so every strategy executes the SAME compiled kernel
(bit-identical results by construction, the paper's shared-kernel design).

Tradeoff: the donated carry chains launches at the device level, which
costs nothing on XLA:CPU/TPU (one program at a time per core — only host
dispatch pipelining matters, and enqueues still return immediately) but
would forfeit inter-stream concurrency on a CUDA-like backend; DESIGN.md §3.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.strategies.base import RunContext, Strategy, register_strategy


def _make_scatter(batched):
    @partial(jax.jit, donate_argnums=(0,))
    def scatter(out_ring, i, *parents):
        task = tuple(jax.lax.dynamic_slice_in_dim(p, i, 1, axis=0)
                     for p in parents)
        return jax.lax.dynamic_update_slice(
            out_ring, batched(*task), (i,) + (0,) * (out_ring.ndim - 1))
    return scatter


@register_strategy("s2")
class S2Strategy(Strategy):
    name = "s2"

    def _scatter_for(self, scenario, kernel, ctx: RunContext):
        key = ("s2_scatter", kernel)
        fn = ctx.caches.get(key)
        if fn is None:
            fn = _make_scatter(scenario.family(kernel).batched_body)
            ctx.caches[key] = fn
        return fn

    def _ring_spec(self, scenario, pop, ctx: RunContext):
        shapes = tuple((p.shape, str(p.dtype)) for p in pop.parents)
        key = ("s2_out", pop.kernel, shapes)
        spec = ctx.caches.get(key)
        if spec is None:
            spec = jax.eval_shape(scenario.family(pop.kernel).batched_body,
                                  *pop.parents)
            ctx.caches[key] = spec
        return spec

    def run_iteration(self, scenario, state, ctx: RunContext):
        outs = []
        for pop in scenario.populations(state):
            scatter = self._scatter_for(scenario, pop.kernel, ctx)
            spec = self._ring_spec(scenario, pop, ctx)
            ring = jnp.zeros(spec.shape, spec.dtype)
            for i in range(pop.n_tasks):
                ring = ctx.pool.get().launch(scatter, ring, jnp.int32(i),
                                             *pop.parents, family=pop.kernel)
            outs.append(ring)
            ctx.stats["kernel_launches"] += pop.n_tasks
        return scenario.assemble(state, outs)
