"""The one execution facade: ``StrategyRunner(scenario, agg)``.

Replaces the legacy per-workload runners (``HydroStrategyRunner`` /
``AMRStrategyRunner`` survive below as deprecation shims): the runner owns
the executor pool, the (optional) multi-region ``AggregationExecutor``
with every scenario family registered, the unified stats, and the
scenario-agnostic drivers — RK3 stepping over arbitrary state pytrees,
AOT bucket warmup, and the ``lax.scan`` whole-trajectory program (now
uniform across scenarios, AMR included).

Strategy names are validated against the plugin registry at CONSTRUCTION
(listing the valid names on error), not on the first ``rhs()`` call.
"""
from __future__ import annotations

import time
import warnings
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AMRHydroConfig, AggregationConfig, HydroConfig,
)
from repro.core.aggregation import AggregationExecutor, greedy_decomposition
from repro.core.executor import ExecutorPool
from repro.core.faults import FaultInjector, NonFiniteStateError, all_finite
from repro.core.scenario import (
    AMRSedovScenario, Scenario, UniformSedovScenario,
)
from repro.core.strategies.base import RunContext, get_strategy_class


class StrategyRunner:
    """Drives any :class:`~repro.core.scenario.Scenario` under any
    registered strategy.  ``state`` is whatever pytree the scenario
    defines (a bare array for the uniform grid, ``(uc, uf)`` for AMR).

    ``stats`` is the unified observability surface: ``kernel_launches`` /
    ``iterations`` / ``staging_s`` accumulate per-call deltas for every
    strategy, and — when an aggregation executor exists — ``regions`` is a
    live view of the per-``TaskSignature``-family bucket histograms.
    Per-family launch counts are on ``launches_by_family``.
    """

    def __init__(self, scenario: Scenario, agg: AggregationConfig,
                 fault_injector: Optional[FaultInjector] = None):
        strategy_cls = get_strategy_class(agg.strategy)   # fail fast
        self.scenario = scenario
        self.agg = agg
        self.strategy = agg.strategy
        self._strategy = strategy_cls()
        self._guard = getattr(agg, "guard", "off")
        if self._guard not in ("off", "finite"):
            raise ValueError(
                f"guard={self._guard!r} — expected 'off' or 'finite'")
        self.pool = ExecutorPool(max(1, agg.n_executors))
        self._agg_exec: Optional[AggregationExecutor] = None
        self.stats: Dict[str, Any] = {"kernel_launches": 0, "iterations": 0,
                                      "staging_s": 0.0}
        self._validate_family_strategies(scenario, agg)
        if strategy_cls.uses_executor:
            self._agg_exec = AggregationExecutor(
                None, agg, pool=self.pool, name=scenario.name,
                fault_injector=fault_injector)
            for fam in scenario.families():
                self._agg_exec.register(fam.kernel, fam.batched_body)
            for fam in scenario.stage_families():
                self._agg_exec.register(fam.kernel, fam.batched_body)
            self.stats["regions"] = self._agg_exec.stats["regions"]
        else:
            # stats parity (DESIGN.md §12): executor-less strategies (s2 /
            # fused) publish per-family counters under the same key, so
            # the BENCH observability surface is strategy-independent
            self.stats["regions"] = {}
        self.ctx = RunContext(config=agg, pool=self.pool,
                              executor=self._agg_exec, stats=self.stats)
        # epilogue-fused RK stages (DESIGN.md §9): opt-in via config, only
        # when the scenario declares stage populations AND the strategy
        # overrides run_stage AND staging is device-resident — deciding
        # here (not at the first step) keeps warmup() warming the families
        # the run will actually launch
        from repro.core.strategies.base import Strategy as _StrategyBase
        strategy_has_stage = (type(self._strategy).run_stage
                              is not _StrategyBase.run_stage)
        self._fuse_epilogue = (getattr(agg, "fuse_epilogue", False)
                               and bool(scenario.stage_families())
                               and strategy_has_stage
                               and agg.staging != "host")
        self._traj_cache: Dict[int, Callable] = {}

    @staticmethod
    def _validate_family_strategies(scenario: Scenario,
                                    agg: AggregationConfig) -> None:
        """Fail fast on a bad ``family_strategies`` mapping: every value
        must be a valid route, every key a kernel the scenario can launch
        (plain or stage family, a "+epi" twin's base, or "*")."""
        fs = getattr(agg, "family_strategies", None)
        if not fs:
            return
        from repro.configs.base import FAMILY_STRATEGY_CHOICES
        known = {f.kernel for f in scenario.families()}
        known |= {f.kernel for f in scenario.stage_families()}
        valid_keys = known | {"*"}
        for kernel, choice in fs.items():
            if choice not in FAMILY_STRATEGY_CHOICES:
                raise ValueError(
                    f"family_strategies[{kernel!r}] = {choice!r} — valid "
                    f"assignments: {FAMILY_STRATEGY_CHOICES}")
            if kernel not in valid_keys:
                raise ValueError(
                    f"family_strategies key {kernel!r} names no kernel "
                    f"family of scenario {scenario.name!r} — known "
                    f"families: {sorted(known)} (or '*')")

    # -- observability -----------------------------------------------------
    @property
    def executor(self) -> Optional[AggregationExecutor]:
        """The multi-region aggregation executor (s3/s2+s3), else None."""
        return self._agg_exec

    def set_fault_injector(self, injector: Optional[FaultInjector]) -> None:
        """Arm (or disarm with None) deterministic fault injection on the
        aggregation executor.  Executor-less strategies (fused / s2) have
        no injection sites — for them only the runner-level guard applies."""
        if self._agg_exec is not None:
            self._agg_exec.set_fault_injector(injector)

    @property
    def launches_by_family(self) -> dict:
        return self.pool.launches_by_family

    # -- warmup ------------------------------------------------------------
    def warmup(self, wave_only: bool = False,
               store: Optional[Any] = None) -> None:
        """AOT pre-compile every family's gather/prefix buckets from the
        parent shapes the scenario's submission waves will reference
        (shape-agreeing waves are deduplicated).

        ``wave_only=True`` restricts AOT to the buckets a full wave's greedy
        decomposition uses (the steady state under a pinned watermark) —
        the benchmark's compile budget; other buckets compile lazily.  When
        the epilogue-fused stage path is active, only the stage families
        are warmed — the plain families never launch on that path.

        ``store`` (DESIGN.md §13) passes a persistent tune store through
        to the executor: families with a valid stored entry load their
        tuned state instead of measuring it, and bucket compiles become
        persistent-cache disk hits.
        """
        if self._agg_exec is None:
            return
        if self._fuse_epilogue:
            specs = tuple(self.scenario.stage_warmup_parent_specs())
        else:
            specs = tuple(self.scenario.warmup_parent_specs())
        seen = set()
        for kernel, parent_specs in specs:
            key = (kernel, tuple((tuple(p.shape), str(p.dtype))
                                 for p in parent_specs))
            if key in seen:
                continue
            seen.add(key)
            buckets = None
            if wave_only:
                ladder = self._agg_exec.config.bucket_sizes()
                wave = min(p.shape[0] for p in parent_specs)
                buckets = tuple(sorted(set(greedy_decomposition(wave,
                                                                ladder))))
            self._agg_exec.warmup(kernel=kernel, parent_shapes=parent_specs,
                                  buckets=buckets, store=store)

    def save_tuning(self, store: Optional[Any] = None) -> Optional[str]:
        """Persist every tuned family's state into the tune store (the
        config's, or an explicit path/instance).  No-op (returns None)
        for executor-less strategies or when no store is configured."""
        if self._agg_exec is None:
            return None
        return self._agg_exec.save_tuning(store)

    # -- one solver iteration ----------------------------------------------
    def rhs(self, state):
        self.stats["iterations"] += 1
        out = self._strategy.run_iteration(self.scenario, state, self.ctx)
        if self._guard == "finite" and self._agg_exec is None:
            # executor-less strategies (fused / s2) have no per-bucket
            # containment layer — the guard degrades to a whole-iteration
            # tripwire so guard="finite" still means "never silently
            # propagate a non-finite state" under every strategy
            if not all_finite(out):
                raise NonFiniteStateError(
                    f"non-finite rhs output under strategy "
                    f"{self.strategy!r} (iteration "
                    f"{self.stats['iterations']}); executor-less strategies "
                    f"cannot bisect — rerun under s3 to isolate the task")
        return out

    # -- RK3 (three iterations per time-step, as in the paper) -------------
    def rk3_step(self, state, dt):
        if self._fuse_epilogue:
            out = self._rk3_step_fused_stages(state, dt)
            if out is not None:
                return out
        tm = jax.tree_util.tree_map
        l0 = self.rhs(state)
        u1 = tm(lambda u, l: u + dt * l, state, l0)
        l1 = self.rhs(u1)
        u2 = tm(lambda u, a, l: 0.75 * u + 0.25 * (a + dt * l),
                state, u1, l1)
        l2 = self.rhs(u2)
        out = tm(lambda u, a, l: (1.0 / 3.0) * u + (2.0 / 3.0) * (a + dt * l),
                 state, u2, l2)
        return self.scenario.finalize_step(out)

    def _rk3_step_fused_stages(self, state, dt):
        """RK3 through the epilogue-fused stage path: each Shu-Osher stage
        is one submission wave of the scenario's stage families — gather,
        body and stage axpy in ONE program per bucket (DESIGN.md §9).
        Returns None (falling back to the generic path) when the strategy
        has no ``run_stage``."""
        stage = self._strategy.run_stage
        sc = self.scenario
        u1 = stage(sc, state, state, dt, 0.0, 1.0, self.ctx)
        if u1 is None:
            self._fuse_epilogue = False       # strategy has no stage path
            return None
        self.stats["iterations"] += 1
        u2 = stage(sc, state, u1, dt, 0.75, 0.25, self.ctx)
        self.stats["iterations"] += 1
        out = stage(sc, state, u2, dt, 1.0 / 3.0, 2.0 / 3.0, self.ctx)
        self.stats["iterations"] += 1
        return sc.finalize_step(out)

    # -- whole-trajectory scan driver (fused upper bound) ------------------
    def _trajectory_impl(self, n_steps: int, state, dt):
        tm = jax.tree_util.tree_map

        def body(s, _):
            l0 = self.scenario.reference_rhs(s)
            u1 = tm(lambda u, l: u + dt * l, s, l0)
            l1 = self.scenario.reference_rhs(u1)
            u2 = tm(lambda u, a, l: 0.75 * u + 0.25 * (a + dt * l),
                    s, u1, l1)
            l2 = self.scenario.reference_rhs(u2)
            out = tm(lambda u, a, l: (1.0 / 3.0) * u
                     + (2.0 / 3.0) * (a + dt * l), s, u2, l2)
            return self.scenario.finalize_step(out), None

        out, _ = jax.lax.scan(body, state, None, length=n_steps)
        return out

    def rk3_trajectory(self, state, dt, n_steps: int):
        """Run ``n_steps`` RK3 steps.  Under ``fused`` the whole trajectory
        is ONE donated ``lax.scan`` program (single dispatch, state updated
        in place) — for EVERY scenario, AMR included; other strategies
        fall back to the per-step loop."""
        if self.strategy != "fused":
            for _ in range(n_steps):
                state = self.rk3_step(state, dt)
            return state
        fn = self._traj_cache.get(n_steps)
        if fn is None:
            fn = jax.jit(partial(self._trajectory_impl, n_steps),
                         donate_argnums=(0,))
            self._traj_cache[n_steps] = fn
        # donate a private copy so the caller's state stays valid; inside
        # the program the scan carry aliases the donated buffers
        out = fn(jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                        state), dt)
        self.stats["kernel_launches"] += 1
        self.stats["iterations"] += 3 * n_steps
        return out

    def time_step(self, state, dt, n_steps: int = 1,
                  use_scan: bool = False) -> float:
        """Average wall seconds per time-step (the Table III metric)."""
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        if use_scan and self.strategy == "fused":
            out = self.rk3_trajectory(state, dt, n_steps)
        else:
            out = state
            for _ in range(n_steps):
                out = self.rk3_step(out, dt)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n_steps


# ---------------------------------------------------------------------------
# deprecation shims over the facade (state/call conventions are the new
# ones: the AMR runner's state is a (uc, uf) tuple)
# ---------------------------------------------------------------------------

def HydroStrategyRunner(cfg: HydroConfig, agg: AggregationConfig,
                        bc: str = "outflow", body=None, batched_body=None):
    """Deprecated: ``StrategyRunner(UniformSedovScenario(cfg), agg)``."""
    warnings.warn(
        "HydroStrategyRunner is deprecated — use "
        "StrategyRunner(UniformSedovScenario(cfg), agg)",
        DeprecationWarning, stacklevel=2)
    return StrategyRunner(UniformSedovScenario(cfg, bc=bc, body=body,
                                               batched_body=batched_body), agg)


def AMRStrategyRunner(cfg: AMRHydroConfig, agg: AggregationConfig,
                      bc: str = "outflow"):
    """Deprecated: ``StrategyRunner(AMRSedovScenario(cfg), agg)``."""
    warnings.warn(
        "AMRStrategyRunner is deprecated — use "
        "StrategyRunner(AMRSedovScenario(cfg), agg)",
        DeprecationWarning, stacklevel=2)
    return StrategyRunner(AMRSedovScenario(cfg, bc=bc), agg)
