"""Work-aggregation strategy runners (the paper's S1 / S2 / S3 and combos).

``HydroStrategyRunner`` executes one hydro RK3 time-step where every
per-sub-grid Reconstruct+Flux task is launched according to a strategy:

* ``s1``   — larger sub-problems: not a runtime mode but a *config* (16^3
             sub-grids via ``repro.configs.sedov.CONFIG_16``); the runner
             accepts any HydroConfig, so s1 is "same runner, bigger blocks".
* ``s2``   — implicit aggregation: one launch per task, round-robin over a
             pre-allocated executor pool; the runtime is left to overlap them
             (paper finding: works iff the runtime can — reproduced here).
             Each launch scatters its result into a donated output slot ring
             (``lax.dynamic_update_slice`` on an in-place buffer), so the
             iteration performs ZERO host-side slicing or concatenation.
* ``s3``   — explicit aggregation: tasks are fused on-the-fly into bucketed
             batched kernels by the AggregationExecutor.  Inputs are staged
             by slot index (``submit_indexed``): one gather per launch over
             the already-device-resident sub-grid array, per DESIGN.md §3.
* ``s2+s3``— s3 with multiple underlying executors (the paper's best rows).
* ``fused``— beyond-paper upper bound: the whole iteration as ONE XLA
             program (what a static whole-graph compiler can do when the
             task structure is known ahead of time; the paper's dynamic AMR
             setting is precisely where this is NOT generally available).
             ``rk3_trajectory`` extends this to whole multi-step RK3
             trajectories dispatched as ONE ``lax.scan`` program with the
             state buffer donated (the Table III upper-bound row).

All strategies are bit-identical in results (tested); only launch structure
differs.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AMRHydroConfig, AggregationConfig, HydroConfig
from repro.core.aggregation import AggregationExecutor, gather_futures
from repro.core.executor import ExecutorPool
from repro.hydro.state import (
    assemble_global, extract_subgrids, extract_subgrids_multilevel,
)
from repro.hydro.stepper import (
    amr_rk3_step, level_batched_body, level_batched_jit, subgrid_rhs,
)


def xla_task_body(cfg: HydroConfig, h: float) -> Callable:
    """The fine-grained task body: (F, P, P, P) -> (F, S, S, S)."""
    return partial(subgrid_rhs, h=h, gamma=cfg.gamma,
                   ghost=cfg.ghost, subgrid=cfg.subgrid)


class HydroStrategyRunner:
    def __init__(self, cfg: HydroConfig, agg: AggregationConfig,
                 bc: str = "outflow",
                 body: Optional[Callable] = None,
                 batched_body: Optional[Callable] = None):
        self.cfg = cfg
        self.agg = agg
        self.bc = bc
        n = cfg.grids_per_edge * cfg.subgrid
        self.h = cfg.domain / n
        self.body = body or xla_task_body(cfg, self.h)
        self.batched_body = batched_body or jax.vmap(self.body)
        self.strategy = agg.strategy

        self._jit_body = jax.jit(self.body)
        self._jit_batched = jax.jit(self.batched_body)
        # s2: one compiled program reused for every task — slice task i out
        # of the resident sub-grid array and scatter the result into its
        # output-ring slot, both inside the program (no subs[i:i+1] host
        # slicing, no per-iteration jnp.concatenate).  The ring is donated,
        # so XLA reuses one output buffer across all n launches.
        self._s2_scatter = jax.jit(self._s2_scatter_impl, donate_argnums=(0,))
        self._traj_cache: Dict[int, Callable] = {}
        self.pool = ExecutorPool(max(1, agg.n_executors))
        self._agg_exec: Optional[AggregationExecutor] = None
        if self.strategy in ("s3", "s2+s3"):
            self._agg_exec = AggregationExecutor(
                self.batched_body, agg, pool=self.pool, name="hydro_rhs")
        self.stats: Dict[str, float] = {"kernel_launches": 0, "iterations": 0,
                                        "staging_s": 0.0}

    def _s2_scatter_impl(self, out_ring, subs, i):
        task = jax.lax.dynamic_slice_in_dim(subs, i, 1, axis=0)
        return jax.lax.dynamic_update_slice(
            out_ring, self.batched_body(task),
            (i,) + (0,) * (out_ring.ndim - 1))

    # -- one hydro iteration: ghost exchange + all sub-grid tasks ---------
    def rhs(self, u: jax.Array) -> jax.Array:
        subs = extract_subgrids(u, self.cfg.subgrid, self.cfg.ghost, self.bc)
        n = subs.shape[0]
        self.stats["iterations"] += 1

        if self.strategy == "fused":
            out = self._jit_batched(subs)
            self.stats["kernel_launches"] += 1
        elif self.strategy == "s2":
            # one launch per fine-grained task, round-robin over executors.
            # Uses the batched body at bucket size 1 so every strategy runs
            # the SAME compiled program (bit-identical results by
            # construction, matching the paper's shared-kernel design).
            # Results assemble via a single donated slot ring — each launch
            # writes its slot in place; no host-side stitching remains.
            # Tradeoff: the donated carry chains launches at the device
            # level, which costs nothing on XLA:CPU/TPU (one program at a
            # time per core — only host dispatch pipelining matters, and
            # enqueues still return immediately) but would forfeit
            # inter-stream concurrency on a CUDA-like backend; see
            # DESIGN.md §3.
            s = self.cfg.subgrid
            out = jnp.zeros((n, self.cfg.n_fields, s, s, s), subs.dtype)
            for i in range(n):
                exe = self.pool.get()
                out = exe.launch(self._s2_scatter, out, subs, jnp.int32(i))
            self.stats["kernel_launches"] += n
        elif self.strategy in ("s3", "s2+s3"):
            exe = self._agg_exec
            # every strategy reports per-call DELTAS (+=); the executor's own
            # counters are cumulative, so snapshot around the submission wave
            before_launches = exe.stats["launches"]
            before_staging = exe.stats["staging_s"]
            if self.agg.staging == "host":
                # the seed's path, kept measurable: slice each task apart on
                # the host queue, re-stack per launch
                futs = [exe.submit(subs[i]) for i in range(n)]
            else:
                futs = [exe.submit_indexed((subs,), i) for i in range(n)]
            exe.flush()
            out = gather_futures(futs)
            self.stats["staging_s"] += exe.stats["staging_s"] - before_staging
            self.stats["kernel_launches"] += (exe.stats["launches"]
                                              - before_launches)
        else:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        return assemble_global(out, self.cfg.subgrid)

    # -- RK3 (three iterations per time-step, as in the paper) ------------
    def rk3_step(self, u: jax.Array, dt) -> jax.Array:
        l0 = self.rhs(u)
        u1 = u + dt * l0
        l1 = self.rhs(u1)
        u2 = 0.75 * u + 0.25 * (u1 + dt * l1)
        l2 = self.rhs(u2)
        return (1.0 / 3.0) * u + (2.0 / 3.0) * (u2 + dt * l2)

    # -- whole-trajectory scan driver (fused upper bound) -----------------
    def _trajectory_impl(self, n_steps: int, u, dt):
        def one_rhs(v):
            subs = extract_subgrids(v, self.cfg.subgrid, self.cfg.ghost,
                                    self.bc)
            return assemble_global(self.batched_body(subs), self.cfg.subgrid)

        def body(v, _):
            l0 = one_rhs(v)
            u1 = v + dt * l0
            l1 = one_rhs(u1)
            u2 = 0.75 * v + 0.25 * (u1 + dt * l1)
            l2 = one_rhs(u2)
            return (1.0 / 3.0) * v + (2.0 / 3.0) * (u2 + dt * l2), None

        out, _ = jax.lax.scan(body, u, None, length=n_steps)
        return out

    def rk3_trajectory(self, u: jax.Array, dt, n_steps: int) -> jax.Array:
        """Run ``n_steps`` RK3 steps.  Under ``fused`` the whole trajectory
        is ONE donated ``lax.scan`` program (single dispatch, state updated
        in place); other strategies fall back to the per-step loop."""
        if self.strategy != "fused":
            for _ in range(n_steps):
                u = self.rk3_step(u, dt)
            return u
        fn = self._traj_cache.get(n_steps)
        if fn is None:
            fn = jax.jit(partial(self._trajectory_impl, n_steps),
                         donate_argnums=(0,))
            self._traj_cache[n_steps] = fn
        # donate a private copy so the caller's state array stays valid;
        # inside the program the scan carry aliases the donated buffer
        out = fn(jnp.array(u, copy=True), dt)
        self.stats["kernel_launches"] += 1
        self.stats["iterations"] += 3 * n_steps
        return out

    def time_step(self, u: jax.Array, dt, n_steps: int = 1,
                  use_scan: bool = False) -> float:
        """Average wall seconds per time-step (the Table III metric)."""
        out = u
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        if use_scan and self.strategy == "fused":
            out = self.rk3_trajectory(out, dt, n_steps)
        else:
            for _ in range(n_steps):
                out = self.rk3_step(out, dt)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n_steps


# ---------------------------------------------------------------------------
# Two-level AMR runner: a mixed coarse+fine task population through one
# multi-region AggregationExecutor
# ---------------------------------------------------------------------------

class AMRStrategyRunner:
    """Drives the two-level refined Sedov scenario under every strategy.

    Each RK3 iteration produces a *mixed* task list — every coarse sub-grid
    and every fine sub-grid, with per-level cell width ``h`` as a traced
    per-task argument.  Under s3/s2+s3 both levels flow through ONE
    :class:`AggregationExecutor`: levels whose sub-grid shapes agree share a
    single ``TaskSignature`` family (the same compiled buckets serve both),
    while mixed sub-grid sizes open two families that aggregate concurrently
    (distinct rings/buckets, interleaved launches).

    All strategies are bit-identical to the per-level fused reference
    (``repro.hydro.stepper.amr_reference_rhs``) — enforced by
    tests/test_amr.py.
    """

    def __init__(self, cfg: AMRHydroConfig, agg: AggregationConfig,
                 bc: str = "outflow"):
        self.cfg = cfg
        self.agg = agg
        self.bc = bc
        self.strategy = agg.strategy
        dtype = jnp.dtype(cfg.dtype)
        self._levels = ("coarse", "fine")
        self._subgrid = {"coarse": cfg.coarse_subgrid,
                         "fine": cfg.fine_subgrid}
        self._h = {
            "coarse": jnp.full((cfg.n_subgrids_coarse,), cfg.h_coarse, dtype),
            "fine": jnp.full((cfg.n_subgrids_fine,), cfg.h_fine, dtype),
        }
        # one body per DISTINCT sub-grid size; equal sizes share everything
        # (kernel id, region, compiled buckets) — the shape-agreement case
        self._kernel = {lvl: f"hydro_rhs_s{self._subgrid[lvl]}"
                        for lvl in self._levels}
        self._batched = {s: level_batched_body(cfg.gamma, cfg.ghost, s)
                         for s in set(self._subgrid.values())}
        self._jit_batched = {s: level_batched_jit(cfg.gamma, cfg.ghost, s)
                             for s in set(self._subgrid.values())}
        self._s2_scatter = {s: self._make_s2_scatter(self._batched[s])
                            for s in set(self._subgrid.values())}
        self.pool = ExecutorPool(max(1, agg.n_executors))
        self._agg_exec: Optional[AggregationExecutor] = None
        if self.strategy in ("s3", "s2+s3"):
            self._agg_exec = AggregationExecutor(
                None, agg, pool=self.pool, name="amr_hydro_rhs")
            for s in set(self._subgrid.values()):
                self._agg_exec.register(f"hydro_rhs_s{s}", self._batched[s])
        self.stats: Dict[str, float] = {"kernel_launches": 0, "iterations": 0,
                                        "staging_s": 0.0}

    @staticmethod
    def _make_s2_scatter(batched):
        @partial(jax.jit, donate_argnums=(0,))
        def scatter(out_ring, subs, h_vec, i):
            task = jax.lax.dynamic_slice_in_dim(subs, i, 1, axis=0)
            hk = jax.lax.dynamic_slice_in_dim(h_vec, i, 1, axis=0)
            return jax.lax.dynamic_update_slice(
                out_ring, batched(task, hk),
                (i,) + (0,) * (out_ring.ndim - 1))
        return scatter

    def warmup(self) -> None:
        """AOT pre-compile every family's gather/prefix buckets from the
        parent shapes the submission waves will reference."""
        if self._agg_exec is None:
            return
        seen = set()
        for lvl in self._levels:
            n = (self.cfg.n_subgrids_coarse if lvl == "coarse"
                 else self.cfg.n_subgrids_fine)
            s = self._subgrid[lvl]
            p = s + 2 * self.cfg.ghost
            dtype = jnp.dtype(self.cfg.dtype)
            subs_spec = jax.ShapeDtypeStruct(
                (n, self.cfg.n_fields, p, p, p), dtype)
            h_spec = jax.ShapeDtypeStruct((n,), dtype)
            key = (self._kernel[lvl], subs_spec.shape, h_spec.shape)
            if key in seen:       # shape-agreeing levels share the programs
                continue
            seen.add(key)
            self._agg_exec.warmup(kernel=self._kernel[lvl],
                                  parent_shapes=(subs_spec, h_spec))

    # -- one two-level iteration ------------------------------------------
    def rhs(self, uc: jax.Array, uf: jax.Array):
        subs = dict(zip(self._levels,
                        extract_subgrids_multilevel(uc, uf, self.cfg,
                                                    self.bc)))
        self.stats["iterations"] += 1
        out: Dict[str, jax.Array] = {}

        if self.strategy == "fused":
            for lvl in self._levels:
                out[lvl] = self._jit_batched[self._subgrid[lvl]](
                    subs[lvl], self._h[lvl])
                self.stats["kernel_launches"] += 1
        elif self.strategy == "s2":
            for lvl in self._levels:
                n = subs[lvl].shape[0]
                s = self._subgrid[lvl]
                ring = jnp.zeros((n, self.cfg.n_fields, s, s, s),
                                 subs[lvl].dtype)
                scatter = self._s2_scatter[s]
                for i in range(n):
                    ring = self.pool.get().launch(
                        scatter, ring, subs[lvl], self._h[lvl], jnp.int32(i))
                out[lvl] = ring
                self.stats["kernel_launches"] += n
        elif self.strategy in ("s3", "s2+s3"):
            exe = self._agg_exec
            before_launches = exe.stats["launches"]
            before_staging = exe.stats["staging_s"]
            futs = {lvl: [exe.submit_indexed((subs[lvl], self._h[lvl]), i,
                                             kernel=self._kernel[lvl])
                          for i in range(subs[lvl].shape[0])]
                    for lvl in self._levels}
            exe.flush()
            for lvl in self._levels:
                out[lvl] = gather_futures(futs[lvl])
            self.stats["staging_s"] += exe.stats["staging_s"] - before_staging
            self.stats["kernel_launches"] += (exe.stats["launches"]
                                              - before_launches)
        else:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        return tuple(assemble_global(out[lvl], self._subgrid[lvl])
                     for lvl in self._levels)

    def rk3_step(self, uc: jax.Array, uf: jax.Array, dt):
        return amr_rk3_step(self.rhs, uc, uf, dt, self.cfg)

    def time_step(self, uc, uf, dt, n_steps: int = 1) -> float:
        """Average wall seconds per two-level time-step."""
        jax.block_until_ready((uc, uf))
        t0 = time.perf_counter()
        for _ in range(n_steps):
            uc, uf = self.rk3_step(uc, uf, dt)
        jax.block_until_ready((uc, uf))
        return (time.perf_counter() - t0) / n_steps
