"""Work-aggregation strategy runners (the paper's S1 / S2 / S3 and combos).

``HydroStrategyRunner`` executes one hydro RK3 time-step where every
per-sub-grid Reconstruct+Flux task is launched according to a strategy:

* ``s1``   — larger sub-problems: not a runtime mode but a *config* (16^3
             sub-grids via ``repro.configs.sedov.CONFIG_16``); the runner
             accepts any HydroConfig, so s1 is "same runner, bigger blocks".
* ``s2``   — implicit aggregation: one launch per task, round-robin over a
             pre-allocated executor pool; the runtime is left to overlap them
             (paper finding: works iff the runtime can — reproduced here).
             Each launch scatters its result into a donated output slot ring
             (``lax.dynamic_update_slice`` on an in-place buffer), so the
             iteration performs ZERO host-side slicing or concatenation.
* ``s3``   — explicit aggregation: tasks are fused on-the-fly into bucketed
             batched kernels by the AggregationExecutor.  Inputs are staged
             by slot index (``submit_indexed``): one gather per launch over
             the already-device-resident sub-grid array, per DESIGN.md §3.
* ``s2+s3``— s3 with multiple underlying executors (the paper's best rows).
* ``fused``— beyond-paper upper bound: the whole iteration as ONE XLA
             program (what a static whole-graph compiler can do when the
             task structure is known ahead of time; the paper's dynamic AMR
             setting is precisely where this is NOT generally available).
             ``rk3_trajectory`` extends this to whole multi-step RK3
             trajectories dispatched as ONE ``lax.scan`` program with the
             state buffer donated (the Table III upper-bound row).

All strategies are bit-identical in results (tested); only launch structure
differs.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core.aggregation import AggregationExecutor, gather_futures
from repro.core.executor import ExecutorPool
from repro.hydro.state import assemble_global, extract_subgrids
from repro.hydro.stepper import subgrid_rhs


def xla_task_body(cfg: HydroConfig, h: float) -> Callable:
    """The fine-grained task body: (F, P, P, P) -> (F, S, S, S)."""
    return partial(subgrid_rhs, h=h, gamma=cfg.gamma,
                   ghost=cfg.ghost, subgrid=cfg.subgrid)


class HydroStrategyRunner:
    def __init__(self, cfg: HydroConfig, agg: AggregationConfig,
                 bc: str = "outflow",
                 body: Optional[Callable] = None,
                 batched_body: Optional[Callable] = None):
        self.cfg = cfg
        self.agg = agg
        self.bc = bc
        n = cfg.grids_per_edge * cfg.subgrid
        self.h = cfg.domain / n
        self.body = body or xla_task_body(cfg, self.h)
        self.batched_body = batched_body or jax.vmap(self.body)
        self.strategy = agg.strategy

        self._jit_body = jax.jit(self.body)
        self._jit_batched = jax.jit(self.batched_body)
        # s2: one compiled program reused for every task — slice task i out
        # of the resident sub-grid array and scatter the result into its
        # output-ring slot, both inside the program (no subs[i:i+1] host
        # slicing, no per-iteration jnp.concatenate).  The ring is donated,
        # so XLA reuses one output buffer across all n launches.
        self._s2_scatter = jax.jit(self._s2_scatter_impl, donate_argnums=(0,))
        self._traj_cache: Dict[int, Callable] = {}
        self.pool = ExecutorPool(max(1, agg.n_executors))
        self._agg_exec: Optional[AggregationExecutor] = None
        if self.strategy in ("s3", "s2+s3"):
            self._agg_exec = AggregationExecutor(
                self.batched_body, agg, pool=self.pool, name="hydro_rhs")
        self.stats: Dict[str, float] = {"kernel_launches": 0, "iterations": 0,
                                        "staging_s": 0.0}

    def _s2_scatter_impl(self, out_ring, subs, i):
        task = jax.lax.dynamic_slice_in_dim(subs, i, 1, axis=0)
        return jax.lax.dynamic_update_slice(
            out_ring, self.batched_body(task),
            (i,) + (0,) * (out_ring.ndim - 1))

    # -- one hydro iteration: ghost exchange + all sub-grid tasks ---------
    def rhs(self, u: jax.Array) -> jax.Array:
        subs = extract_subgrids(u, self.cfg.subgrid, self.cfg.ghost, self.bc)
        n = subs.shape[0]
        self.stats["iterations"] += 1

        if self.strategy == "fused":
            out = self._jit_batched(subs)
            self.stats["kernel_launches"] += 1
        elif self.strategy == "s2":
            # one launch per fine-grained task, round-robin over executors.
            # Uses the batched body at bucket size 1 so every strategy runs
            # the SAME compiled program (bit-identical results by
            # construction, matching the paper's shared-kernel design).
            # Results assemble via a single donated slot ring — each launch
            # writes its slot in place; no host-side stitching remains.
            # Tradeoff: the donated carry chains launches at the device
            # level, which costs nothing on XLA:CPU/TPU (one program at a
            # time per core — only host dispatch pipelining matters, and
            # enqueues still return immediately) but would forfeit
            # inter-stream concurrency on a CUDA-like backend; see
            # DESIGN.md §3.
            s = self.cfg.subgrid
            out = jnp.zeros((n, self.cfg.n_fields, s, s, s), subs.dtype)
            for i in range(n):
                exe = self.pool.get()
                out = exe.launch(self._s2_scatter, out, subs, jnp.int32(i))
            self.stats["kernel_launches"] += n
        elif self.strategy in ("s3", "s2+s3"):
            exe = self._agg_exec
            if self.agg.staging == "host":
                # the seed's path, kept measurable: slice each task apart on
                # the host queue, re-stack per launch
                futs = [exe.submit(subs[i]) for i in range(n)]
            else:
                futs = [exe.submit_indexed((subs,), i) for i in range(n)]
            exe.flush()
            out = gather_futures(futs)
            self.stats["staging_s"] = exe.stats["staging_s"]
            self.stats["kernel_launches"] = exe.stats["launches"]
        else:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        return assemble_global(out, self.cfg.subgrid)

    # -- RK3 (three iterations per time-step, as in the paper) ------------
    def rk3_step(self, u: jax.Array, dt) -> jax.Array:
        l0 = self.rhs(u)
        u1 = u + dt * l0
        l1 = self.rhs(u1)
        u2 = 0.75 * u + 0.25 * (u1 + dt * l1)
        l2 = self.rhs(u2)
        return (1.0 / 3.0) * u + (2.0 / 3.0) * (u2 + dt * l2)

    # -- whole-trajectory scan driver (fused upper bound) -----------------
    def _trajectory_impl(self, n_steps: int, u, dt):
        def one_rhs(v):
            subs = extract_subgrids(v, self.cfg.subgrid, self.cfg.ghost,
                                    self.bc)
            return assemble_global(self.batched_body(subs), self.cfg.subgrid)

        def body(v, _):
            l0 = one_rhs(v)
            u1 = v + dt * l0
            l1 = one_rhs(u1)
            u2 = 0.75 * v + 0.25 * (u1 + dt * l1)
            l2 = one_rhs(u2)
            return (1.0 / 3.0) * v + (2.0 / 3.0) * (u2 + dt * l2), None

        out, _ = jax.lax.scan(body, u, None, length=n_steps)
        return out

    def rk3_trajectory(self, u: jax.Array, dt, n_steps: int) -> jax.Array:
        """Run ``n_steps`` RK3 steps.  Under ``fused`` the whole trajectory
        is ONE donated ``lax.scan`` program (single dispatch, state updated
        in place); other strategies fall back to the per-step loop."""
        if self.strategy != "fused":
            for _ in range(n_steps):
                u = self.rk3_step(u, dt)
            return u
        fn = self._traj_cache.get(n_steps)
        if fn is None:
            fn = jax.jit(partial(self._trajectory_impl, n_steps),
                         donate_argnums=(0,))
            self._traj_cache[n_steps] = fn
        # donate a private copy so the caller's state array stays valid;
        # inside the program the scan carry aliases the donated buffer
        out = fn(jnp.array(u, copy=True), dt)
        self.stats["kernel_launches"] += 1
        self.stats["iterations"] += 3 * n_steps
        return out

    def time_step(self, u: jax.Array, dt, n_steps: int = 1,
                  use_scan: bool = False) -> float:
        """Average wall seconds per time-step (the Table III metric)."""
        out = u
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        if use_scan and self.strategy == "fused":
            out = self.rk3_trajectory(out, dt, n_steps)
        else:
            for _ in range(n_steps):
                out = self.rk3_step(out, dt)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n_steps
