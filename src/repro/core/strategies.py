"""Work-aggregation strategy runners (the paper's S1 / S2 / S3 and combos).

``HydroStrategyRunner`` executes one hydro RK3 time-step where every
per-sub-grid Reconstruct+Flux task is launched according to a strategy:

* ``s1``   — larger sub-problems: not a runtime mode but a *config* (16^3
             sub-grids via ``repro.configs.sedov.CONFIG_16``); the runner
             accepts any HydroConfig, so s1 is "same runner, bigger blocks".
* ``s2``   — implicit aggregation: one launch per task, round-robin over a
             pre-allocated executor pool; the runtime is left to overlap them
             (paper finding: works iff the runtime can — reproduced here).
* ``s3``   — explicit aggregation: tasks are fused on-the-fly into bucketed
             batched kernels by the AggregationExecutor.
* ``s2+s3``— s3 with multiple underlying executors (the paper's best rows).
* ``fused``— beyond-paper upper bound: the whole iteration as ONE XLA
             program (what a static whole-graph compiler can do when the
             task structure is known ahead of time; the paper's dynamic AMR
             setting is precisely where this is NOT generally available).

All strategies are bit-identical in results (tested); only launch structure
differs.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core.aggregation import AggregationExecutor
from repro.core.executor import ExecutorPool
from repro.hydro.state import assemble_global, extract_subgrids
from repro.hydro.stepper import subgrid_rhs


def xla_task_body(cfg: HydroConfig, h: float) -> Callable:
    """The fine-grained task body: (F, P, P, P) -> (F, S, S, S)."""
    return partial(subgrid_rhs, h=h, gamma=cfg.gamma,
                   ghost=cfg.ghost, subgrid=cfg.subgrid)


class HydroStrategyRunner:
    def __init__(self, cfg: HydroConfig, agg: AggregationConfig,
                 bc: str = "outflow",
                 body: Optional[Callable] = None,
                 batched_body: Optional[Callable] = None):
        self.cfg = cfg
        self.agg = agg
        self.bc = bc
        n = cfg.grids_per_edge * cfg.subgrid
        self.h = cfg.domain / n
        self.body = body or xla_task_body(cfg, self.h)
        self.batched_body = batched_body or jax.vmap(self.body)
        self.strategy = agg.strategy

        self._jit_body = jax.jit(self.body)
        self._jit_batched = jax.jit(self.batched_body)
        self.pool = ExecutorPool(max(1, agg.n_executors))
        self._agg_exec: Optional[AggregationExecutor] = None
        if self.strategy in ("s3", "s2+s3"):
            self._agg_exec = AggregationExecutor(
                self.batched_body, agg, pool=self.pool, name="hydro_rhs")
        self.stats: Dict[str, int] = {"kernel_launches": 0, "iterations": 0}

    # -- one hydro iteration: ghost exchange + all sub-grid tasks ---------
    def rhs(self, u: jax.Array) -> jax.Array:
        subs = extract_subgrids(u, self.cfg.subgrid, self.cfg.ghost, self.bc)
        n = subs.shape[0]
        self.stats["iterations"] += 1

        if self.strategy == "fused":
            out = self._jit_batched(subs)
            self.stats["kernel_launches"] += 1
        elif self.strategy == "s2":
            # one launch per fine-grained task, round-robin over executors.
            # Uses the batched body at bucket size 1 so every strategy runs
            # the SAME compiled program (bit-identical results by
            # construction, matching the paper's shared-kernel design).
            results = [None] * n
            for i in range(n):
                exe = self.pool.get()
                results[i] = exe.launch(self._jit_batched, subs[i:i + 1])
            self.stats["kernel_launches"] += n
            out = jnp.concatenate(results)
        elif self.strategy in ("s3", "s2+s3"):
            exe = self._agg_exec
            futs = [exe.submit(subs[i]) for i in range(n)]
            exe.flush()
            out = jnp.stack([f.result() for f in futs])
            self.stats["kernel_launches"] = exe.stats["launches"]
        else:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        return assemble_global(out, self.cfg.subgrid)

    # -- RK3 (three iterations per time-step, as in the paper) ------------
    def rk3_step(self, u: jax.Array, dt) -> jax.Array:
        l0 = self.rhs(u)
        u1 = u + dt * l0
        l1 = self.rhs(u1)
        u2 = 0.75 * u + 0.25 * (u1 + dt * l1)
        l2 = self.rhs(u2)
        return (1.0 / 3.0) * u + (2.0 / 3.0) * (u2 + dt * l2)

    def time_step(self, u: jax.Array, dt, n_steps: int = 1) -> float:
        """Average wall seconds per time-step (the Table III metric)."""
        out = u
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(n_steps):
            out = self.rk3_step(out, dt)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n_steps
