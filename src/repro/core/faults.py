"""Fault injection + containment primitives for the aggregation runtime.

Aggregation deliberately widens the blast radius of every failure: one
poisoned task (a NaN blow-up, a bad tenant input, a failed bucket compile)
corrupts an entire mega-bucket of slots instead of one launch.  Long
production campaigns of the source system hit exactly this (PAPERS.md: the
Fugaku stellar-merger runs, and the exascale AMT follow-up, both name
resilience as first-order), so the runtime needs two things this module
provides:

* a **deterministic fault-injection harness** — :class:`FaultSpec` /
  :class:`FaultInjector` — that injects failures at configurable sites
  (NaN/Inf task payloads, simulated bucket-compile failures, delayed or
  failed launches, corrupted ring slots), seeded and composable, so tests
  and benchmarks can replay *exact* failure schedules;
* the **error taxonomy + numeric helpers** the containment machinery in
  ``core/aggregation.py`` builds on: per-bucket finite checks
  (:func:`all_finite`), slot poisoning (:func:`poison_slots`), and the
  exception types a failed task's future carries.

Injection is a pure observation layer: with no injector attached (the
default), the hot path executes zero extra device work, and with an
injector attached but no spec matching, only cheap host-side predicate
calls run.  Detection (``AggregationConfig(guard="finite")``), bisection
and quarantine live in ``AggregationExecutor`` (DESIGN.md §11).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base class for every fault the containment layer recognises."""


class BucketCompileError(FaultError):
    """A bucket program failed to compile (simulated or real).  Compilation
    is deterministic per process, so the executor degrades the ladder —
    it never retries the same bucket size."""


class LaunchFaultError(FaultError):
    """A launch failed at dispatch (transient by assumption: the executor
    retries with bounded backoff before degrading to smaller buckets)."""


class TaskFailedError(FaultError):
    """Raised when reading the result of a task the guard marked failed.
    ``task_ids`` carries the wave-relative indices of the culprits."""

    def __init__(self, msg: str, task_ids: Sequence[int] = (),
                 kernel: str = ""):
        super().__init__(msg)
        self.task_ids = tuple(task_ids)
        self.kernel = kernel


class RegionFaultError(FaultError):
    """An unexpected error re-raised with region/bucket context attached
    (the narrow-except policy: expected failures are handled, everything
    else surfaces loudly *with* the aggregation context)."""


class NonFiniteStateError(FaultError):
    """A guarded strategy without containment machinery (fused / s2)
    produced a non-finite iterate — detection without bisection."""


# ---------------------------------------------------------------------------
# Fault specifications
# ---------------------------------------------------------------------------

SITES = ("payload", "compile", "launch", "ring")
PAYLOAD_MODES = ("nan", "inf")
LAUNCH_MODES = ("fail", "delay")


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic injection rule.  ``None`` fields match anything.

    site="payload"  — the matched task's *output slot* becomes NaN/Inf in
                      every launch that contains it (re-executions
                      included, so the poison is a property of the TASK:
                      bisection finds it at any bucket size).  Matched by
                      (kernel, task, wave); ``rate`` draws a deterministic
                      seeded coin per (kernel, wave, task) instead.
    site="ring"     — the matched task's slot-ring *input* is poisoned at
                      submission (the corrupted-staging variant; flows
                      through the kernel into a non-finite output).
    site="compile"  — compiling/launching the matched (kernel, bucket)
                      program raises :class:`BucketCompileError`.
    site="launch"   — dispatch of the matched (kernel, bucket) launch
                      fails (``mode="fail"``) or is delayed by ``delay_s``
                      (``mode="delay"``).  ``times`` bounds how often the
                      spec fires (a ``times=1`` launch failure models a
                      transient the retry policy must absorb).
    """

    site: str
    kernel: Optional[str] = None      # kernel family id (None = any family)
    task: Optional[int] = None        # wave-relative task index
    wave: Optional[int] = None        # region wave counter (None = every)
    bucket: Optional[int] = None      # bucket size (compile/launch sites)
    mode: Optional[str] = None        # payload: nan|inf; launch: fail|delay
    times: Optional[int] = None       # max fires (None = unbounded)
    rate: Optional[float] = None      # payload: seeded per-task coin
    delay_s: float = 0.0              # launch "delay" mode: seconds

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} — valid "
                             f"sites: {', '.join(SITES)}")
        if self.site in ("payload", "ring"):
            if self.mode is not None and self.mode not in PAYLOAD_MODES:
                raise ValueError(f"payload/ring mode must be one of "
                                 f"{PAYLOAD_MODES}, got {self.mode!r}")
            if self.task is None and self.rate is None:
                raise ValueError(f"{self.site} spec needs 'task' or 'rate' "
                                 f"— an unconditional poison would fail "
                                 f"every task")
        if self.site == "launch" and self.mode not in LAUNCH_MODES:
            raise ValueError(f"launch mode must be one of {LAUNCH_MODES}, "
                             f"got {self.mode!r}")
        if self.rate is not None and not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.times is not None and self.times < 1:
            raise ValueError(f"times must be >= 1, got {self.times}")


def _coin(seed: int, *key) -> float:
    """Deterministic draw in [0, 1) from (seed, *key) — stable across
    processes and call order, so a ``rate`` schedule replays exactly."""
    h = hashlib.blake2b(repr((seed,) + key).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big") / 2.0 ** 64


class FaultInjector:
    """Deterministic, composable fault schedule over many :class:`FaultSpec`
    rules.  Attach to an executor via
    ``AggregationExecutor.set_fault_injector`` (or pass ``fault_injector=``
    at construction) and to a ``ServingEngine`` the same way.

    Every fired injection is appended to ``log`` as a
    ``(site, kernel, wave, detail)`` tuple — the replayable record a test
    asserts against (and the exact schedule a second injector with the
    same specs + seed reproduces).
    """

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = int(seed)
        self._fired: Dict[int, int] = {}
        self.log: List[Tuple[str, str, Optional[int], Any]] = []

    # -- matching ----------------------------------------------------------
    @staticmethod
    def _field_ok(want, got) -> bool:
        return want is None or want == got

    def _fire(self, i: int, spec: FaultSpec, kernel: str,
              wave: Optional[int], detail) -> bool:
        n = self._fired.get(i, 0)
        if spec.times is not None and n >= spec.times:
            return False
        self._fired[i] = n + 1
        self.log.append((spec.site, kernel, wave, detail))
        return True

    # -- sites -------------------------------------------------------------
    def poison_positions(self, kernel: str, wave: int,
                         wave_ids: Sequence[int]) -> Dict[int, str]:
        """Which positions of a launch (0..k-1, identified by their
        wave-relative task ids) carry a payload fault right now; returns
        ``{position: mode}``.  Called on every launch AND every bisection
        re-execution — the poison follows the task."""
        out: Dict[int, str] = {}
        for i, spec in enumerate(self.specs):
            if spec.site != "payload":
                continue
            if not (self._field_ok(spec.kernel, kernel)
                    and self._field_ok(spec.wave, wave)):
                continue
            mode = spec.mode or "nan"
            for pos, tid in enumerate(wave_ids):
                if pos in out:
                    continue
                if spec.task is not None:
                    if spec.task == tid and self._fire(i, spec, kernel, wave,
                                                       ("task", tid)):
                        out[pos] = mode
                elif spec.rate is not None:
                    if (_coin(self.seed, "payload", kernel, wave, tid)
                            < spec.rate
                            and self._fire(i, spec, kernel, wave,
                                           ("task", tid))):
                        out[pos] = mode
        return out

    def corrupt_ring(self, kernel: str, wave: int,
                     task_id: int) -> Optional[str]:
        """Should this task's ring slot be poisoned at submission?"""
        for i, spec in enumerate(self.specs):
            if spec.site != "ring":
                continue
            if not (self._field_ok(spec.kernel, kernel)
                    and self._field_ok(spec.wave, wave)):
                continue
            hit = (spec.task == task_id if spec.task is not None
                   else _coin(self.seed, "ring", kernel, wave,
                              task_id) < (spec.rate or 0.0))
            if hit and self._fire(i, spec, kernel, wave, ("task", task_id)):
                return spec.mode or "nan"
        return None

    def compile_fails(self, kernel: str, bucket: int) -> bool:
        """Does compiling/entering the (kernel, bucket) program fail?"""
        for i, spec in enumerate(self.specs):
            if (spec.site == "compile"
                    and self._field_ok(spec.kernel, kernel)
                    and self._field_ok(spec.bucket, bucket)
                    and self._fire(i, spec, kernel, None,
                                   ("bucket", bucket))):
                return True
        return False

    def launch_fault(self, kernel: str,
                     bucket: int) -> Optional[Tuple[str, float]]:
        """Launch-site injection: ``("fail", 0.0)`` to raise, or
        ``("delay", seconds)`` to stall dispatch; None when clean."""
        for i, spec in enumerate(self.specs):
            if (spec.site == "launch"
                    and self._field_ok(spec.kernel, kernel)
                    and self._field_ok(spec.bucket, bucket)
                    and self._fire(i, spec, kernel, None,
                                   ("bucket", bucket))):
                return (spec.mode, spec.delay_s)
        return None


# ---------------------------------------------------------------------------
# Numeric helpers (shared by executor guard, runner guard, serving guard)
# ---------------------------------------------------------------------------

@jax.jit
def _all_finite_impl(leaves):
    acc = jnp.bool_(True)
    for leaf in leaves:
        acc = jnp.logical_and(acc, jnp.all(jnp.isfinite(leaf)))
    return acc


def all_finite(tree) -> bool:
    """ONE scalar per checked pytree: are all inexact leaves finite?
    This is the per-bucket guard predicate — deliberately not per-slot
    (per-slot masks cost a device reduction per task; the bisection path
    recovers slot resolution in O(log bucket) launches only when a bucket
    actually trips)."""
    verdict = all_finite_async(tree)
    return verdict if isinstance(verdict, bool) else bool(verdict)


def all_finite_async(tree):
    """Dispatch the finite-check WITHOUT blocking: returns the device
    scalar (or plain True when nothing is checkable).  The guard enqueues
    this right after each launch so the reduction overlaps later staging
    and dispatch work; the verdict is only forced (``bool``) post-drain."""
    leaves = [x for x in jax.tree_util.tree_leaves(tree)
              if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)]
    if not leaves:
        return True
    return _all_finite_impl(leaves)


def poison_slots(tree, positions: Sequence[int],
                 modes: Optional[Dict[int, str]] = None):
    """Overwrite the given slot positions of a batched output pytree with
    NaN (or +Inf for positions whose mode is "inf").  Inexact leaves only —
    integer outputs cannot carry the poison and are left untouched."""
    if not positions:
        return tree
    modes = modes or {}
    nan_pos = [p for p in positions if modes.get(p, "nan") == "nan"]
    inf_pos = [p for p in positions if modes.get(p, "nan") == "inf"]

    def one(x):
        if not (hasattr(x, "dtype")
                and jnp.issubdtype(x.dtype, jnp.inexact)):
            return x
        if nan_pos:
            x = x.at[jnp.asarray(nan_pos, jnp.int32)].set(jnp.nan)
        if inf_pos:
            x = x.at[jnp.asarray(inf_pos, jnp.int32)].set(jnp.inf)
        return x

    return jax.tree_util.tree_map(one, tree)


def poison_args(args: Tuple[Any, ...], mode: str = "nan") -> Tuple[Any, ...]:
    """NaN/Inf-fill one task's input argument tuple (inexact args only) —
    the ring-corruption site's payload."""
    val = float("nan") if mode == "nan" else float("inf")

    def one(a):
        arr = jnp.asarray(a)
        if not jnp.issubdtype(arr.dtype, jnp.inexact):
            return a
        return jnp.full_like(arr, val)

    return tuple(one(a) for a in args)


# ---------------------------------------------------------------------------
# Quarantine
# ---------------------------------------------------------------------------

@dataclass
class QuarantineList:
    """Per-region repeat-offender memory: wave-relative task indices whose
    outputs tripped the guard ``threshold`` times get quarantined.  A
    quarantined index short-circuits bisection on later trips — it is
    re-executed per-task directly (the degraded per-task mode), so a known
    repeat offender costs O(1) extra launches instead of O(log bucket)."""

    threshold: int = 2
    offenses: Dict[int, int] = field(default_factory=dict)
    members: set = field(default_factory=set)

    def record_offense(self, task_id: int) -> bool:
        """Count one guard trip against ``task_id``; returns True when the
        index just crossed the threshold (newly quarantined)."""
        n = self.offenses.get(task_id, 0) + 1
        self.offenses[task_id] = n
        if n >= self.threshold and task_id not in self.members:
            self.members.add(task_id)
            return True
        return False

    def __contains__(self, task_id: int) -> bool:
        return task_id in self.members

    def as_stats(self) -> List[int]:
        return sorted(self.members)


__all__ = [
    "FaultError", "BucketCompileError", "LaunchFaultError",
    "TaskFailedError", "RegionFaultError", "NonFiniteStateError",
    "FaultSpec", "FaultInjector", "QuarantineList",
    "all_finite", "all_finite_async", "poison_slots", "poison_args", "SITES",
]
