"""Staging buffers: the device-resident slot ring + the host slab recycler.

The paper: device mallocs synchronize the whole GPU, so CPPuddle recycles
buffers between tasks instead of freeing them.  Under JAX the device-side
analogue is buffer donation + XLA's arena allocator.  Two staging layers
live here (DESIGN.md §3):

* ``SlotRing`` — the device-resident analogue of CPPuddle's pre-allocated
  aggregation buffer: one persistent ``(capacity, *task_shape)`` device
  array per kernel argument, double-buffered.  Each submitted task writes
  its inputs into slot ``i`` via a *donated* ``lax.dynamic_update_slice``,
  so XLA updates the ring in place; a launch then consumes a zero-copy
  prefix view of the filled slots.  No host round-trip ever happens on the
  hot path.
* ``BufferPool`` — the legacy *host* slab recycler, kept for the
  ``staging="host"`` comparison mode (the seed implementation) and for
  genuinely host-resident inputs.  Reallocating a slab per launch costs an
  alloc + page-fault storm per aggregated kernel; the pool recycles slabs
  keyed by (shape, dtype), exactly like CPPuddle's ``buffer_recycler``.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from functools import partial
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class BufferPool:
    """Slab recycler: ``acquire`` hands out a previously released buffer of
    the same (shape, dtype) if available, else allocates (the "malloc")."""

    def __init__(self):
        self._free: Dict[Tuple, List[np.ndarray]] = defaultdict(list)
        self._lock = threading.Lock()
        self.allocations = 0        # statistics: actual mallocs
        self.reuses = 0

    def acquire(self, shape: Sequence[int], dtype) -> np.ndarray:
        key = (tuple(shape), np.dtype(dtype).str)
        with self._lock:
            if self._free[key]:
                self.reuses += 1
                return self._free[key].pop()
        self.allocations += 1
        return np.empty(shape, dtype)

    def release(self, buf: np.ndarray) -> None:
        key = (tuple(buf.shape), buf.dtype.str)
        with self._lock:
            self._free[key].append(buf)

    def stage(self, parts: Sequence[np.ndarray]) -> np.ndarray:
        """Stack task inputs into one recycled slab (tasks fill chunks)."""
        n = len(parts)
        shape = (n,) + tuple(parts[0].shape)
        slab = self.acquire(shape, parts[0].dtype)
        for i, p in enumerate(parts):
            slab[i] = p
        return slab


# process-wide default pool, mirroring CPPuddle's global recycler
DEFAULT_POOL = BufferPool()


# ---------------------------------------------------------------------------
# Device-resident slot ring
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0,))
def _write_slot(ring, value, slot):
    """In-place slot write: ring[slot] = value (ring buffer donated)."""
    return jax.lax.dynamic_update_slice(
        ring, value[None], (slot,) + (0,) * value.ndim)


@partial(jax.jit, donate_argnums=(0,))
def _compact(ring, start):
    """Move the live suffix [start:] to the front (slot renumbering)."""
    return jnp.roll(ring, -start, axis=0)


class SlotRing:
    """Double-buffered device staging ring for aggregated task inputs.

    One ring per kernel argument, each shaped ``(capacity, *task_shape)``.
    Tasks claim consecutive slots; a bucketed launch reads the prefix
    ``[first_queued, first_queued + k)`` directly from the ring (zero host
    staging).  After a launch drains the queue the *other* buffer becomes
    active, so new writes never chain a data dependency onto a ring an
    in-flight kernel is still reading (classic double buffering).

    When the active buffer fills while a remainder is still queued (possible
    under watermark-triggered partial launches), ``compact`` rolls the live
    suffix to the front — a single fused device op, no host copies.
    """

    def __init__(self, capacity: int, example_args: Sequence[Any],
                 n_buffers: int = 2):
        assert capacity >= 1 and n_buffers >= 1
        self.capacity = capacity
        self._specs = [(tuple(np.shape(a)), jnp.asarray(a).dtype)
                       for a in example_args]
        self._bufs = [
            [jnp.zeros((capacity,) + shape, dtype)
             for shape, dtype in self._specs]
            for _ in range(n_buffers)]
        self._active = 0
        self.fill = 0                 # next free slot in the active buffer
        self.writes = 0               # statistics
        self.compactions = 0
        self.swaps = 0

    @property
    def n_args(self) -> int:
        return len(self._specs)

    def buffers(self) -> Tuple[jax.Array, ...]:
        """The active ring buffers (one per kernel argument)."""
        return tuple(self._bufs[self._active])

    def write(self, args: Sequence[Any]) -> int:
        """Write one task's inputs into the next free slot; returns the slot.

        The caller must ``compact``/reset before writing to a full ring.
        """
        assert self.fill < self.capacity, "ring full — compact first"
        slot = self.fill
        active = self._bufs[self._active]
        s = jnp.int32(slot)
        for j, a in enumerate(args):
            active[j] = _write_slot(active[j], jnp.asarray(a), s)
        self.fill += 1
        self.writes += 1
        return slot

    def compact(self, start: int) -> None:
        """Renumber live slots [start:fill) down to [0, fill-start)."""
        active = self._bufs[self._active]
        s = jnp.int32(start)
        for j in range(len(active)):
            active[j] = _compact(active[j], s)
        self.fill -= start
        self.compactions += 1

    def swap(self) -> None:
        """Switch to the other buffer and reset the fill cursor (called when
        the queue drains, so the just-launched ring stays untouched)."""
        self._active = (self._active + 1) % len(self._bufs)
        self.fill = 0
        self.swaps += 1
