"""Recycled staging-buffer pool (the CPPuddle allocator analogue).

The paper: device mallocs synchronize the whole GPU, so CPPuddle recycles
buffers between tasks instead of freeing them.  Under JAX the device-side
analogue is buffer donation + XLA's arena allocator; what remains on the
*host* is the aggregation staging slab: the contiguous pinned buffer into
which aggregated tasks write their inputs (each task owning chunk ``i``).
Reallocating that slab per launch costs an alloc + page-fault storm per
aggregated kernel; this pool recycles slabs keyed by (shape, dtype), exactly
like CPPuddle's ``buffer_recycler``.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import numpy as np


class BufferPool:
    """Slab recycler: ``acquire`` hands out a previously released buffer of
    the same (shape, dtype) if available, else allocates (the "malloc")."""

    def __init__(self):
        self._free: Dict[Tuple, List[np.ndarray]] = defaultdict(list)
        self._lock = threading.Lock()
        self.allocations = 0        # statistics: actual mallocs
        self.reuses = 0

    def acquire(self, shape: Sequence[int], dtype) -> np.ndarray:
        key = (tuple(shape), np.dtype(dtype).str)
        with self._lock:
            if self._free[key]:
                self.reuses += 1
                return self._free[key].pop()
        self.allocations += 1
        return np.empty(shape, dtype)

    def release(self, buf: np.ndarray) -> None:
        key = (tuple(buf.shape), buf.dtype.str)
        with self._lock:
            self._free[key].append(buf)

    def stage(self, parts: Sequence[np.ndarray]) -> np.ndarray:
        """Stack task inputs into one recycled slab (tasks fill chunks)."""
        n = len(parts)
        shape = (n,) + tuple(parts[0].shape)
        slab = self.acquire(shape, parts[0].dtype)
        for i, p in enumerate(parts):
            slab[i] = p
        return slab


# process-wide default pool, mirroring CPPuddle's global recycler
DEFAULT_POOL = BufferPool()
