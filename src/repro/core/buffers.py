"""Staging buffers: the device-resident slot ring + the host slab recycler.

The paper: device mallocs synchronize the whole GPU, so CPPuddle recycles
buffers between tasks instead of freeing them.  Under JAX the device-side
analogue is buffer donation + XLA's arena allocator.  Two staging layers
live here (DESIGN.md §3):

* ``SlotRing`` — the device-resident analogue of CPPuddle's pre-allocated
  aggregation buffer: one persistent ``(capacity, *task_shape)`` device
  array per kernel argument, double-buffered.  Each submitted task writes
  its inputs into slot ``i`` via a *donated* ``lax.dynamic_update_slice``,
  so XLA updates the ring in place; a launch then consumes a zero-copy
  prefix view of the filled slots.  No host round-trip ever happens on the
  hot path.
* ``BufferPool`` — the legacy *host* slab recycler, kept for the
  ``staging="host"`` comparison mode (the seed implementation) and for
  genuinely host-resident inputs.  Reallocating a slab per launch costs an
  alloc + page-fault storm per aggregated kernel; the pool recycles slabs
  keyed by (shape, dtype), exactly like CPPuddle's ``buffer_recycler``.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from functools import partial
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class BufferPool:
    """Slab recycler: ``acquire`` hands out a previously released buffer of
    the same (shape, dtype) if available, else allocates (the "malloc")."""

    def __init__(self):
        self._free: Dict[Tuple, List[np.ndarray]] = defaultdict(list)
        self._lock = threading.Lock()
        self.allocations = 0        # statistics: actual mallocs
        self.reuses = 0

    def acquire(self, shape: Sequence[int], dtype) -> np.ndarray:
        key = (tuple(shape), np.dtype(dtype).str)
        with self._lock:
            if self._free[key]:
                self.reuses += 1
                return self._free[key].pop()
        self.allocations += 1
        return np.empty(shape, dtype)

    def release(self, buf: np.ndarray) -> None:
        key = (tuple(buf.shape), buf.dtype.str)
        with self._lock:
            self._free[key].append(buf)

    def stage(self, parts: Sequence[np.ndarray]) -> np.ndarray:
        """Stack task inputs into one recycled slab (tasks fill chunks)."""
        n = len(parts)
        shape = (n,) + tuple(parts[0].shape)
        slab = self.acquire(shape, parts[0].dtype)
        for i, p in enumerate(parts):
            slab[i] = p
        return slab


# process-wide default pool, mirroring CPPuddle's global recycler
DEFAULT_POOL = BufferPool()


# ---------------------------------------------------------------------------
# Device-resident slot ring
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0,))
def _write_block(ring, block, start):
    """In-place contiguous block write: ring[start:start+k] = block (ring
    donated).  One donated scatter covers k pending slot writes."""
    return jax.lax.dynamic_update_slice(
        ring, block, (start,) + (0,) * (block.ndim - 1))


@partial(jax.jit, donate_argnums=(0,))
def _compact(ring, start):
    """Move the live suffix [start:] to the front (slot renumbering)."""
    return jnp.roll(ring, -start, axis=0)


class SlotRing:
    """Double-buffered device staging ring for aggregated task inputs.

    One ring per kernel argument, each shaped ``(capacity, *task_shape)``.
    Tasks claim consecutive slots; a bucketed launch reads the prefix
    ``[first_queued, first_queued + k)`` directly from the ring (zero host
    staging).  After a launch drains the queue the *other* buffer becomes
    active, so new writes never chain a data dependency onto a ring an
    in-flight kernel is still reading (classic double buffering).

    Slot writes are *coalesced*: ``write`` only records the task's inputs
    host-side; the next ``commit`` (implicit in ``buffers``/``compact``)
    materializes every pending slot with ONE donated contiguous scatter per
    kernel argument instead of one ``dynamic_update_slice`` per task — k
    queued tasks cost one device write, not k.

    When the active buffer fills while a remainder is still queued (possible
    under watermark-triggered partial launches), ``compact`` rolls the live
    suffix to the front — a single fused device op, no host copies.
    """

    def __init__(self, capacity: int, example_args: Sequence[Any],
                 n_buffers: int = 2):
        assert capacity >= 1 and n_buffers >= 1
        self.capacity = capacity
        self._specs = [(tuple(np.shape(a)),
                        getattr(a, "dtype", None) or jnp.asarray(a).dtype)
                       for a in example_args]
        self._bufs = [
            [jnp.zeros((capacity,) + shape, dtype)
             for shape, dtype in self._specs]
            for _ in range(n_buffers)]
        self._active = 0
        self._pending: List[Tuple[Any, ...]] = []
        self._committed = 0           # slots materialized on device
        self.fill = 0                 # next free slot (incl. pending writes)
        self.writes = 0               # statistics: logical slot writes
        self.commits = 0              # donated-scatter flushes (1 per batch)
        self.compactions = 0
        self.swaps = 0

    @property
    def n_args(self) -> int:
        return len(self._specs)

    def buffers(self) -> Tuple[jax.Array, ...]:
        """The active ring buffers (one per kernel argument), with every
        pending write committed."""
        self.commit()
        return tuple(self._bufs[self._active])

    def write(self, args: Sequence[Any]) -> int:
        """Claim the next free slot for one task's inputs; returns the slot.

        The write is deferred: inputs are queued host-side and coalesced
        into one donated scatter at the next ``commit``.  The caller must
        ``compact``/reset before writing to a full ring.
        """
        assert self.fill < self.capacity, "ring full — compact first"
        slot = self.fill
        self._pending.append(tuple(args))
        self.fill += 1
        self.writes += 1
        return slot

    def commit(self) -> None:
        """Materialize pending writes: one donated contiguous scatter per
        kernel argument covers all k pending slots."""
        if not self._pending:
            return
        active = self._bufs[self._active]
        start = jnp.int32(self._committed)
        for j in range(len(active)):
            if len(self._pending) == 1:
                block = jnp.asarray(self._pending[0][j])[None]
            else:
                block = jnp.stack([jnp.asarray(p[j]) for p in self._pending])
            active[j] = _write_block(active[j], block, start)
        self._committed = self.fill
        self._pending.clear()
        self.commits += 1

    def poison(self, slot: int, mode: str = "nan") -> None:
        """Corrupt one claimed slot's staged inputs (fault-injection site:
        a bad DMA or a stale recycled buffer handed to the wrong task).
        A still-pending write is replaced host-side before it ever reaches
        the device; an already-committed slot gets one non-donated device
        update per inexact argument.  Integer arguments are left intact —
        they cannot carry a NaN/Inf poison."""
        assert 0 <= slot < self.fill, "poisoning an unclaimed slot"
        val = float("nan") if mode == "nan" else float("inf")

        def bad(a):
            arr = jnp.asarray(a)
            if not jnp.issubdtype(arr.dtype, jnp.inexact):
                return a
            return jnp.full_like(arr, val)

        if slot >= self._committed:
            i = slot - self._committed
            self._pending[i] = tuple(bad(a) for a in self._pending[i])
            return
        active = self._bufs[self._active]
        for j in range(len(active)):
            if jnp.issubdtype(active[j].dtype, jnp.inexact):
                active[j] = active[j].at[slot].set(val)

    def compact(self, start: int) -> None:
        """Renumber live slots [start:fill) down to [0, fill-start)."""
        self.commit()
        active = self._bufs[self._active]
        s = jnp.int32(start)
        for j in range(len(active)):
            active[j] = _compact(active[j], s)
        self.fill -= start
        self._committed = self.fill
        self.compactions += 1

    def swap(self) -> None:
        """Switch to the other buffer and reset the fill cursor (called when
        the queue drains, so the just-launched ring stays untouched)."""
        self.commit()                 # never strand writes on the old buffer
        self._active = (self._active + 1) % len(self._bufs)
        self.fill = 0
        self._committed = 0
        self.swaps += 1
