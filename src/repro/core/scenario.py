"""Scenario protocol: declarative workload descriptions for StrategyRunner.

The execution API splits WHAT from HOW (DESIGN.md §8):

* a **Scenario** (this module) declares WHAT one solver iteration computes —
  its kernel families (id + batched body), the per-iteration task
  populations (parent arrays with a leading task axis, per-task traced
  args), the exchange/assembly steps around them, and the bit-exact fused
  reference every strategy must reproduce;
* a **Strategy** (``repro.core.strategies``) decides HOW those populations
  launch (per-task scatter ring, explicit aggregation, whole-graph fusion).

Adding a workload is one Scenario subclass; it immediately runs under every
registered strategy, and its families aggregate alongside any other
family submitted to the same ``AggregationExecutor``.  Implementations:

* ``UniformSedovScenario`` — the paper's Table II/III workload (one family);
* ``AMRSedovScenario``     — two-level refined Sedov (one or two hydro
  families, per-level traced ``h``);
* ``GravityScenario``      — hydro + per-sub-grid gravity solve: TWO kernel
  families (``hydro_rhs`` + ``gravity``) submitted interleaved through ONE
  executor per iteration, the cross-solver aggregation Octo-Tiger performs
  with its hydro and FMM kernels.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    AMRHydroConfig, GravityHydroConfig, HydroConfig,
)
from repro.hydro.state import (
    assemble_global, extract_subgrids, extract_subgrids_multilevel,
    sync_coarse,
)
from repro.hydro.stepper import (
    level_batched_body, level_batched_jit, rk_stage_epilogue,
    stage_coeff_vectors, subgrid_rhs,
)
from repro.kernels.gravity import (
    gravity_batched_body, gravity_batched_jit, gravity_source_update,
)


def xla_task_body(cfg: HydroConfig, h: float) -> Callable:
    """The fine-grained hydro task body: (F, P, P, P) -> (F, S, S, S)."""
    return partial(subgrid_rhs, h=h, gamma=cfg.gamma,
                   ghost=cfg.ghost, subgrid=cfg.subgrid)


@dataclass(frozen=True)
class KernelFamily:
    """One aggregable kernel family: the ``TaskSignature`` kernel id, its
    batched body ``(*stacked_args) -> stacked_out`` (leading slot axis on
    every arg/out), and optionally a pre-jitted twin (so scenario,
    reference and fused strategy share ONE compiled program).

    ``epilogue`` optionally declares a PER-SLOT epilogue
    ``epilogue(body_out_slot, *extra_slots) -> slot_out`` (e.g. the RK-stage
    axpy) that :func:`stage_family` traces *into* the bucketed program: the
    derived family's batched body is ``vmap(epilogue)(batched_body(*main),
    *extras)``, so gather -> body -> stage update compiles to ONE XLA
    program per bucket while submission stays task-granular (DESIGN.md §9).
    """

    kernel: str
    batched_body: Callable
    jit_body: Optional[Callable] = None
    epilogue: Optional[Callable] = None


def stage_family(fam: KernelFamily, n_body_args: int) -> KernelFamily:
    """Derive the epilogue-fused twin of a family: same aggregation
    substrate, bigger body.  The first ``n_body_args`` of a submission feed
    the body; the rest (per-slot extras, incl. per-task coefficient
    vectors) feed the vmapped epilogue.  Works with any batched body — the
    Pallas kernels included — because composition happens at the batched
    level."""
    if fam.epilogue is None:
        raise ValueError(f"family {fam.kernel!r} declares no epilogue")

    def batched(*args):
        out = fam.batched_body(*args[:n_body_args])
        return jax.vmap(fam.epilogue)(out, *args[n_body_args:])

    return KernelFamily(fam.kernel + "+epi", batched, jax.jit(batched))


def _cached_u0_interiors(scn, u0, v, v_int, extract):
    """``u0`` is invariant across a step's three stages (and IS ``v`` in
    stage 1): extract its interiors once per step, keyed on the ``u0``
    object.  Shared by every scenario's ``stage_populations``."""
    if v is u0:
        scn._u0_int_cache = (u0, v_int)
        return v_int
    cache = getattr(scn, "_u0_int_cache", None)
    if cache is None or cache[0] is not u0:
        cache = (u0, extract(u0))
        scn._u0_int_cache = cache
    return cache[1]


def _coeff_cache(scn) -> dict:
    cache = getattr(scn, "_stage_coeff_cache", None)
    if cache is None:
        cache = scn._stage_coeff_cache = {}
    return cache


@dataclass(frozen=True)
class TaskPopulation:
    """One iteration's submission wave for one family: per-task parent
    arrays (leading task axis; per-task traced args like the cell width
    ride along as 1-D parents).  Task ``i`` consumes ``parents[j][i]``."""

    kernel: str
    parents: Tuple[jax.Array, ...]

    @property
    def n_tasks(self) -> int:
        return self.parents[0].shape[0]

    def submit_to(self, executor):
        """Bulk-submit the whole population as ONE contiguous range entry
        (one ``RangeFuture``) — the population-level fast path over n
        per-task ``submit_indexed`` calls."""
        return executor.submit_range(self.parents, 0, self.n_tasks,
                                     kernel=self.kernel)


class Scenario:
    """Base class / protocol.  Subclasses implement:

    * ``families()``            — static kernel-family declarations;
    * ``populations(state)``    — ghost exchange + decomposition: one
      ``TaskPopulation`` per family, ready to submit;
    * ``assemble(state, outs)`` — per-population batched outputs (population
      order) -> ``d(state)/dt`` with the state's pytree structure;
    * ``warmup_parent_specs()`` — (kernel, parent ShapeDtypeStructs) pairs
      describing the submission waves, for AOT bucket warmup;

    and may override ``finalize_step`` (post-RK3 hook, e.g. the AMR
    coarse-fine sync).  ``reference_rhs`` — ONE jitted launch per family
    through the same assemble path — is the bit-exact oracle every
    strategy must match; it is shared code, not per-scenario, so
    runner-vs-reference equivalence reduces to per-family kernel
    equivalence (the aggregation substrate's invariant).
    """

    name: str = "scenario"

    # -- required ----------------------------------------------------------
    def families(self) -> Tuple[KernelFamily, ...]:
        raise NotImplementedError

    def populations(self, state) -> Tuple[TaskPopulation, ...]:
        raise NotImplementedError

    def assemble(self, state, outs: Sequence[Any]):
        raise NotImplementedError

    def warmup_parent_specs(self) -> Tuple[Tuple[str, Tuple[Any, ...]], ...]:
        return ()

    # -- optional: epilogue-fused RK stages (DESIGN.md §9) -----------------
    def stage_families(self) -> Tuple[KernelFamily, ...]:
        """Epilogue-fused twins of the families that declare one; empty when
        the scenario does not support fused stages."""
        return ()

    def stage_populations(self, u0, v, dt, c0,
                          c1) -> Optional[Tuple[TaskPopulation, ...]]:
        """Submission waves whose launches produce the NEXT RK stage state
        per slot: ``out = c0*u0 + c1*(v + dt*rhs(v))`` (Shu-Osher form;
        stage 1 is ``c0=0, c1=1``).  ``None`` = not supported — the runner
        falls back to rhs() + global combine."""
        return None

    def assemble_stage(self, state, outs: Sequence[Any], dt, c0, c1):
        """Per-population stage outputs (population order) -> the next
        stage's state pytree.  The stage coefficients ride along because
        cross-family couplings (e.g. gravity's ``c1*dt`` source tail) are
        applied HERE, after all of the wave's launches — a per-slot
        epilogue cannot see another family's output."""
        raise NotImplementedError

    def stage_warmup_parent_specs(self):
        """Like ``warmup_parent_specs`` for the stage families' waves."""
        return ()

    def reference_stage(self, u0, v, dt, c0, c1):
        """Bit-exact fused reference for one epilogue-fused RK stage: ONE
        jitted launch of each stage family through the same assemble path.
        The oracle the aggregated stage path must match bit-identically —
        same traced composition, only the batch decomposition differs."""
        pops = self.stage_populations(u0, v, dt, c0, c1)
        if pops is None:
            raise NotImplementedError(
                f"scenario {self.name!r} declares no stage populations")
        outs = [self.jitted_body(p.kernel)(*p.parents) for p in pops]
        return self.assemble_stage(v, outs, dt, c0, c1)

    # -- provided ----------------------------------------------------------
    def finalize_step(self, state):
        """Post-RK3-combine hook; identity unless levels need re-syncing."""
        return state

    def describe_task(self, kernel: str, index: int) -> str:
        """Human-readable identity of one task within a family's submission
        wave, used to enrich containment failures (DESIGN.md §11) — e.g.
        "subgrid (1, 3) of the fine level".  Index is wave-relative (the
        task's position in the family's wave).  Override per scenario; the
        default names the kernel and position."""
        return f"task {index} of family {kernel!r}"

    def family(self, kernel: str) -> KernelFamily:
        cache = getattr(self, "_family_by_kernel", None)
        if cache is None:
            cache = {f.kernel: f
                     for f in self.families() + tuple(self.stage_families())}
            self._family_by_kernel = cache
        return cache[kernel]

    def jitted_body(self, kernel: str) -> Callable:
        """The family's jitted batched body (one shared wrapper per family,
        so reference and fused strategy hit the same compiled programs)."""
        cache: Dict[str, Callable] = getattr(self, "_jit_cache", None)
        if cache is None:
            cache = {}
            self._jit_cache = cache
        fn = cache.get(kernel)
        if fn is None:
            fam = self.family(kernel)
            fn = fam.jit_body or jax.jit(fam.batched_body)
            cache[kernel] = fn
        return fn

    def reference_rhs(self, state):
        """Bit-exact fused per-family reference (and the traced rhs the
        ``lax.scan`` trajectory driver folds over)."""
        pops = self.populations(state)
        outs = [self.jitted_body(p.kernel)(*p.parents) for p in pops]
        return self.assemble(state, outs)


# ---------------------------------------------------------------------------
# Uniform Sedov (the paper's Table II/III workload)
# ---------------------------------------------------------------------------

class UniformSedovScenario(Scenario):
    """AMR-off Sedov blast: one kernel family, one task per sub-grid.

    The cell width is uniform, so it is baked into the body at trace time
    (the single-level fast path); custom ``body``/``batched_body`` let the
    Pallas kernels slot in unchanged.
    """

    def __init__(self, cfg: HydroConfig, bc: str = "outflow",
                 body: Optional[Callable] = None,
                 batched_body: Optional[Callable] = None):
        self.cfg = cfg
        self.bc = bc
        n = cfg.grids_per_edge * cfg.subgrid
        self.h = cfg.domain / n
        self.body = body or xla_task_body(cfg, self.h)
        self.batched_body = batched_body or jax.vmap(self.body)
        self.name = cfg.name
        self._dtype = jnp.dtype(cfg.dtype)
        self._families = (KernelFamily("hydro_rhs", self.batched_body,
                                       epilogue=rk_stage_epilogue),)
        self._stage_families = (stage_family(self._families[0], 1),)

    def families(self):
        return self._families

    def populations(self, state):
        subs = extract_subgrids(state, self.cfg.subgrid, self.cfg.ghost,
                                self.bc)
        return (TaskPopulation("hydro_rhs", (subs,)),)

    def assemble(self, state, outs):
        return assemble_global(outs[0], self.cfg.subgrid)

    def warmup_parent_specs(self):
        cfg = self.cfg
        p = cfg.padded
        spec = jax.ShapeDtypeStruct(
            (cfg.n_subgrids, cfg.n_fields, p, p, p), jnp.dtype(cfg.dtype))
        return (("hydro_rhs", (spec,)),)

    # -- epilogue-fused RK stages (DESIGN.md §9) ---------------------------
    def stage_families(self):
        return self._stage_families

    def stage_populations(self, u0, v, dt, c0, c1):
        cfg = self.cfg
        subs = extract_subgrids(v, cfg.subgrid, cfg.ghost, self.bc)
        v_int = extract_subgrids(v, cfg.subgrid, 0, self.bc)
        u0_int = _cached_u0_interiors(
            self, u0, v, v_int,
            lambda u: extract_subgrids(u, cfg.subgrid, 0, self.bc))
        n = subs.shape[0]
        coeffs = stage_coeff_vectors(_coeff_cache(self), dt, c0, c1, n,
                                     self._dtype)
        return (TaskPopulation(
            self._stage_families[0].kernel,
            (subs, v_int, u0_int) + coeffs),)

    def assemble_stage(self, state, outs, dt, c0, c1):
        return assemble_global(outs[0], self.cfg.subgrid)

    def stage_warmup_parent_specs(self):
        cfg = self.cfg
        p, s, n = cfg.padded, cfg.subgrid, cfg.n_subgrids
        dtype = jnp.dtype(cfg.dtype)
        f = cfg.n_fields
        scalar = jax.ShapeDtypeStruct((n,), dtype)
        return ((self._stage_families[0].kernel, (
            jax.ShapeDtypeStruct((n, f, p, p, p), dtype),
            jax.ShapeDtypeStruct((n, f, s, s, s), dtype),
            jax.ShapeDtypeStruct((n, f, s, s, s), dtype),
            scalar, scalar, scalar)),)


# ---------------------------------------------------------------------------
# Two-level AMR Sedov (mixed task population, per-level traced h)
# ---------------------------------------------------------------------------

class AMRSedovScenario(Scenario):
    """Two-level refined Sedov: state is ``(uc, uf)``; every iteration
    yields one population per level with per-task traced ``h``.  Levels
    whose sub-grid shapes agree share one kernel family (the same compiled
    buckets serve both); mixed sizes open two families that aggregate
    concurrently.  ``finalize_step`` re-syncs the covered coarse cells.

    The epilogue-fused stage path (DESIGN.md §10) extends §9 to the
    adaptive workload: each level's family derives a ``stage_family`` twin
    with the per-task traced ``h`` riding straight through the fused body,
    so one compiled bucket still serves every refinement level whose
    sub-grid shapes agree — now with the Shu-Osher axpy fused in.
    """

    def __init__(self, cfg: AMRHydroConfig, bc: str = "outflow"):
        self.cfg = cfg
        self.bc = bc
        self.name = cfg.name
        dtype = jnp.dtype(cfg.dtype)
        self._dtype = dtype
        self._levels = ("coarse", "fine")
        self._subgrid = {"coarse": cfg.coarse_subgrid,
                         "fine": cfg.fine_subgrid}
        self._n_level = {"coarse": cfg.n_subgrids_coarse,
                         "fine": cfg.n_subgrids_fine}
        self._h = {
            "coarse": jnp.full((cfg.n_subgrids_coarse,), cfg.h_coarse, dtype),
            "fine": jnp.full((cfg.n_subgrids_fine,), cfg.h_fine, dtype),
        }
        # one family per DISTINCT sub-grid size; equal sizes share everything
        self._kernel = {lvl: f"hydro_rhs_s{self._subgrid[lvl]}"
                        for lvl in self._levels}
        self._families = tuple(
            KernelFamily(f"hydro_rhs_s{s}",
                         level_batched_body(cfg.gamma, cfg.ghost, s),
                         level_batched_jit(cfg.gamma, cfg.ghost, s),
                         epilogue=rk_stage_epilogue)
            for s in dict.fromkeys(self._subgrid.values()))
        # the level body consumes (subs, h); everything after feeds the
        # vmapped stage epilogue
        self._stage_families = tuple(stage_family(f, 2)
                                     for f in self._families)
        self._stage_kernel = {lvl: self._kernel[lvl] + "+epi"
                              for lvl in self._levels}

    def families(self):
        return self._families

    def populations(self, state):
        uc, uf = state
        subs = dict(zip(self._levels,
                        extract_subgrids_multilevel(uc, uf, self.cfg,
                                                    self.bc)))
        return tuple(
            TaskPopulation(self._kernel[lvl], (subs[lvl], self._h[lvl]))
            for lvl in self._levels)

    def assemble(self, state, outs):
        return tuple(assemble_global(out, self._subgrid[lvl])
                     for lvl, out in zip(self._levels, outs))

    def finalize_step(self, state):
        uc, uf = state
        return sync_coarse(uc, uf, self.cfg), uf

    def warmup_parent_specs(self):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        specs = []
        for lvl in self._levels:
            n = self._n_level[lvl]
            p = self._subgrid[lvl] + 2 * cfg.ghost
            specs.append((self._kernel[lvl], (
                jax.ShapeDtypeStruct((n, cfg.n_fields, p, p, p), dtype),
                jax.ShapeDtypeStruct((n,), dtype))))
        return tuple(specs)

    # -- epilogue-fused RK stages (DESIGN.md §10) --------------------------
    def _interiors(self, state):
        """Per-level interiors of the RAW state arrays — the combine side
        of a stage reads the un-synced levels, exactly as the generic
        ``u1 = v + dt * rhs(v)`` path does (the sync lives inside the
        ghost exchange and in ``finalize_step``)."""
        uc, uf = state
        return {"coarse": extract_subgrids(uc, self.cfg.coarse_subgrid, 0,
                                           self.bc),
                "fine": extract_subgrids(uf, self.cfg.fine_subgrid, 0,
                                         self.bc)}

    def stage_families(self):
        return self._stage_families

    def stage_populations(self, u0, v, dt, c0, c1):
        uc, uf = v
        subs = dict(zip(self._levels,
                        extract_subgrids_multilevel(uc, uf, self.cfg,
                                                    self.bc)))
        v_int = self._interiors(v)
        u0_int = _cached_u0_interiors(self, u0, v, v_int, self._interiors)
        cache = _coeff_cache(self)
        pops = []
        for lvl in self._levels:
            coeffs = stage_coeff_vectors(cache, dt, c0, c1,
                                         self._n_level[lvl], self._dtype)
            pops.append(TaskPopulation(
                self._stage_kernel[lvl],
                (subs[lvl], self._h[lvl], v_int[lvl], u0_int[lvl]) + coeffs))
        return tuple(pops)

    def assemble_stage(self, state, outs, dt, c0, c1):
        return tuple(assemble_global(out, self._subgrid[lvl])
                     for lvl, out in zip(self._levels, outs))

    def stage_warmup_parent_specs(self):
        cfg = self.cfg
        dtype = self._dtype
        specs = []
        for lvl in self._levels:
            n, s = self._n_level[lvl], self._subgrid[lvl]
            p = s + 2 * cfg.ghost
            scalar = jax.ShapeDtypeStruct((n,), dtype)
            specs.append((self._stage_kernel[lvl], (
                jax.ShapeDtypeStruct((n, cfg.n_fields, p, p, p), dtype),
                scalar,
                jax.ShapeDtypeStruct((n, cfg.n_fields, s, s, s), dtype),
                jax.ShapeDtypeStruct((n, cfg.n_fields, s, s, s), dtype),
                scalar, scalar, scalar)))
        return tuple(specs)


# ---------------------------------------------------------------------------
# Self-gravitating Sedov (cross-solver aggregation: hydro + gravity)
# ---------------------------------------------------------------------------

@jax.jit
def _apply_gravity_source(u, dudt, pg):
    """Couple the gravity family's output into the hydro RHS: momentum
    gains ``rho * g`` and energy gains ``S . g``.  ONE shared jitted code
    path for runner and reference, so bit-exactness reduces to per-family
    kernel equivalence."""
    return gravity_source_update(u, dudt, pg)


@jax.jit
def _apply_gravity_stage_source(v, staged, pg, c1dt):
    """Couple gravity into an epilogue-fused stage (DESIGN.md §10).  The
    hydro stage family already produced ``c0*u0 + c1*(v + dt*dudt)``; the
    gravity tail of the full update enters as its algebraic remainder,
    ``+ c1*dt * src(v, pg)``.  ONE shared jitted path for runner and
    reference (the aggregated stage wave and ``reference_stage`` both
    land here), so stage bit-exactness again reduces to per-family kernel
    equivalence."""
    return gravity_source_update(v, staged, pg, scale=c1dt)


class GravityScenario(Scenario):
    """Sedov blast under self-gravity: TWO kernel families per iteration.

    Both families consume the SAME ghost-exchanged sub-grid decomposition
    (one parent array feeds hydro and gravity tasks alike, staged by slot
    index) and both take the cell width as a traced per-task argument.
    Under s3/s2+s3 their tasks are submitted interleaved into one
    ``AggregationExecutor``: the region registry routes them by kernel id
    into two concurrent ``TaskSignature`` families with independent bucket
    ladders — the cross-solver aggregation the redesign exists to unlock.

    The epilogue-fused stage path (DESIGN.md §10) is the TWO-FAMILY stage
    protocol: each RK stage submits the hydro family's epilogue-fused twin
    (gather -> Reconstruct+Flux -> Shu-Osher axpy, one program per bucket)
    AND the unchanged gravity relaxation interleaved in the SAME wave; the
    cross-family coupling — which no per-slot epilogue can see, the
    gravity output being a different launch — enters at ``assemble_stage``
    as the algebraically equivalent ``+ c1*dt * src(v, pg)`` tail, through
    one jitted path shared with ``reference_stage``.
    """

    def __init__(self, cfg: GravityHydroConfig, bc: str = "outflow"):
        self.cfg = cfg
        self.bc = bc
        self.name = cfg.name
        hc = cfg.hydro
        self.h = hc.domain / (hc.grids_per_edge * hc.subgrid)
        self._dtype = jnp.dtype(hc.dtype)
        self._h_vec = jnp.full((hc.n_subgrids,), self.h, self._dtype)
        self._families = (
            KernelFamily("hydro_rhs",
                         level_batched_body(hc.gamma, hc.ghost, hc.subgrid),
                         level_batched_jit(hc.gamma, hc.ghost, hc.subgrid),
                         epilogue=rk_stage_epilogue),
            KernelFamily("gravity",
                         gravity_batched_body(hc.ghost, hc.subgrid,
                                              cfg.g_const, cfg.relax_iters),
                         gravity_batched_jit(hc.ghost, hc.subgrid,
                                             cfg.g_const, cfg.relax_iters)),
        )
        # hydro body consumes (subs, h); gravity joins the stage wave as
        # itself (its launches carry no per-slot epilogue to fuse)
        self._stage_families = (stage_family(self._families[0], 2),)

    def families(self):
        return self._families

    def populations(self, state):
        hc = self.cfg.hydro
        subs = extract_subgrids(state, hc.subgrid, hc.ghost, self.bc)
        return (TaskPopulation("hydro_rhs", (subs, self._h_vec)),
                TaskPopulation("gravity", (subs, self._h_vec)))

    def assemble(self, state, outs):
        hc = self.cfg.hydro
        dudt = assemble_global(outs[0], hc.subgrid)
        pg = assemble_global(outs[1], hc.subgrid)
        return _apply_gravity_source(state, dudt, pg)

    def warmup_parent_specs(self):
        hc = self.cfg.hydro
        p = hc.padded
        subs = jax.ShapeDtypeStruct(
            (hc.n_subgrids, hc.n_fields, p, p, p), self._dtype)
        h = jax.ShapeDtypeStruct((hc.n_subgrids,), self._dtype)
        return (("hydro_rhs", (subs, h)), ("gravity", (subs, h)))

    # -- two-family epilogue-fused RK stages (DESIGN.md §10) ---------------
    def stage_families(self):
        return self._stage_families

    def stage_populations(self, u0, v, dt, c0, c1):
        hc = self.cfg.hydro
        subs = extract_subgrids(v, hc.subgrid, hc.ghost, self.bc)
        v_int = extract_subgrids(v, hc.subgrid, 0, self.bc)
        u0_int = _cached_u0_interiors(
            self, u0, v, v_int,
            lambda u: extract_subgrids(u, hc.subgrid, 0, self.bc))
        coeffs = stage_coeff_vectors(_coeff_cache(self), dt, c0, c1,
                                     hc.n_subgrids, self._dtype)
        return (
            TaskPopulation(
                self._stage_families[0].kernel,
                (subs, self._h_vec, v_int, u0_int) + coeffs),
            TaskPopulation("gravity", (subs, self._h_vec)),
        )

    def assemble_stage(self, state, outs, dt, c0, c1):
        hc = self.cfg.hydro
        staged = assemble_global(outs[0], hc.subgrid)
        pg = assemble_global(outs[1], hc.subgrid)
        return _apply_gravity_stage_source(state, staged, pg, c1 * dt)

    def stage_warmup_parent_specs(self):
        hc = self.cfg.hydro
        n, s, p = hc.n_subgrids, hc.subgrid, hc.padded
        dtype = self._dtype
        scalar = jax.ShapeDtypeStruct((n,), dtype)
        subs = jax.ShapeDtypeStruct((n, hc.n_fields, p, p, p), dtype)
        return (
            (self._stage_families[0].kernel, (
                subs, scalar,
                jax.ShapeDtypeStruct((n, hc.n_fields, s, s, s), dtype),
                jax.ShapeDtypeStruct((n, hc.n_fields, s, s, s), dtype),
                scalar, scalar, scalar)),
            ("gravity", (subs, scalar)),
        )
