"""The paper's strategy 3: on-the-fly explicit work aggregation, TPU-native.

Fine-grained tasks submit "launch kernel K on my inputs" requests.  While the
underlying executor is busy, compatible submissions accumulate; when it
becomes idle — or the ``max_aggregated`` cap is reached — the queued tasks
are fused into ONE batched kernel launch over a slot axis.  Each task gets a
future resolving to its slot of the batched output.

TPU adaptation (DESIGN.md §2): XLA requires static shapes, so a dynamic
aggregation count is realized as a small set of pre-compiled *buckets*
(powers of two up to the cap).  A queue of length k is drained greedily with
the largest bucket <= k; because bucket 1 exists, no padding is ever needed
and results are *bit-identical* to unaggregated execution (the equivalence
invariant tested in tests/test_aggregation.py and tests/test_slot_ring.py).

Staging (DESIGN.md §3): the hot path is device-resident end to end.  Task
inputs either

* land in a pre-allocated :class:`~repro.core.buffers.SlotRing` via donated
  ``lax.dynamic_update_slice`` writes (concrete per-task arrays), or
* stay where they already live and are referenced by a :class:`SlotView`
  ``(parent, index)``; a launch then performs ONE ``jnp.take`` gather inside
  the bucketed program (index-batched staging, zero per-task slicing).

The seed's slice -> host-stack -> launch cycle survives as
``staging="host"`` so benchmarks/launch_overhead.py can measure the win.

The paper's "Single-GPU-workload-Multiple-Tasks" constraint (all aggregated
tasks execute the same allocation/launch sequence) is enforced *statically*
here: the bucketed kernel is one traced function extended over the slot axis,
so divergence between aggregated tasks is impossible by construction.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AggregationConfig
from repro.core.buffers import DEFAULT_POOL, BufferPool, SlotRing
from repro.core.executor import ExecutorPool


class TaskFuture:
    """HPX-future analogue: resolves to one task's slice of a batched launch.

    Resolution is lazy twice over: ``_fulfil`` only records (batch, slot) —
    no per-slot ``tree_map`` happens until ``result()`` is actually read —
    and callers that want the whole batch back should use
    :func:`gather_futures`, which recognises futures covering a full launch
    and returns the batched output itself with zero copies.
    """

    __slots__ = ("_value", "_batch", "_slot", "_done")

    def __init__(self):
        self._value = None
        self._batch = None
        self._slot = -1
        self._done = False

    def _fulfil(self, batch_out: Any, slot: int) -> None:
        self._batch, self._slot, self._done = batch_out, slot, True

    def ready(self) -> bool:
        return self._done

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if self._value is None:
            slot = self._slot
            self._value = jax.tree_util.tree_map(lambda x: x[slot], self._batch)
            self._batch = None
        return self._value


def gather_futures(futs: Sequence[TaskFuture]) -> Any:
    """Assemble many futures' results into one batched array, lazily.

    Futures fulfilled by the same launch share one batched output; a run of
    such futures in slot order contributes the batch itself (zero-copy).
    Out-of-order runs become a single ``jnp.take``; distinct launches are
    joined with one ``jnp.concatenate``.  This replaces the seed's
    per-future slice + re-stack (2n device ops for n tasks) with O(launches)
    ops.
    """
    if not futs:
        raise ValueError("gather_futures needs at least one future")
    parts = []
    i = 0
    while i < len(futs):
        f = futs[i]
        if not f._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if f._batch is None:          # already resolved individually
            parts.append(jax.tree_util.tree_map(lambda x: x[None], f.result()))
            i += 1
            continue
        batch = f._batch
        slots = []
        while i < len(futs) and futs[i]._batch is batch:
            slots.append(futs[i]._slot)
            i += 1
        n_slots = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if slots == list(range(n_slots)):
            parts.append(batch)       # the whole launch, in order: zero-copy
        else:
            idx = jnp.asarray(slots, jnp.int32)
            parts.append(jax.tree_util.tree_map(
                lambda x: jnp.take(x, idx, axis=0), batch))
    if len(parts) == 1:
        return parts[0]
    return jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *parts)


class SlotView:
    """Zero-copy task-input reference: ``parent[index]``, never sliced.

    Submitting SlotViews lets ``_launch`` stage a whole bucket with ONE
    ``jnp.take`` over the already-device-resident parent instead of n
    per-task slices — the index-batched staging mode.
    """

    __slots__ = ("parent", "index")

    def __init__(self, parent: jax.Array, index: int):
        self.parent = parent
        self.index = index


@dataclass
class _Pending:
    future: TaskFuture
    slot: int = -1                               # ring mode: slot in the ring
    views: Optional[Tuple[SlotView, ...]] = None  # ref mode
    args: Optional[Tuple[Any, ...]] = None        # host mode


class AggregationExecutor:
    """Aggregates submissions of one *kernel family* into bucketed launches.

    Parameters
    ----------
    batched_fn : callable
        ``batched_fn(*stacked_args) -> stacked_out`` where every arg/out has
        a leading slot axis.  This is the "aggregation region" body: one
        traced function shared by all aggregated tasks (SGMT by construction).
    config : AggregationConfig
        ``max_aggregated`` caps the bucket size (the paper's second launch
        criterion); ``n_executors`` sizes the underlying executor pool
        (combining strategy 3 with strategy 2, as the paper's best rows do);
        ``staging`` selects device-resident (slot ring / indexed gather) or
        the seed's host staging.
    """

    def __init__(self, batched_fn: Callable, config: AggregationConfig,
                 pool: Optional[ExecutorPool] = None,
                 buffer_pool: Optional[BufferPool] = None,
                 donate: bool = False,
                 name: str = "region"):
        self.name = name
        self.config = config
        self.pool = pool or ExecutorPool(config.n_executors)
        self.buffers = buffer_pool or DEFAULT_POOL
        self.ring: Optional[SlotRing] = None
        self._queue: List[_Pending] = []
        self._buckets = tuple(sorted(config.bucket_sizes()))
        self._compiled: Dict[Tuple[str, int], Callable] = {}
        self._batched_fn = batched_fn
        self._donate = donate
        self._staging = getattr(config, "staging", "device")
        if self._staging not in ("device", "host"):
            raise ValueError(f"unknown staging mode {self._staging!r}")
        # shared shape-polymorphic wrappers (jit re-specializes per shape,
        # so ONE wrapper serves every bucket / parent shape)
        self._host_jit = jax.jit(
            self._batched_fn, donate_argnums=(0,) if donate else ())
        self._gather_jit = jax.jit(self._apply_gathered)
        # statistics for the benchmark tables
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {},
                      "staging_s": 0.0}

    # -- bucketed programs -------------------------------------------------
    def _apply_gathered(self, idx, *parents):
        """Index-batched staging: one gather feeds the aggregation body."""
        return self._batched_fn(*(jnp.take(p, idx, axis=0) for p in parents))

    def _apply_ring_prefix(self, bucket: int, start, *rings):
        """Ring staging: the bucket reads a zero-copy view of the filled
        prefix [start, start+bucket) straight out of the slot ring."""
        sliced = tuple(jax.lax.dynamic_slice_in_dim(r, start, bucket, axis=0)
                       for r in rings)
        return self._batched_fn(*sliced)

    # -- compilation cache -------------------------------------------------
    # Each bucket size is a genuinely distinct XLA program (static shapes),
    # cached under ("ring"|"host", bucket).  ``warmup`` replaces the lazy
    # jit wrappers with AOT ``.lower().compile()`` executables so the first
    # submission wave never hits the tracer (CPPuddle's startup-time
    # executor allocation analogue).
    def compiled_for(self, bucket: int, mode: str = "ring") -> Callable:
        # "ring" entries may be AOT-specialized to the ring buffer shapes by
        # warmup; "prefix" entries serve arbitrary parents (shape-polymorphic
        # jit) for contiguous SlotView runs.
        key = (mode, bucket)
        fn = self._compiled.get(key)
        if fn is None:
            if mode in ("ring", "prefix"):
                fn = jax.jit(partial(self._apply_ring_prefix, bucket))
            else:
                fn = self._host_jit
            self._compiled[key] = fn
        return fn

    def _ensure_ring(self, example_args: Sequence[Any]) -> SlotRing:
        if self.ring is None:
            self.ring = SlotRing(self.config.max_aggregated, example_args)
        return self.ring

    def warmup(self, example_args: Tuple[Any, ...]) -> None:
        """AOT pre-compile every bucket size (amortized startup, like stream
        pre-allocation in CPPuddle).

        Buckets are lowered with ``.lower().compile()`` — no example
        execution, no broadcast staging, and no tracer hit on the first
        real submission.  (Gather-mode programs specialize on the parent
        array's shape, which is only known at submit time; they stay lazily
        jitted.)
        """
        specs = [jax.ShapeDtypeStruct(np.shape(a), jnp.asarray(a).dtype)
                 for a in example_args]
        start = jax.ShapeDtypeStruct((), jnp.int32)
        if self._staging == "device":
            ring = self._ensure_ring(example_args)
            ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                          for r in ring.buffers()]
            for b in self._buckets:
                fn = jax.jit(partial(self._apply_ring_prefix, b))
                self._compiled[("ring", b)] = fn.lower(
                    start, *ring_specs).compile()
        else:
            for b in self._buckets:
                stacked = tuple(
                    jax.ShapeDtypeStruct((b,) + s.shape, s.dtype)
                    for s in specs)
                self._compiled[("host", b)] = self._host_jit.lower(
                    *stacked).compile()

    # -- submission API ----------------------------------------------------
    def submit(self, *args) -> TaskFuture:
        """Queue one task.  Args are either concrete per-task arrays (staged
        into the slot ring) or all :class:`SlotView` references (staged by a
        single gather at launch)."""
        fut = TaskFuture()
        is_ref = bool(args) and all(isinstance(a, SlotView) for a in args)
        if is_ref and self._staging == "device":
            if any(v.index != args[0].index for v in args[1:]):
                raise ValueError(
                    "SlotView args of one task must share one index — a "
                    "launch gathers the SAME slot from every parent "
                    "(use submit_indexed)")
            entry = _Pending(future=fut, views=tuple(args))
        elif self._staging == "host" or not args:
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            entry = _Pending(future=fut, args=args)
        else:
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            t0 = time.perf_counter()
            ring = self._ensure_ring(args)
            if ring.fill >= ring.capacity:
                # watermark remainders left a partial prefix consumed; slide
                # the live tail to the front (one fused device op)
                first = self._queue[0].slot if self._queue else ring.fill
                ring.compact(first)
                for p in self._queue:
                    p.slot -= first
            entry = _Pending(future=fut, slot=ring.write(args))
            self.stats["staging_s"] += time.perf_counter() - t0
        self._check_mode(entry)
        self._queue.append(entry)
        self.stats["submitted"] += 1
        self._maybe_launch()
        return fut

    def submit_indexed(self, parents: Tuple[jax.Array, ...],
                       index: int) -> TaskFuture:
        """Sugar: submit task ``i`` whose j-th arg is ``parents[j][i]``."""
        return self.submit(*(SlotView(p, index) for p in parents))

    def _check_mode(self, entry: _Pending) -> None:
        """A bucket must stage uniformly: same mode, and for ref entries the
        same parent arrays (a launch gathers from ONE parent set).  Launch
        what's queued before admitting an incompatible entry."""
        if not self._queue:
            return
        head = self._queue[0]
        compatible = self._entry_mode(head) == self._entry_mode(entry)
        if compatible and entry.views is not None:
            compatible = all(a.parent is b.parent
                             for a, b in zip(head.views, entry.views))
        if not compatible:
            while self._queue:
                self._launch(self._largest_bucket(len(self._queue)))

    @staticmethod
    def _entry_mode(entry: _Pending) -> str:
        if entry.views is not None:
            return "ref"
        if entry.args is not None:
            return "host"
        return "ring"

    def _maybe_launch(self) -> None:
        """The paper's launch policy: launch when (a) the cap is reached, or
        (b) an underlying executor is idle; otherwise keep aggregating."""
        while self._queue:
            q = len(self._queue)
            if q >= self.config.max_aggregated:
                self._launch(self.config.max_aggregated)
            elif q >= self.config.launch_watermark and self.pool.any_idle():
                self._launch(self._largest_bucket(q))
            else:
                break

    def _largest_bucket(self, k: int) -> int:
        best = self._buckets[0]
        for b in self._buckets:
            if b <= k:
                best = b
        return best

    def _launch(self, k: int) -> None:
        tasks, self._queue = self._queue[:k], self._queue[k:]
        mode = self._entry_mode(tasks[0])
        t0 = time.perf_counter()
        if mode == "ref":
            indices = [t.views[0].index for t in tasks]
            parents = tuple(v.parent for v in tasks[0].views)
            if indices == list(range(indices[0], indices[0] + k)):
                # contiguous slot run: one dynamic slice of the parent (the
                # parent IS the ring) — no gather, no index array
                fn = self.compiled_for(k, "prefix")
                call_args = (jnp.int32(indices[0]),) + parents
            else:
                idx = jnp.asarray(indices, jnp.int32)
                fn, call_args = self._gather_jit, (idx,) + parents
        elif mode == "ring":
            fn = self.compiled_for(k, "ring")
            call_args = (jnp.int32(tasks[0].slot),) + self.ring.buffers()
        else:
            stacked = []
            for j in range(len(tasks[0].args)):
                parts = [t.args[j] for t in tasks]
                if k == 1:
                    stacked.append(jnp.asarray(parts[0])[None])
                elif isinstance(parts[0], jax.Array):
                    stacked.append(jnp.stack(parts))
                else:
                    stacked.append(jnp.asarray(self.buffers.stage(parts)))
            fn = self._compiled.get(("host", k), self._host_jit)
            call_args = tuple(stacked)
        self.stats["staging_s"] += time.perf_counter() - t0
        exe = self.pool.get()
        out = exe.launch(fn, *call_args)
        for slot, t in enumerate(tasks):
            t.future._fulfil(out, slot)
        if mode == "ring" and not self._queue:
            self.ring.swap()      # in-flight launch keeps the old buffer
        self.stats["launches"] += 1
        hist = self.stats["aggregated_hist"]
        hist[k] = hist.get(k, 0) + 1

    def flush(self) -> None:
        """Launch everything still queued (greedy buckets) and drain."""
        while self._queue:
            self._launch(self._largest_bucket(len(self._queue)))
        self.pool.drain()

    def map(self, task_args: Sequence[Tuple[Any, ...]]) -> List[Any]:
        """Submit many tasks, flush, return their results in order."""
        futs = [self.submit(*a) for a in task_args]
        self.flush()
        return [f.result() for f in futs]


# ---------------------------------------------------------------------------
# Region API — the paper's "aggregation region" (a marked code region that
# compatible tasks may enter together).  Cosmetic sugar over the executor.
# ---------------------------------------------------------------------------

_REGIONS: Dict[str, AggregationExecutor] = {}


def aggregation_region(name: str, batched_fn: Callable,
                       config: Optional[AggregationConfig] = None,
                       **kw) -> AggregationExecutor:
    """Get-or-create the named region's executor (one Executor Pool per
    aggregation region, as in the paper's CPPuddle implementation)."""
    exe = _REGIONS.get(name)
    if exe is None:
        exe = AggregationExecutor(batched_fn, config or AggregationConfig(),
                                  name=name, **kw)
        _REGIONS[name] = exe
    return exe


def reset_regions() -> None:
    _REGIONS.clear()
