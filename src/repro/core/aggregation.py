"""The paper's strategy 3: on-the-fly explicit work aggregation, TPU-native.

Fine-grained tasks submit "launch kernel K on my inputs" requests.  While the
underlying executor is busy, compatible submissions accumulate; when it
becomes idle — or the ``max_aggregated`` cap is reached — the queued tasks
are fused into ONE batched kernel launch over a slot axis.  Each task gets a
future resolving to its slot of the batched output.

Multi-region runtime (DESIGN.md §7): one executor hosts MANY aggregation
regions at once.  Submissions are routed by :class:`TaskSignature` — kernel
id plus per-argument shape/dtype — to their family's slot ring, queue and
compiled-bucket cache, so heterogeneous task populations (the adaptive-
refinement regime of the follow-up AMR work, arXiv:2412.15518) aggregate
concurrently without serializing each other.  A region is created lazily the
first time a signature is seen, which also makes a single registered kernel
shape-polymorphic: new task shapes simply open new regions over the same
body.

TPU adaptation (DESIGN.md §2): XLA requires static shapes, so a dynamic
aggregation count is realized as a small set of pre-compiled *buckets*
(powers of two up to the cap).  A queue of length k is drained greedily with
the largest bucket <= k; because bucket 1 exists, no padding is ever needed
and results are *bit-identical* to unaggregated execution (the equivalence
invariant tested in tests/test_aggregation.py and tests/test_slot_ring.py).

Staging (DESIGN.md §3): the hot path is device-resident end to end.  Task
inputs either

* land in a pre-allocated :class:`~repro.core.buffers.SlotRing` via donated
  coalesced scatters (concrete per-task arrays), or
* stay where they already live and are referenced by a :class:`SlotView`
  ``(parent, index)``; a launch then performs ONE ``jnp.take`` gather inside
  the bucketed program (index-batched staging, zero per-task slicing).

The seed's slice -> host-stack -> launch cycle survives as
``staging="host"`` so benchmarks/launch_overhead.py can measure the win.

The paper's "Single-GPU-workload-Multiple-Tasks" constraint (all aggregated
tasks execute the same allocation/launch sequence) is enforced *statically*
here: each region's bucketed kernel is one traced function extended over the
slot axis, so divergence between aggregated tasks is impossible by
construction.
"""
from __future__ import annotations

import bisect
import statistics
import time
from dataclasses import dataclass
from functools import partial
from types import MappingProxyType
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AggregationConfig
from repro.core.buffers import DEFAULT_POOL, BufferPool, SlotRing
from repro.core.executor import ExecutorPool


# inner-chunk auto-tune memo: (backend, body id, bucket, task specs) ->
# (body, chunk).  Keyed on the backend AND device kind because the chunk is
# a *measured* choice — a value timed on one backend must never leak into a
# process that later tunes the same body on another device.  Keeping the
# body ref in the value pins its id() for the key's lifetime (an id-keyed
# entry without the ref would collide on id reuse); the cache is
# FIFO-bounded so long-lived sweeps don't pin every body ever tuned.
_CHUNK_TUNE_MEMO: Dict[Tuple, Tuple[Any, int]] = {}
_CHUNK_TUNE_MEMO_MAX = 32


def _backend_key() -> Tuple[str, str]:
    """(backend, device kind) — the identity a timed tuning choice is valid
    for.  Measured decisions (inner_chunk, bucket costs) are per-device:
    what saturates a TPU-v4 is not what saturates a 2-core CPU."""
    try:
        kind = getattr(jax.devices()[0], "device_kind", "")
    except RuntimeError:
        kind = ""
    return jax.default_backend(), kind


class TaskFuture:
    """HPX-future analogue: resolves to one task's slice of a batched launch.

    Resolution is lazy twice over: ``_fulfil`` only records (batch, slot) —
    no per-slot ``tree_map`` happens until ``result()`` is actually read —
    and callers that want the whole batch back should use
    :func:`gather_futures`, which recognises futures covering a full launch
    and returns the batched output itself with zero copies.
    """

    __slots__ = ("_value", "_batch", "_slot", "_done")

    def __init__(self):
        self._value = None
        self._batch = None
        self._slot = -1
        self._done = False

    def _fulfil(self, batch_out: Any, slot: int) -> None:
        self._batch, self._slot, self._done = batch_out, slot, True

    def ready(self) -> bool:
        return self._done

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if self._value is None:
            slot = self._slot
            self._value = jax.tree_util.tree_map(lambda x: x[slot], self._batch)
            self._batch = None
        return self._value


class RangeFuture:
    """One future for a contiguous range of ``count`` tasks (the bulk-
    submission analogue of :class:`TaskFuture`).

    A range enters the queue as ONE entry; the greedy drain may still split
    it across several bucketed launches, so fulfilment is segmented: each
    launch contributes ``(range_offset, batch, slot, n)``.  ``result()``
    assembles the full ``(count, ...)`` batch — zero-copy when one launch
    covered the whole range in order, which is the steady-state fast path
    (``submit_range`` of a full wave -> one mega-bucket launch -> the
    launch output IS the result).
    """

    __slots__ = ("_parts", "_count", "_value")

    def __init__(self, count: int):
        self._parts: List[Tuple[int, Any, int, int]] = []
        self._count = count
        self._value = None

    def __len__(self) -> int:
        return self._count

    def _fulfil_range(self, batch_out: Any, slot: int, offset: int,
                      n: int) -> None:
        self._parts.append((offset, batch_out, slot, n))

    def ready(self) -> bool:
        if self._value is not None:     # resolved (parts were released)
            return True
        return sum(p[3] for p in self._parts) == self._count

    def result(self) -> Any:
        """The whole range as one batched pytree (task axis leading)."""
        if self._value is None:
            if not self.ready():
                raise RuntimeError(
                    "range not fully launched yet — call executor.flush()")
            self._value = _assemble_segments(
                [(batch, slot, n)
                 for _, batch, slot, n in sorted(self._parts,
                                                 key=lambda p: p[0])])
            self._parts = []
        return self._value

    def _segments(self):
        if self._value is not None:
            leaves = jax.tree_util.tree_leaves(self._value)
            yield self._value, 0, leaves[0].shape[0]
            return
        if not self.ready():
            raise RuntimeError(
                "range not fully launched yet — call executor.flush()")
        for _, batch, slot, n in sorted(self._parts, key=lambda p: p[0]):
            yield batch, slot, n


def _assemble_segments(segments: List[Tuple[Any, int, int]]) -> Any:
    """Merge ``(batch, start_slot, n)`` runs into one batched pytree.

    Consecutive runs on the same launch output coalesce; a run covering a
    whole launch in order contributes the batch itself (zero-copy), a
    contiguous partial run is one slice, anything else one ``jnp.take``.
    """
    parts = []
    i = 0
    while i < len(segments):
        batch = segments[i][0]
        runs = []                                  # [(start, n)] on `batch`
        while i < len(segments) and segments[i][0] is batch:
            s0, n = segments[i][1], segments[i][2]
            if runs and runs[-1][0] + runs[-1][1] == s0:
                runs[-1] = (runs[-1][0], runs[-1][1] + n)
            else:
                runs.append((s0, n))
            i += 1
        n_slots = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if runs == [(0, n_slots)]:
            parts.append(batch)       # the whole launch, in order: zero-copy
        elif len(runs) == 1:
            s0, n = runs[0]
            parts.append(jax.tree_util.tree_map(
                lambda x: jax.lax.slice_in_dim(x, s0, s0 + n, axis=0), batch))
        else:
            idx = jnp.asarray([s for s0, n in runs
                               for s in range(s0, s0 + n)], jnp.int32)
            parts.append(jax.tree_util.tree_map(
                lambda x: jnp.take(x, idx, axis=0), batch))
    if len(parts) == 1:
        return parts[0]
    return _concat_parts(parts)


def _concat_parts(parts: List[Any]) -> Any:
    task_specs = {tuple((tuple(x.shape[1:]), np.dtype(x.dtype).str)
                        for x in jax.tree_util.tree_leaves(p))
                  for p in parts}
    if len(task_specs) > 1:
        raise ValueError(
            f"futures span task families with different output "
            f"shapes/dtypes {sorted(task_specs)} — gather each family "
            f"separately")
    return jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *parts)


def gather_futures(futs: Sequence[Any]) -> Any:
    """Assemble many futures' results into one batched array, lazily.

    Futures fulfilled by the same launch share one batched output; a run of
    such futures in slot order contributes the batch itself (zero-copy).
    Out-of-order runs become a single ``jnp.take``; distinct launches are
    joined with one ``jnp.concatenate``.  This replaces the seed's
    per-future slice + re-stack (2n device ops for n tasks) with O(launches)
    ops.

    ``TaskFuture`` and ``RangeFuture`` entries may be interleaved freely (a
    range contributes its launch segments in range order), as may launches
    from different regions — but all results must share one output
    task-shape to concatenate; gather each family separately otherwise.
    """
    if not futs:
        raise ValueError("gather_futures needs at least one future")
    segments: List[Tuple[Any, int, int]] = []
    parts = []

    def emit_segments():
        if segments:
            parts.append(_assemble_segments(segments))
            segments.clear()

    for f in futs:
        if isinstance(f, RangeFuture):
            segments.extend(f._segments())
            continue
        if not f._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if f._batch is None:          # already resolved individually
            emit_segments()
            parts.append(jax.tree_util.tree_map(lambda x: x[None], f.result()))
        else:
            segments.append((f._batch, f._slot, 1))
    emit_segments()
    if len(parts) == 1:
        return parts[0]
    return _concat_parts(parts)


class SlotView:
    """Zero-copy task-input reference: ``parent[index]``, never sliced.

    Submitting SlotViews lets ``_launch`` stage a whole bucket with ONE
    ``jnp.take`` over the already-device-resident parent instead of n
    per-task slices — the index-batched staging mode.
    """

    __slots__ = ("parent", "index")

    def __init__(self, parent: jax.Array, index: int):
        self.parent = parent
        self.index = index


def _spec_of(a) -> Tuple[Tuple[int, ...], str]:
    """(shape, dtype-str) of one task argument (SlotView -> per-slot spec)."""
    if isinstance(a, SlotView):
        p = a.parent
        return tuple(p.shape[1:]), np.dtype(p.dtype).str
    if hasattr(a, "shape") and hasattr(a, "dtype"):   # jax array / SDS
        return tuple(a.shape), np.dtype(a.dtype).str
    arr = np.asarray(a)
    return arr.shape, np.dtype(jax.dtypes.canonicalize_dtype(arr.dtype)).str


@dataclass(frozen=True)
class TaskSignature:
    """What makes two fine-grained tasks aggregable: the kernel family id
    plus every argument's per-task shape and dtype.  The paper's SGMT
    compatibility check, reified as the region-registry key."""

    kernel: str
    arg_specs: Tuple[Tuple[Tuple[int, ...], str], ...]

    @classmethod
    def from_args(cls, kernel: str, args: Sequence[Any]) -> "TaskSignature":
        return cls(kernel, tuple(_spec_of(a) for a in args))

    def describe(self) -> str:
        """Unique human-readable key: shapes, with dtype appended whenever
        it is not the default float32 (so same-shape families of different
        dtypes never collide in ``stats["regions"]``)."""
        f32 = np.dtype(np.float32).str

        def one(spec):
            shape, dt = spec
            s = "x".join(map(str, shape)) or "scalar"
            return s if dt == f32 else f"{s}:{dt.lstrip('<>|=')}"

        return f"{self.kernel}[{','.join(one(s) for s in self.arg_specs)}]"


@dataclass
class _Pending:
    future: Any                                   # TaskFuture | RangeFuture
    slot: int = -1                               # ring mode: slot in the ring
    views: Optional[Tuple[SlotView, ...]] = None  # ref mode
    args: Optional[Tuple[Any, ...]] = None        # host mode
    count: int = 1                    # tasks in this entry (>1: slot range)
    fut_offset: int = 0               # this entry's offset in its RangeFuture

    def split(self, n: int) -> Tuple["_Pending", "_Pending"]:
        """Split a contiguous range entry: first ``n`` tasks / the rest.
        Both halves share the future (each fulfils its own offset)."""
        assert 0 < n < self.count and self.views is not None
        head = _Pending(future=self.future, views=self.views, count=n,
                        fut_offset=self.fut_offset)
        tail = _Pending(
            future=self.future,
            views=tuple(SlotView(v.parent, v.index + n) for v in self.views),
            count=self.count - n, fut_offset=self.fut_offset + n)
        return head, tail


class BucketCostModel:
    """Measured per-bucket wall times for ONE region (DESIGN.md §10).

    ``record`` accumulates raw timed samples per bucket size; ``time``
    reports the median (robust against scheduler hiccups on a noisy host);
    ``predict`` extends the table to unmeasured sizes by piecewise-linear
    interpolation in the bucket size — clamped below the smallest measured
    bucket (a launch never costs less than the smallest thing we timed,
    which is what stops the tuner from hallucinating free micro-launches)
    and extrapolated above the largest with the last measured segment's
    slope (floored at the largest measurement).

    The model is the common currency of the measured tuner: the ladder
    derivation minimizes ``predict_seq`` of each wave's greedy
    decomposition, and the ``"cost"`` flush policy compares split-drain
    against one-shot predictions.  ``as_stats`` is the JSON-safe table
    persisted into ``stats["regions"][fam]["cost_model"]`` and the BENCH
    rows (milliseconds, bucket-keyed).
    """

    __slots__ = ("samples",)

    def __init__(self):
        self.samples: Dict[int, List[float]] = {}

    def record(self, bucket: int, seconds: float) -> None:
        self.samples.setdefault(int(bucket), []).append(float(seconds))

    def clear(self) -> None:
        """Drop every sample (the measurements' premise changed — e.g. the
        region's inner chunk was re-swept, so old timings describe programs
        that no longer exist)."""
        self.samples.clear()

    def measured(self) -> bool:
        return bool(self.samples)

    def buckets(self) -> Tuple[int, ...]:
        return tuple(sorted(self.samples))

    def time(self, bucket: int) -> Optional[float]:
        s = self.samples.get(bucket)
        return statistics.median(s) if s else None

    def predict(self, bucket: int) -> float:
        t = self.time(bucket)
        if t is not None:
            return t
        bs = self.buckets()
        if not bs:
            raise ValueError("cost model has no measurements — check "
                             "measured() before predicting")
        if bucket <= bs[0]:
            return self.time(bs[0])
        if bucket >= bs[-1]:
            hi = self.time(bs[-1])
            if len(bs) == 1:
                return hi * bucket / bs[-1]
            lo = self.time(bs[-2])
            slope = (hi - lo) / (bs[-1] - bs[-2])
            return max(hi, hi + slope * (bucket - bs[-1]))
        i = bisect.bisect_left(bs, bucket)
        b0, b1 = bs[i - 1], bs[i]
        t0, t1 = self.time(b0), self.time(b1)
        return t0 + (t1 - t0) * (bucket - b0) / (b1 - b0)

    def predict_seq(self, buckets: Sequence[int]) -> float:
        """Predicted wall time of one greedy drain (launch sequence)."""
        return sum(self.predict(b) for b in buckets)

    def as_stats(self) -> Dict[int, float]:
        """{bucket: median milliseconds}, rounded for the stats surface."""
        return {b: round(self.time(b) * 1e3, 4) for b in self.buckets()}


def greedy_decomposition(k: int, buckets: Sequence[int]) -> Tuple[int, ...]:
    """The bucket sequence the greedy drain launches for a queue of length
    k under a valid ladder (every bucket <= the cap by validation, so this
    models over-cap waves too: a 100-task wave under cap 64 is 64 + the
    greedy cover of 36).  Shared by the launch path, the ladder tuner and
    wave-only warmup — one definition of "what will actually launch"."""
    out = []
    while k:
        b = max(x for x in buckets if x <= k)
        out.append(b)
        k -= b
    return tuple(out)


def greedy_launches(k: int, buckets: Sequence[int]) -> int:
    """Launches the greedy drain performs for a queue of length k under a
    valid ladder (shared oracle; tests mirror it in conftest.py)."""
    return len(greedy_decomposition(k, buckets))


def ladder_candidates(queue_hist: Mapping[int, int], cap: int) -> set:
    """The bucket sizes a ladder derivation considers: observed wave peaks
    clipped to the cap, their cap-split remainders, plus powers of two up
    to the cap.  Shared by :func:`derive_ladder` and the executor's
    cost-model measurement pass, so exactly the drain-reachable sizes the
    tuner may pick are the ones that get timed."""
    candidates = set()
    for k in queue_hist:
        if k <= 0:
            continue
        candidates.add(min(k, cap))
        if k > cap and k % cap:
            candidates.add(k % cap)   # the cap-split remainder of the wave
    b = 1
    while b <= cap:
        candidates.add(b)
        b *= 2
    return candidates


def derive_ladder(queue_hist: Mapping[int, int], cap: int, budget: int,
                  cost_model: Optional[BucketCostModel] = None
                  ) -> Tuple[int, ...]:
    """Re-derive a bucket ladder from an observed queue-length histogram.

    Starting from the mandatory ``{1}`` (the no-padding invariant needs a
    remainder bucket) seeded with the dominant wave's cap-decomposition
    (a single candidate search cannot learn that the cap bucket is only
    worth having TOGETHER with its remainder — e.g. a 100-task wave under
    cap 64 wants {64, 36} as a pair), greedily add the candidate size
    (:func:`ladder_candidates`) that most reduces the per-wave objective,
    until ``budget`` distinct bucket programs are reached or no candidate
    improves.  A steady k-task wave therefore converges on a ladder
    covering k exactly: one launch per cap-chunk, no ones-drain.

    The objective is *expected launches per wave* — the §9 proxy — unless
    a measured :class:`BucketCostModel` is supplied, in which case it is
    the *predicted wall time per wave* (DESIGN.md §10: the device's cost
    structure, not a launch count).  Under the model, a final prune drops
    any seeded bucket whose removal does not increase predicted time, so
    exact-cost ties always resolve to the smaller compile footprint
    (candidates are also tried smallest-first: an equal-cost pair admits
    the cheaper program).
    """
    # non-positive "wave lengths" carry no drain (and would crash the
    # greedy cover): drop them before they reach the objective
    queue_hist = {k: c for k, c in queue_hist.items() if k > 0}
    candidates = ladder_candidates(queue_hist, cap)
    use_model = cost_model is not None and cost_model.measured()

    def cost(ladder):
        # candidate buckets never exceed the cap, so the greedy cover of
        # the FULL wave length models the real drain (cap-splits included)
        ls = sorted(ladder)
        if use_model:
            return sum(c * cost_model.predict_seq(greedy_decomposition(k, ls))
                       for k, c in queue_hist.items())
        return sum(c * greedy_launches(k, ls)
                   for k, c in queue_hist.items())

    ladder = {1}
    peaks = [k for k in queue_hist if k > 0]
    if peaks:
        top = max(peaks, key=lambda k: (queue_hist[k], k))
        seed = {cap, top % cap} if top > cap else {top}
        for b in sorted(seed - {0}, reverse=True):
            if len(ladder) < budget:
                ladder.add(b)

    def grow():
        while len(ladder) < budget:
            best, best_cost = None, cost(ladder)
            for c in sorted(candidates - ladder):
                cc = cost(ladder | {c})
                if cc < best_cost:
                    best, best_cost = c, cc
            if best is None:
                break
            ladder.add(best)

    grow()
    if use_model:
        # The seeds were added without a cost check (correct under the
        # launch-count objective, where a mega bucket can never lose);
        # measured time CAN say a big bucket is pessimal, so drop any
        # bucket whose removal keeps predicted time no worse — ties go to
        # the smaller compile footprint — then let the search refill the
        # freed budget (a pruned cap bucket may have been shadowing its
        # cheaper halves).  (cost, |ladder|) strictly decreases each
        # cycle, so the loop terminates.
        while True:
            pruned = False
            for b in sorted(ladder - {1}, reverse=True):
                if cost(ladder - {b}) <= cost(ladder):
                    ladder.discard(b)
                    pruned = True
                    break
            if not pruned:
                break
            grow()
    return tuple(sorted(ladder))


def _chunked_eval(batched_fn: Callable, chunk: int, *stacked):
    """Mega-bucket evaluation: run the batched body over the slot axis in
    sequential ``chunk``-slot pieces via ONE ``lax.map`` inside the same
    program.  Bit-identical to the flat call (a pure batch split of an
    independent-per-slot body); the win is cache locality — stencil-heavy
    bodies keep their intermediates resident instead of streaming a
    bucket-64-sized working set.  Falls back to the flat call whenever the
    chunk does not divide the bucket (no padding, ever)."""
    k = stacked[0].shape[0] if stacked else 0
    if chunk and 0 < chunk < k and k % chunk == 0:
        resh = tuple(a.reshape((k // chunk, chunk) + a.shape[1:])
                     for a in stacked)
        out = jax.lax.map(lambda xs: batched_fn(*xs), resh)
        return jax.tree_util.tree_map(
            lambda o: o.reshape((o.shape[0] * o.shape[1],) + o.shape[2:]),
            out)
    return batched_fn(*stacked)


class _Region:
    """One aggregation region: per-TaskSignature slot ring, submission queue
    and compiled-bucket cache.  Regions share the owning executor's pool,
    launch policy and config; everything shape- or body-specific lives here.
    """

    __slots__ = ("signature", "batched_fn", "ring", "queue", "compiled",
                 "host_jit", "gather_jit", "stats", "buckets", "chunk",
                 "chunk_tuned", "queued_tasks", "waves", "tuned",
                 "_wave_peak", "_aot_parents", "cost", "_retuned_waves",
                 "_retuned_peak", "_donate")

    def __init__(self, signature: TaskSignature, batched_fn: Callable,
                 donate: bool, buckets: Tuple[int, ...] = (1,),
                 chunk: int = 0):
        self.signature = signature
        self.batched_fn = batched_fn
        self._donate = donate
        self.ring: Optional[SlotRing] = None
        self.queue: List[_Pending] = []
        self.queued_tasks = 0         # tasks queued (entries carry counts)
        self.compiled: Dict[Tuple, Callable] = {}
        self.buckets = buckets        # per-region ladder (auto-tune target)
        self.chunk = chunk            # mega-bucket inner chunk (0 = flat)
        self.chunk_tuned = False      # "auto" tuning ran for this region
        self.waves = 0                # completed waves (queue drained to 0)
        self.tuned = False
        self._wave_peak = 0
        self._aot_parents: Dict[Tuple, Tuple] = {}  # pk -> parent structs
        self.cost = BucketCostModel()     # measured bucket wall times (§10)
        self._retuned_waves = -1      # waves counter at the last retune
        self._retuned_peak = 0        # largest wave peak seen at last retune
        # shared shape-polymorphic wrappers (jit re-specializes per shape,
        # so ONE wrapper serves every bucket / parent shape)
        self.reset_compiled()
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {},
                      "queue_hist": {}, "ladder": list(buckets)}

    # -- bucketed programs -------------------------------------------------
    def _eval(self, *stacked):
        """The body over a staged bucket, chunk-aware (DESIGN.md §9)."""
        return _chunked_eval(self.batched_fn, self.chunk, *stacked)

    def _apply_host(self, *stacked):
        return self._eval(*stacked)

    def _apply_gathered(self, idx, *parents):
        """Index-batched staging: one gather feeds the aggregation body."""
        return self._eval(*(jnp.take(p, idx, axis=0) for p in parents))

    def _apply_ring_prefix(self, bucket: int, start, *rings):
        """Ring staging: the bucket reads a zero-copy view of the filled
        prefix [start, start+bucket) straight out of the slot ring."""
        sliced = tuple(jax.lax.dynamic_slice_in_dim(r, start, bucket, axis=0)
                       for r in rings)
        return self._eval(*sliced)

    # -- compilation cache -------------------------------------------------
    # Each bucket size is a genuinely distinct XLA program (static shapes),
    # cached under ("ring"|"host"|"prefix", bucket) — plus parent-shape-keyed
    # AOT entries ("gather"|"prefix_aot", bucket, parent_shapes) installed by
    # ``AggregationExecutor.warmup(parent_shapes=...)``.
    def compiled_for(self, bucket: int, mode: str = "ring") -> Callable:
        key = (mode, bucket)
        fn = self.compiled.get(key)
        if fn is None:
            if mode in ("ring", "prefix"):
                fn = jax.jit(partial(self._apply_ring_prefix, bucket))
            else:
                fn = self.host_jit
            self.compiled[key] = fn
        return fn

    def ensure_ring(self, capacity: int,
                    example_args: Sequence[Any]) -> SlotRing:
        if self.ring is None:
            self.ring = SlotRing(capacity, example_args)
        return self.ring

    def expected_peak(self) -> int:
        """The modal observed wave peak (ties to the larger) — what the
        adaptive flush policies treat as 'a full wave'; 0 before any wave
        has completed (policies then behave eagerly)."""
        qh = self.stats["queue_hist"]
        if not qh:
            return 0
        return max(qh, key=lambda k: (qh[k], k))

    # -- AOT lowering (ONE recipe shared by warmup and ladder retune, so
    # the cache keys the _launch lookup probes are spelled out once) ------
    def aot_ref(self, bucket: int, parents: Sequence[Any]) -> None:
        """Pre-compile the indexed-gather + contiguous-prefix programs for
        one bucket over one parent set (ShapeDtypeStructs)."""
        pk = tuple(tuple(p.shape) for p in parents)
        if ("gather", bucket, pk) not in self.compiled:
            idx = jax.ShapeDtypeStruct((bucket,), jnp.int32)
            self.compiled[("gather", bucket, pk)] = jax.jit(
                self._apply_gathered).lower(idx, *parents).compile()
        if ("prefix_aot", bucket, pk) not in self.compiled:
            start = jax.ShapeDtypeStruct((), jnp.int32)
            self.compiled[("prefix_aot", bucket, pk)] = jax.jit(
                partial(self._apply_ring_prefix, bucket)).lower(
                    start, *parents).compile()

    def aot_ring(self, bucket: int, ring_specs: Sequence[Any]) -> None:
        """Pre-compile the slot-ring prefix program for one bucket."""
        if ("ring", bucket) not in self.compiled:
            start = jax.ShapeDtypeStruct((), jnp.int32)
            self.compiled[("ring", bucket)] = jax.jit(
                partial(self._apply_ring_prefix, bucket)).lower(
                    start, *ring_specs).compile()

    def reset_compiled(self) -> None:
        """Drop every compiled program AND recreate the shared jit
        wrappers.  Needed when the inner chunk changes after compilation
        (a retune-time re-sweep): every cached trace baked the old chunk,
        and the shared wrappers' per-shape jit caches would silently keep
        serving it."""
        self.compiled.clear()
        self.host_jit = jax.jit(self._apply_host,
                                donate_argnums=(0,) if self._donate else ())
        self.gather_jit = jax.jit(self._apply_gathered)


class AggregationExecutor:
    """Aggregates submissions of *kernel families* into bucketed launches.

    A registry of aggregation regions keyed by :class:`TaskSignature` lets
    tasks of different kernels AND different shapes coexist: each family
    gets its own slot ring, queue and compiled buckets, while the launch
    policy, executor pool and statistics are shared.  ``flush`` drains the
    live regions round-robin, so families interleave on the device instead
    of serializing.

    Parameters
    ----------
    batched_fn : callable, optional
        ``batched_fn(*stacked_args) -> stacked_out`` where every arg/out has
        a leading slot axis.  Registered as the default kernel family under
        ``name``; further families via :meth:`register`.  The body is one
        traced function shared by all its aggregated tasks (SGMT by
        construction), and serves every task shape submitted to it (each
        distinct shape opens its own region over the same body).
    config : AggregationConfig
        ``max_aggregated`` caps the bucket size (the paper's second launch
        criterion); ``n_executors`` sizes the underlying executor pool
        (combining strategy 3 with strategy 2, as the paper's best rows do);
        ``staging`` selects device-resident (slot ring / indexed gather) or
        the seed's host staging.
    """

    def __init__(self, batched_fn: Optional[Callable] = None,
                 config: Optional[AggregationConfig] = None,
                 pool: Optional[ExecutorPool] = None,
                 buffer_pool: Optional[BufferPool] = None,
                 donate: bool = False,
                 name: str = "region"):
        self.name = name
        self.config = config or AggregationConfig()
        self.pool = pool or ExecutorPool(self.config.n_executors)
        self.buffers = buffer_pool or DEFAULT_POOL
        self._buckets = tuple(sorted(self.config.bucket_sizes()))
        self._donate = donate
        ic = getattr(self.config, "inner_chunk", 0)
        self._chunk = int(ic) if ic != "auto" else 0   # "auto": set at warmup
        self._chunk_auto = ic == "auto"
        self._staging = getattr(self.config, "staging", "device")
        if self._staging not in ("device", "host"):
            raise ValueError(f"unknown staging mode {self._staging!r}")
        self._flush_policy = getattr(self.config, "flush_policy", "eager")
        if self._flush_policy not in ("eager", "watermark", "cost"):
            raise ValueError(
                f"unknown flush_policy {self._flush_policy!r} — valid "
                f"policies: eager, watermark, cost")
        self._cost_on = bool(getattr(self.config, "cost_model", False))
        self._cost_samples = max(1, int(getattr(self.config,
                                                "cost_samples", 3)))
        self._bodies: Dict[str, Callable] = {}
        self._regions: Dict[TaskSignature, _Region] = {}
        self._default_kernel: Optional[str] = None
        # per-kernel routing cache for SlotView waves: kernel -> (parents,
        # sig).  A wave's submissions share one parent set per family, so
        # identity-comparing the parents skips the per-task signature
        # rebuild on the hot path — keyed per kernel so interleaved
        # multi-family waves (e.g. hydro + gravity) don't thrash it.
        self._sig_cache: Dict[str, Tuple[Tuple[Any, ...], TaskSignature]] = {}
        # statistics for the benchmark tables; per-family bucket histograms
        # live under "regions" (the multi-signature observability surface)
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {},
                      "staging_s": 0.0, "regions": {},
                      "flush_policy": self._flush_policy}
        if batched_fn is not None:
            self.register(name, batched_fn)

    # -- region registry ---------------------------------------------------
    def register(self, kernel: str, batched_fn: Callable,
                 default: bool = False) -> str:
        """Register a kernel family's batched body.  The first registration
        (or ``default=True``) becomes the default for untagged submissions.
        Regions themselves are opened lazily, one per task signature."""
        if kernel in self._bodies and self._bodies[kernel] is not batched_fn:
            raise ValueError(
                f"kernel {kernel!r} already registered with a different body")
        self._bodies[kernel] = batched_fn
        if default or self._default_kernel is None:
            self._default_kernel = kernel
        return kernel

    def _region_for(self, kernel: str, args: Sequence[Any]) -> _Region:
        sig = TaskSignature.from_args(kernel, args)
        region = self._regions.get(sig)
        if region is None:
            body = self._bodies.get(kernel)
            if body is None:
                raise KeyError(f"no batched body registered for kernel "
                               f"{kernel!r} (have {sorted(self._bodies)})")
            region = _Region(sig, body, self._donate, buckets=self._buckets,
                             chunk=self._chunk)
            self._regions[sig] = region
            self.stats["regions"][sig.describe()] = region.stats
        return region

    def _region_for_views(self, kernel: str,
                          views: Sequence[SlotView]) -> _Region:
        """Region routing for all-SlotView submissions, cached on the
        parent-set identity (strong refs keep ids valid)."""
        parents = tuple(v.parent for v in views)
        c = self._sig_cache.get(kernel)
        if (c is not None and len(c[0]) == len(parents)
                and all(a is b for a, b in zip(c[0], parents))):
            region = self._regions.get(c[1])
            if region is not None:
                return region
        region = self._region_for(kernel, views)
        self._sig_cache[kernel] = (parents, region.signature)
        return region

    def _resolve_kernel(self, kernel: Optional[str]) -> str:
        kernel = kernel or self._default_kernel
        if kernel is None:
            raise RuntimeError("no kernel family registered — pass "
                               "batched_fn to the constructor or register()")
        return kernel

    @property
    def regions(self) -> Dict[TaskSignature, "_Region"]:
        """Live region registry (read-only view)."""
        return dict(self._regions)

    # -- single-region compatibility views --------------------------------
    def _sole_region(self) -> Optional[_Region]:
        if len(self._regions) == 1:
            return next(iter(self._regions.values()))
        return None

    @property
    def ring(self) -> Optional[SlotRing]:
        region = self._sole_region()
        return region.ring if region is not None else None

    @property
    def _queue(self) -> List[_Pending]:
        out: List[_Pending] = []
        for region in self._regions.values():
            out.extend(region.queue)
        return out

    @property
    def _compiled(self) -> Mapping[Tuple, Callable]:
        """Read-only view of the compiled-program caches (merged across
        regions); write through ``region.compiled`` instead — a write to
        this view would silently vanish in the multi-region case."""
        region = self._sole_region()
        if region is not None:
            return MappingProxyType(region.compiled)
        merged: Dict[Tuple, Callable] = {}
        for region in self._regions.values():
            merged.update(region.compiled)
        return MappingProxyType(merged)

    # -- warmup ------------------------------------------------------------
    def warmup(self, example_args: Optional[Tuple[Any, ...]] = None, *,
               kernel: Optional[str] = None,
               parent_shapes: Optional[Sequence[Any]] = None,
               buckets: Optional[Sequence[int]] = None) -> None:
        """AOT pre-compile every bucket size (amortized startup, like stream
        pre-allocation in CPPuddle).

        Buckets are lowered with ``.lower().compile()`` — no example
        execution, no broadcast staging, and no tracer hit on the first
        real submission.  Two modes, combinable:

        * ``example_args`` — per-task example inputs; pre-compiles the slot
          ring (device staging) or host-stacked (host staging) buckets.
        * ``parent_shapes`` — shapes/dtypes of the parent arrays that
          ``submit_indexed``/``submit_range`` will reference (arrays or
          ShapeDtypeStructs); pre-compiles the indexed-gather AND
          contiguous-prefix programs those submissions hit, closing the
          gather-mode warmup gap (DESIGN.md §6 -> §7).

        ``buckets`` restricts which ladder buckets are AOT-compiled (e.g.
        just the steady wave's greedy decomposition — the caller's compile
        budget); default is the region's whole ladder.  Un-warmed buckets
        still compile lazily on first use.
        """
        kernel = self._resolve_kernel(kernel)

        def aot_buckets(region):
            return region.buckets if buckets is None else tuple(buckets)

        if parent_shapes is not None:
            parents = tuple(jax.ShapeDtypeStruct(tuple(p.shape), p.dtype)
                            for p in parent_shapes)
            task_specs = tuple(jax.ShapeDtypeStruct(p.shape[1:], p.dtype)
                               for p in parents)
            region = self._region_for(kernel, task_specs)
            pk = tuple(tuple(p.shape) for p in parents)
            region._aot_parents[pk] = parents    # retune re-AOTs from these
            if self._chunk_auto and not region.chunk_tuned:
                self._tune_chunk(region, parents)
            n_parent = min(p.shape[0] for p in parents)
            for b in (b for b in aot_buckets(region) if b <= n_parent):
                region.aot_ref(b, parents)
            if self._cost_on:
                self._measure_region(region, aot_buckets(region),
                                     parents=parents)
            if example_args is None:
                return
        if example_args is None:
            raise ValueError("warmup needs example_args and/or parent_shapes")
        region = self._region_for(kernel, example_args)
        specs = [jax.ShapeDtypeStruct(tuple(np.shape(a)),
                                      getattr(a, "dtype", None)
                                      or jnp.asarray(a).dtype)
                 for a in example_args]
        if self._chunk_auto and not region.chunk_tuned:
            # ring/host-staged regions tune too: a pseudo-parent of the
            # largest bucket's stacked shape drives the same measurement
            pseudo = tuple(jax.ShapeDtypeStruct(
                (max(region.buckets),) + s.shape, s.dtype) for s in specs)
            self._tune_chunk(region, pseudo)
        if self._staging == "device":
            ring = region.ensure_ring(self.config.max_aggregated,
                                      example_args)
            ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                          for r in ring.buffers()]
            for b in aot_buckets(region):
                region.aot_ring(b, ring_specs)
            if self._cost_on:
                self._measure_region(region, aot_buckets(region),
                                     ring_specs=ring_specs)
        else:
            for b in aot_buckets(region):
                stacked = tuple(
                    jax.ShapeDtypeStruct((b,) + s.shape, s.dtype)
                    for s in specs)
                region.compiled[("host", b)] = region.host_jit.lower(
                    *stacked).compile()

    def _tune_chunk(self, region: _Region, parents: Sequence[Any],
                    force: bool = False) -> None:
        """``inner_chunk="auto"``: pick the region's mega-bucket chunk by
        timing the body on its largest bucket over candidate chunk sizes
        (0 = flat, then powers of two).  Runs once per region at warmup,
        before any bucket program is compiled, so every compiled program
        sees the chosen chunk; under ``cost_model=True`` the retune pass
        re-runs it with ``force=True`` (DESIGN.md §10 — the sweep follows
        the ladder to whatever bucket the tuner actually converged on,
        superseding the §9 warmup-only choice).  This is a measurement,
        not a lowering — tuning executes a handful of zero-filled buckets.
        Results are memoized per (backend+device kind, body, bucket
        shape), so re-tuning the same family in another executor (a
        benchmark sweep) is free, while a choice timed on one backend can
        never leak into another; ``force`` bypasses the memo read and
        overwrites the entry."""
        n_parent = min(p.shape[0] for p in parents)
        b = max((x for x in region.buckets if x <= n_parent), default=0)
        if b < 2:
            return
        key = (_backend_key(), id(region.batched_fn), b,
               tuple((tuple(p.shape[1:]), str(p.dtype)) for p in parents))
        memo = _CHUNK_TUNE_MEMO.get(key)
        if memo is not None and not force:
            region.chunk = memo[1]
            region.chunk_tuned = True
            region.stats["inner_chunk"] = memo[1]
            return
        stacked = tuple(jnp.zeros((b,) + tuple(p.shape[1:]), p.dtype)
                        for p in parents)
        best_chunk, best_t = 0, float("inf")
        for c in (0, 2, 4, 8):
            if c >= b or (c and b % c):
                continue
            fn = jax.jit(partial(_chunked_eval, region.batched_fn, c))
            try:
                jax.block_until_ready(fn(*stacked))    # compile + warm
            except Exception:
                continue                               # body rejects chunking
            # min-of-3 guards the choice against scheduler hiccups — the
            # memo pins it process-wide, so one noisy sample must not
            # lock in a pessimal chunk (~3.5x between best and worst here)
            t = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*stacked))
                t = min(t, time.perf_counter() - t0)
            if t < best_t:
                best_chunk, best_t = c, t
        # the memo holds a ref to the body so id() stays valid for the key
        while len(_CHUNK_TUNE_MEMO) >= _CHUNK_TUNE_MEMO_MAX:
            _CHUNK_TUNE_MEMO.pop(next(iter(_CHUNK_TUNE_MEMO)))
        _CHUNK_TUNE_MEMO[key] = (region.batched_fn, best_chunk)
        region.chunk = best_chunk
        region.chunk_tuned = True
        region.stats["inner_chunk"] = best_chunk

    # -- bucket cost measurement (DESIGN.md §10) ---------------------------
    def _measure_region(self, region: _Region, buckets: Sequence[int],
                        parents: Optional[Sequence[Any]] = None,
                        ring_specs: Optional[Sequence[Any]] = None) -> None:
        """Time each bucket's compiled program on zero-filled inputs into
        the region's :class:`BucketCostModel`: one warm call, then the
        median of ``cost_samples`` timed runs.  Ref-staged regions time
        the contiguous-prefix program (the steady bulk-submission fast
        path — gather-by-index costs the same body plus one take);
        ring-staged regions time the ring-prefix program.  Buckets that
        already have samples are skipped, so repeated warmups are free;
        a chunk re-sweep clears the model first (old timings described
        programs that no longer exist).  Host staging is never measured —
        it is the seed baseline, not a tuned hot path."""
        if parents is not None:
            concrete = tuple(jnp.zeros(tuple(p.shape), p.dtype)
                             for p in parents)

            def program(b):
                region.aot_ref(b, parents)
                pk = tuple(tuple(p.shape) for p in parents)
                return region.compiled[("prefix_aot", b, pk)]
        elif ring_specs is not None:
            concrete = tuple(jnp.zeros(tuple(r.shape), r.dtype)
                             for r in ring_specs)

            def program(b):
                region.aot_ring(b, ring_specs)
                return region.compiled[("ring", b)]
        else:
            return
        n_slots = min(c.shape[0] for c in concrete)
        start = jnp.int32(0)
        for b in sorted(set(buckets)):
            if b > n_slots or region.cost.time(b) is not None:
                continue
            fn = program(b)
            jax.block_until_ready(fn(start, *concrete))        # warm call
            for _ in range(self._cost_samples):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(start, *concrete))
                region.cost.record(b, time.perf_counter() - t0)
        if region.cost.measured():
            region.stats["cost_model"] = region.cost.as_stats()

    # -- submission API ----------------------------------------------------
    def submit(self, *args, kernel: Optional[str] = None) -> TaskFuture:
        """Queue one task, routed to its signature's region.  Args are
        either concrete per-task arrays (staged into the region's slot ring)
        or all :class:`SlotView` references (staged by a single gather at
        launch)."""
        kernel = self._resolve_kernel(kernel)
        fut = TaskFuture()
        is_ref = bool(args) and all(isinstance(a, SlotView) for a in args)
        if is_ref and self._staging == "device":
            region = self._region_for_views(kernel, args)
            if any(v.index != args[0].index for v in args[1:]):
                raise ValueError(
                    "SlotView args of one task must share one index — a "
                    "launch gathers the SAME slot from every parent "
                    "(use submit_indexed)")
            entry = _Pending(future=fut, views=tuple(args))
        elif self._staging == "host" or not args:
            region = self._region_for(kernel, args)
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            entry = _Pending(future=fut, args=args)
        else:
            region = self._region_for(kernel, args)
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            t0 = time.perf_counter()
            ring = region.ensure_ring(self.config.max_aggregated, args)
            if ring.fill >= ring.capacity:
                # watermark remainders left a partial prefix consumed; slide
                # the live tail to the front (one fused device op)
                first = region.queue[0].slot if region.queue else ring.fill
                ring.compact(first)
                for p in region.queue:
                    p.slot -= first
            entry = _Pending(future=fut, slot=ring.write(args))
            self.stats["staging_s"] += time.perf_counter() - t0
        self._enqueue(region, entry)
        return fut

    def submit_range(self, parents: Tuple[jax.Array, ...], start: int,
                     n: int, kernel: Optional[str] = None) -> RangeFuture:
        """Bulk submission: enqueue tasks ``start .. start+n-1`` of a parent
        set as ONE queue entry backed by ONE :class:`RangeFuture`.

        Replaces n ``submit_indexed`` calls (n ``TaskFuture`` allocations, n
        signature routings, n queue appends) with one of each — the
        submission loop stops being a per-task Python cost.  The range may
        still drain across several bucketed launches (greedy, in order);
        ``result()``/``gather_futures`` reassemble it, zero-copy in the
        steady one-launch case.  Launch criteria see all n tasks at once, so
        a full wave triggers its mega-bucket immediately on submission.
        """
        if n <= 0:
            raise ValueError(f"submit_range needs n >= 1, got {n}")
        if self._staging != "device":
            raise ValueError(
                "submit_range requires device staging — ranges reference "
                "device-resident parents by slot index (use per-task "
                "submit() under staging='host')")
        kernel = self._resolve_kernel(kernel)
        n_parent = min(p.shape[0] for p in parents)
        if start < 0 or start + n > n_parent:
            # XLA's dynamic_slice/take CLAMP out-of-bounds indices instead
            # of failing — an unchecked range would silently return data
            # from the wrong slots
            raise ValueError(
                f"range [{start}, {start + n}) out of bounds for parents "
                f"with {n_parent} slots")
        views = tuple(SlotView(p, start) for p in parents)
        region = self._region_for_views(kernel, views)
        fut = RangeFuture(n)
        entry = _Pending(future=fut, views=views, count=n)
        self._enqueue(region, entry)
        return fut

    def _enqueue(self, region: _Region, entry: _Pending) -> None:
        self._check_mode(region, entry)
        region.queue.append(entry)
        region.queued_tasks += entry.count
        region._wave_peak = max(region._wave_peak, region.queued_tasks)
        self.stats["submitted"] += entry.count
        region.stats["submitted"] += entry.count
        self._maybe_launch()

    def submit_indexed(self, parents: Tuple[jax.Array, ...], index: int,
                       kernel: Optional[str] = None) -> TaskFuture:
        """Sugar: submit task ``i`` whose j-th arg is ``parents[j][i]``."""
        return self.submit(*(SlotView(p, index) for p in parents),
                           kernel=kernel)

    def _check_mode(self, region: _Region, entry: _Pending) -> None:
        """A bucket must stage uniformly: same mode, and for ref entries the
        same parent arrays (a launch gathers from ONE parent set).  Launch
        the region's queue before admitting an incompatible entry."""
        if not region.queue:
            return
        head = region.queue[0]
        compatible = self._entry_mode(head) == self._entry_mode(entry)
        if compatible and entry.views is not None:
            compatible = all(a.parent is b.parent
                             for a, b in zip(head.views, entry.views))
        if not compatible:
            while region.queue:
                self._launch(region,
                             self._largest_bucket(region,
                                                  region.queued_tasks))

    @staticmethod
    def _entry_mode(entry: _Pending) -> str:
        if entry.views is not None:
            return "ref"
        if entry.args is not None:
            return "host"
        return "ring"

    def _maybe_launch(self) -> None:
        """The paper's launch policy, per region: launch when (a) the cap is
        reached, or (b) an underlying executor is idle AND the flush policy
        agrees that draining the partial queue now beats waiting for a
        fuller bucket; otherwise keep aggregating.  Regions progress
        independently — a full family never stalls behind another family's
        partial queue."""
        progress = True
        while progress:
            progress = False
            for region in self._regions.values():
                q = region.queued_tasks
                if q >= self.config.max_aggregated:
                    self._launch(region,
                                 self._largest_bucket(
                                     region, self.config.max_aggregated))
                    progress = True
                elif (q >= self.config.launch_watermark
                      and self.pool.any_idle()
                      and self._idle_drain_pays(region, q)):
                    self._launch(region, self._largest_bucket(region, q))
                    progress = True

    def _idle_drain_pays(self, region: _Region, q: int) -> bool:
        """The watermark-adaptive flush decision (DESIGN.md §10): should a
        partial queue of ``q`` tasks drain into an idle executor, or keep
        aggregating toward the region's typical wave?

        * ``eager`` — always drain (the §4 policy, and the fallback of the
          adaptive policies until a wave peak / cost model exists);
        * ``watermark`` — drain only at/after the *learned* wave peak, so
          partial buckets stop leaking once the steady wave size is known;
        * ``cost`` — drain early only when the measured model predicts the
          split drain (q now + the remainder later) to be no slower than
          waiting and draining the full wave in one greedy pass — i.e.
          exactly when the big bucket's measured cost is superlinear
          enough that splitting it is free.
        """
        if self._flush_policy == "eager":
            return True
        peak = region.expected_peak()
        if not peak or q >= peak:
            return True               # no history yet, or a full wave: go
        if self._flush_policy == "watermark":
            return False
        if not region.cost.measured():
            return True               # "cost" without a model: eager
        split = (region.cost.predict_seq(
                     greedy_decomposition(q, region.buckets))
                 + region.cost.predict_seq(
                     greedy_decomposition(peak - q, region.buckets)))
        full = region.cost.predict_seq(
            greedy_decomposition(peak, region.buckets))
        return split <= full

    @staticmethod
    def _largest_bucket(region: _Region, k: int) -> int:
        best = region.buckets[0]
        for b in region.buckets:
            if b <= k:
                best = b
        if best > k:
            raise RuntimeError(
                f"bucket {best} exceeds queue length {k} — ladder "
                f"{region.buckets} lacks a remainder bucket (validate_ladder "
                f"should have rejected it)")
        return best

    def _take(self, region: _Region, k: int) -> List[_Pending]:
        """Pop k tasks' worth of entries off the queue, splitting a range
        entry at the bucket boundary (both halves share the RangeFuture)."""
        taken: List[_Pending] = []
        need = k
        while need:
            e = region.queue[0]
            if e.count <= need:
                taken.append(region.queue.pop(0))
                need -= e.count
            else:
                head, tail = e.split(need)
                region.queue[0] = tail
                taken.append(head)
                need = 0
        region.queued_tasks -= k
        return taken

    def _launch(self, region: _Region, k: int) -> None:
        tasks = self._take(region, k)
        mode = self._entry_mode(tasks[0])
        t0 = time.perf_counter()
        if mode == "ref":
            indices: List[int] = []
            for t in tasks:
                i0 = t.views[0].index
                indices.extend(range(i0, i0 + t.count))
            parents = tuple(v.parent for v in tasks[0].views)
            pk = tuple(tuple(p.shape) for p in parents)
            if pk not in region._aot_parents:    # remember for retune AOT
                region._aot_parents[pk] = tuple(
                    jax.ShapeDtypeStruct(tuple(p.shape), p.dtype)
                    for p in parents)
            if indices == list(range(indices[0], indices[0] + k)):
                # contiguous slot run: one dynamic slice of the parent (the
                # parent IS the ring) — no gather, no index array
                fn = (region.compiled.get(("prefix_aot", k, pk))
                      or region.compiled_for(k, "prefix"))
                call_args = (jnp.int32(indices[0]),) + parents
            else:
                idx = jnp.asarray(indices, jnp.int32)
                fn = (region.compiled.get(("gather", k, pk))
                      or region.gather_jit)
                call_args = (idx,) + parents
        elif mode == "ring":
            fn = region.compiled_for(k, "ring")
            call_args = (jnp.int32(tasks[0].slot),) + region.ring.buffers()
        else:
            stacked = []
            for j in range(len(tasks[0].args)):
                parts = [t.args[j] for t in tasks]
                if k == 1:
                    stacked.append(jnp.asarray(parts[0])[None])
                elif isinstance(parts[0], jax.Array):
                    stacked.append(jnp.stack(parts))
                else:
                    stacked.append(jnp.asarray(self.buffers.stage(parts)))
            fn = region.compiled.get(("host", k), region.host_jit)
            call_args = tuple(stacked)
        self.stats["staging_s"] += time.perf_counter() - t0
        exe = self.pool.get()
        out = exe.launch(fn, *call_args, family=region.signature.kernel)
        slot = 0
        for t in tasks:
            if isinstance(t.future, RangeFuture):
                t.future._fulfil_range(out, slot, t.fut_offset, t.count)
            else:
                t.future._fulfil(out, slot)
            slot += t.count
        if mode == "ring" and not region.queue:
            region.ring.swap()    # in-flight launch keeps the old buffer
        self.stats["launches"] += 1
        hist = self.stats["aggregated_hist"]
        hist[k] = hist.get(k, 0) + 1
        region.stats["launches"] += 1
        rhist = region.stats["aggregated_hist"]
        rhist[k] = rhist.get(k, 0) + 1
        if not region.queue:
            self._wave_complete(region)

    # -- ladder auto-tuning ------------------------------------------------
    def _wave_complete(self, region: _Region) -> None:
        """A wave ended (queue drained to zero): record its peak queue
        length and, past the warmup, re-derive the region's ladder."""
        peak = region._wave_peak
        if peak:
            qh = region.stats["queue_hist"]
            qh[peak] = qh.get(peak, 0) + 1
            region.waves += 1
            region._wave_peak = 0
            if region.tuned and peak > region._retuned_peak:
                # the workload outgrew anything the last retune SAW (e.g.
                # warmup saw only watermark-drained micro-waves, then a
                # bulk range arrived): re-arm the tuner instead of pinning
                # the small ladder forever.  The trigger is new EVIDENCE
                # (a peak beyond the tuned histogram), never the ladder
                # shape — a measured tuner may legitimately pick a ladder
                # whose max bucket is below the wave (splitting predicted
                # faster), and comparing against max(buckets) would then
                # re-arm, and re-tune, on every single wave
                region.tuned = False
        if (self.config.autotune and not region.tuned
                and region.waves >= self.config.autotune_warmup):
            self._retune_region(region)

    def _retune_region(self, region: _Region) -> None:
        """Swap in the ladder minimizing the per-wave objective — expected
        launches, or predicted wall time under ``cost_model=True`` — and
        AOT-compile the new buckets for every parent set seen, as the AMR
        follow-up work does once launch overhead stops dominating.

        The measured path (DESIGN.md §10) runs three extra steps first:
        re-sweep ``inner_chunk="auto"`` against the current backend (a
        chunk change invalidates every compiled program AND every cost
        sample — both are rebuilt), then time every drain-reachable
        candidate bucket (:func:`ladder_candidates`), then hand the model
        to :func:`derive_ladder`.  Candidate measurement compiles more
        programs than ``compile_budget`` — the budget bounds the ladder
        the steady state keeps, not what the tuner is allowed to probe.
        """
        region._retuned_waves = region.waves
        region._retuned_peak = max(
            (k for k in region.stats["queue_hist"] if k > 0), default=0)
        chunk_changed = False
        cost_model = None
        if self._cost_on:
            chunk_changed = self._resweep_chunk(region)
            cost_model = self._measure_candidates(region)
        ladder = derive_ladder(region.stats["queue_hist"],
                               self.config.max_aggregated,
                               self.config.compile_budget, cost_model)
        region.tuned = True
        region.stats["tuned_by"] = ("cost_model" if cost_model is not None
                                    else "launches")
        if ladder == region.buckets and not chunk_changed:
            return
        region.buckets = ladder
        region.stats["ladder"] = list(ladder)
        # AOT only the buckets the observed waves will actually drain
        # through under the new ladder (the compile budget, honored)
        used = set()
        for k in region.stats["queue_hist"]:
            used.update(greedy_decomposition(k, ladder))
        if region.ring is not None:       # ring-staged regions retune too
            ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                          for r in region.ring.buffers()]
            for b in sorted(used):
                region.aot_ring(b, ring_specs)
        # (host staging keeps lazy per-shape jit — it is the measurable
        # seed baseline, not a tuned hot path)
        for parents in region._aot_parents.values():
            n_parent = min(p.shape[0] for p in parents)
            for b in (b for b in sorted(used) if b <= n_parent):
                region.aot_ref(b, parents)

    def _resweep_chunk(self, region: _Region) -> bool:
        """Retune-time ``inner_chunk="auto"`` re-sweep (supersedes the §9
        warmup-only choice): re-time the chunk candidates on the current
        backend, bypassing the memo.  Returns True when the chunk changed
        — the caller must then treat every compiled program and cost
        sample as stale (this method already resets both)."""
        if not self._chunk_auto:
            return False
        parents = self._primary_parents(region)
        if parents is None:
            return False
        old = region.chunk
        self._tune_chunk(region, parents, force=True)
        if region.chunk == old:
            return False
        region.reset_compiled()
        region.cost.clear()
        region.stats.pop("cost_model", None)
        return True

    @staticmethod
    def _primary_parents(region: _Region) -> Optional[Tuple[Any, ...]]:
        """The parent set measurements run against: the deepest one seen
        (biggest buckets fit), falling back to the ring's buffers."""
        best = None
        for parents in region._aot_parents.values():
            n = min(p.shape[0] for p in parents)
            if best is None or n > best[0]:
                best = (n, parents)
        if best is not None:
            return best[1]
        if region.ring is not None:
            return tuple(jax.ShapeDtypeStruct(r.shape, r.dtype)
                         for r in region.ring.buffers())
        return None

    def _measure_candidates(self, region: _Region
                            ) -> Optional[BucketCostModel]:
        """Time every drain-reachable candidate bucket for the region's
        observed waves (already-measured buckets are free), returning the
        model — or None when nothing could be measured (e.g. a host-staged
        region, which the cost path then treats as launch-count tuning)."""
        cands = sorted(ladder_candidates(region.stats["queue_hist"],
                                         self.config.max_aggregated))
        for parents in region._aot_parents.values():
            self._measure_region(region, cands, parents=parents)
        if region.ring is not None:
            ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                          for r in region.ring.buffers()]
            self._measure_region(region, cands, ring_specs=ring_specs)
        return region.cost if region.cost.measured() else None

    def retune(self) -> Dict[str, Tuple[int, ...]]:
        """Force a ladder retune of every region that has completed at
        least one NEW wave since its last retune; returns the ladders by
        family.  A region with an empty queue histogram — or none recorded
        since the last retune — is left untouched: re-deriving from no
        (new) evidence would only produce a degenerate ``(1,)`` ladder or
        burn AOT work reproducing the current one."""
        out = {}
        for region in self._regions.values():
            if (region.stats["queue_hist"]
                    and region.waves != region._retuned_waves):
                region.tuned = False
                self._retune_region(region)
            out[region.signature.describe()] = region.buckets
        return out

    def flush(self) -> None:
        """Launch everything still queued (greedy buckets) and drain.
        Live regions are drained round-robin — one launch per family per
        pass — so interleaved families pipeline on the device."""
        live = [r for r in self._regions.values() if r.queue]
        while live:
            for region in live:
                if region.queue:
                    self._launch(region,
                                 self._largest_bucket(region,
                                                      region.queued_tasks))
            live = [r for r in live if r.queue]
        self.pool.drain()
        # the routing cache holds strong refs to the last wave's parent
        # arrays; the wave is over, release them (next wave re-primes)
        self._sig_cache.clear()

    def map(self, task_args: Sequence[Tuple[Any, ...]],
            kernel: Optional[str] = None) -> List[Any]:
        """Submit many tasks, flush, return their results in order."""
        futs = [self.submit(*a, kernel=kernel) for a in task_args]
        self.flush()
        return [f.result() for f in futs]


# ---------------------------------------------------------------------------
# Region API — the paper's "aggregation region" (a marked code region that
# compatible tasks may enter together).  Cosmetic sugar over the executor.
# ---------------------------------------------------------------------------

_REGIONS: Dict[str, AggregationExecutor] = {}


def aggregation_region(name: str, batched_fn: Callable,
                       config: Optional[AggregationConfig] = None,
                       **kw) -> AggregationExecutor:
    """Get-or-create the named region's executor (one Executor Pool per
    aggregation region, as in the paper's CPPuddle implementation)."""
    exe = _REGIONS.get(name)
    if exe is None:
        exe = AggregationExecutor(batched_fn, config or AggregationConfig(),
                                  name=name, **kw)
        _REGIONS[name] = exe
    return exe


def reset_regions() -> None:
    _REGIONS.clear()
