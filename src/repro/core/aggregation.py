"""The paper's strategy 3: on-the-fly explicit work aggregation, TPU-native.

Fine-grained tasks submit "launch kernel K on my inputs" requests.  While the
underlying executor is busy, compatible submissions accumulate; when it
becomes idle — or the ``max_aggregated`` cap is reached — the queued tasks
are fused into ONE batched kernel launch over a slot axis.  Each task gets a
future resolving to its slot of the batched output.

TPU adaptation (DESIGN.md §2): XLA requires static shapes, so a dynamic
aggregation count is realized as a small set of pre-compiled *buckets*
(powers of two up to the cap).  A queue of length k is drained greedily with
the largest bucket <= k; because bucket 1 exists, no padding is ever needed
and results are *bit-identical* to unaggregated execution (the equivalence
invariant tested in tests/test_aggregation.py).

The paper's "Single-GPU-workload-Multiple-Tasks" constraint (all aggregated
tasks execute the same allocation/launch sequence) is enforced *statically*
here: the bucketed kernel is one traced function extended over the slot axis,
so divergence between aggregated tasks is impossible by construction.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AggregationConfig
from repro.core.buffers import DEFAULT_POOL, BufferPool
from repro.core.executor import DeviceExecutor, ExecutorPool


class TaskFuture:
    """HPX-future analogue: resolves to one task's slice of a batched launch."""

    __slots__ = ("_value", "_batch", "_slot", "_done")

    def __init__(self):
        self._value = None
        self._batch = None
        self._slot = -1
        self._done = False

    def _fulfil(self, batch_out: Any, slot: int) -> None:
        self._batch, self._slot, self._done = batch_out, slot, True

    def ready(self) -> bool:
        return self._done

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if self._value is None:
            slot = self._slot
            self._value = jax.tree_util.tree_map(lambda x: x[slot], self._batch)
            self._batch = None
        return self._value


@dataclass
class _Pending:
    args: Tuple[Any, ...]
    future: TaskFuture


class AggregationExecutor:
    """Aggregates submissions of one *kernel family* into bucketed launches.

    Parameters
    ----------
    batched_fn : callable
        ``batched_fn(*stacked_args) -> stacked_out`` where every arg/out has
        a leading slot axis.  This is the "aggregation region" body: one
        traced function shared by all aggregated tasks (SGMT by construction).
    config : AggregationConfig
        ``max_aggregated`` caps the bucket size (the paper's second launch
        criterion); ``n_executors`` sizes the underlying executor pool
        (combining strategy 3 with strategy 2, as the paper's best rows do).
    """

    def __init__(self, batched_fn: Callable, config: AggregationConfig,
                 pool: Optional[ExecutorPool] = None,
                 buffer_pool: Optional[BufferPool] = None,
                 donate: bool = False,
                 name: str = "region"):
        self.name = name
        self.config = config
        self.pool = pool or ExecutorPool(config.n_executors)
        self.buffers = buffer_pool or DEFAULT_POOL
        self._queue: List[_Pending] = []
        self._buckets = tuple(sorted(config.bucket_sizes()))
        self._compiled: Dict[int, Callable] = {}
        self._batched_fn = batched_fn
        self._donate = donate
        # statistics for the benchmark tables
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {}}

    # -- compilation cache (pre-compiling all buckets = CPPuddle's
    #    startup-time executor allocation; lazy by default) ---------------
    def compiled_for(self, bucket: int) -> Callable:
        fn = self._compiled.get(bucket)
        if fn is None:
            fn = jax.jit(self._batched_fn,
                         donate_argnums=(0,) if self._donate else ())
            self._compiled[bucket] = fn
        return fn

    def warmup(self, example_args: Tuple[Any, ...]) -> None:
        """Pre-compile every bucket size (amortized startup, like stream
        pre-allocation in CPPuddle)."""
        for b in self._buckets:
            stacked = tuple(
                jnp.broadcast_to(a[None], (b,) + tuple(np.shape(a)))
                for a in example_args)
            jax.block_until_ready(self.compiled_for(b)(*stacked))

    # -- submission API ---------------------------------------------------
    def submit(self, *args) -> TaskFuture:
        fut = TaskFuture()
        self._queue.append(_Pending(args=args, future=fut))
        self.stats["submitted"] += 1
        self._maybe_launch()
        return fut

    def _maybe_launch(self) -> None:
        """The paper's launch policy: launch when (a) the cap is reached, or
        (b) an underlying executor is idle; otherwise keep aggregating."""
        while self._queue:
            q = len(self._queue)
            if q >= self.config.max_aggregated:
                self._launch(self.config.max_aggregated)
            elif q >= self.config.launch_watermark and self.pool.any_idle():
                self._launch(self._largest_bucket(q))
            else:
                break

    def _largest_bucket(self, k: int) -> int:
        best = self._buckets[0]
        for b in self._buckets:
            if b <= k:
                best = b
        return best

    def _launch(self, k: int) -> None:
        tasks, self._queue = self._queue[:k], self._queue[k:]
        n_args = len(tasks[0].args)
        stacked = []
        for j in range(n_args):
            parts = [t.args[j] for t in tasks]
            if k == 1:
                stacked.append(jnp.asarray(parts[0])[None])
            elif isinstance(parts[0], jax.Array):
                stacked.append(jnp.stack(parts))
            else:
                stacked.append(jnp.asarray(self.buffers.stage(parts)))
        exe = self.pool.get()
        out = exe.launch(self.compiled_for(k), *stacked)
        for slot, t in enumerate(tasks):
            t.future._fulfil(out, slot)
        self.stats["launches"] += 1
        hist = self.stats["aggregated_hist"]
        hist[k] = hist.get(k, 0) + 1

    def flush(self) -> None:
        """Launch everything still queued (greedy buckets) and drain."""
        while self._queue:
            self._launch(self._largest_bucket(len(self._queue)))
        self.pool.drain()

    def map(self, task_args: Sequence[Tuple[Any, ...]]) -> List[Any]:
        """Submit many tasks, flush, return their results in order."""
        futs = [self.submit(*a) for a in task_args]
        self.flush()
        return [f.result() for f in futs]


# ---------------------------------------------------------------------------
# Region API — the paper's "aggregation region" (a marked code region that
# compatible tasks may enter together).  Cosmetic sugar over the executor.
# ---------------------------------------------------------------------------

_REGIONS: Dict[str, AggregationExecutor] = {}


def aggregation_region(name: str, batched_fn: Callable,
                       config: Optional[AggregationConfig] = None,
                       **kw) -> AggregationExecutor:
    """Get-or-create the named region's executor (one Executor Pool per
    aggregation region, as in the paper's CPPuddle implementation)."""
    exe = _REGIONS.get(name)
    if exe is None:
        exe = AggregationExecutor(batched_fn, config or AggregationConfig(),
                                  name=name, **kw)
        _REGIONS[name] = exe
    return exe


def reset_regions() -> None:
    _REGIONS.clear()
