"""The paper's strategy 3: on-the-fly explicit work aggregation, TPU-native.

Fine-grained tasks submit "launch kernel K on my inputs" requests.  While the
underlying executor is busy, compatible submissions accumulate; when it
becomes idle — or the ``max_aggregated`` cap is reached — the queued tasks
are fused into ONE batched kernel launch over a slot axis.  Each task gets a
future resolving to its slot of the batched output.

Multi-region runtime (DESIGN.md §7): one executor hosts MANY aggregation
regions at once.  Submissions are routed by :class:`TaskSignature` — kernel
id plus per-argument shape/dtype — to their family's slot ring, queue and
compiled-bucket cache, so heterogeneous task populations (the adaptive-
refinement regime of the follow-up AMR work, arXiv:2412.15518) aggregate
concurrently without serializing each other.  A region is created lazily the
first time a signature is seen, which also makes a single registered kernel
shape-polymorphic: new task shapes simply open new regions over the same
body.

TPU adaptation (DESIGN.md §2): XLA requires static shapes, so a dynamic
aggregation count is realized as a small set of pre-compiled *buckets*
(powers of two up to the cap).  A queue of length k is drained greedily with
the largest bucket <= k; because bucket 1 exists, no padding is ever needed
and results are *bit-identical* to unaggregated execution (the equivalence
invariant tested in tests/test_aggregation.py and tests/test_slot_ring.py).

Staging (DESIGN.md §3): the hot path is device-resident end to end.  Task
inputs either

* land in a pre-allocated :class:`~repro.core.buffers.SlotRing` via donated
  coalesced scatters (concrete per-task arrays), or
* stay where they already live and are referenced by a :class:`SlotView`
  ``(parent, index)``; a launch then performs ONE ``jnp.take`` gather inside
  the bucketed program (index-batched staging, zero per-task slicing).

The seed's slice -> host-stack -> launch cycle survives as
``staging="host"`` so benchmarks/launch_overhead.py can measure the win.

The paper's "Single-GPU-workload-Multiple-Tasks" constraint (all aggregated
tasks execute the same allocation/launch sequence) is enforced *statically*
here: each region's bucketed kernel is one traced function extended over the
slot axis, so divergence between aggregated tasks is impossible by
construction.
"""
from __future__ import annotations

import bisect
import statistics
import time
import warnings
from dataclasses import dataclass
from functools import partial
from types import MappingProxyType
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    AggregationConfig, resolve_family_option, validate_ladder,
)
from repro.core.buffers import DEFAULT_POOL, BufferPool, SlotRing
from repro.core.executor import ExecutorPool
from repro.core.faults import (
    BucketCompileError, FaultInjector, LaunchFaultError, QuarantineList,
    RegionFaultError, TaskFailedError, all_finite, all_finite_async,
    poison_slots,
)
from repro.core.tunestore import RooflinePrior, TuneStore, TuneStoreWarning


# inner-chunk auto-tune memo: (backend, body id, bucket, task specs) ->
# (body, chunk).  Keyed on the backend AND device kind because the chunk is
# a *measured* choice — a value timed on one backend must never leak into a
# process that later tunes the same body on another device.  Keeping the
# body ref in the value pins its id() for the key's lifetime (an id-keyed
# entry without the ref would collide on id reuse); the cache is
# FIFO-bounded so long-lived sweeps don't pin every body ever tuned.
_CHUNK_TUNE_MEMO: Dict[Tuple, Tuple[Any, int]] = {}
_CHUNK_TUNE_MEMO_MAX = 32


def _backend_key() -> Tuple[str, str]:
    """(backend, device kind) — the identity a timed tuning choice is valid
    for.  Measured decisions (inner_chunk, bucket costs) are per-device:
    what saturates a TPU-v4 is not what saturates a 2-core CPU."""
    try:
        kind = getattr(jax.devices()[0], "device_kind", "")
    except RuntimeError:
        kind = ""
    return jax.default_backend(), kind


class TaskFuture:
    """HPX-future analogue: resolves to one task's slice of a batched launch.

    Resolution is lazy twice over: ``_fulfil`` only records (batch, slot) —
    no per-slot ``tree_map`` happens until ``result()`` is actually read —
    and callers that want the whole batch back should use
    :func:`gather_futures`, which recognises futures covering a full launch
    and returns the batched output itself with zero copies.

    Under ``guard="finite"`` a future may resolve FAILED instead of to a
    value (DESIGN.md §11): ``failed()`` reports it, ``error()`` carries the
    :class:`~repro.core.faults.TaskFailedError`, and ``result()`` raises it
    — a contained fault never returns garbage.
    """

    __slots__ = ("_value", "_batch", "_slot", "_done", "_error")

    def __init__(self):
        self._value = None
        self._batch = None
        self._slot = -1
        self._done = False
        self._error = None

    def _fulfil(self, batch_out: Any, slot: int) -> None:
        self._batch, self._slot, self._done = batch_out, slot, True

    def _fail(self, err: Exception) -> None:
        self._error, self._done = err, True
        self._batch = self._value = None

    def _retract(self) -> None:
        """Un-fulfil: the launch that fulfilled this future tripped the
        guard; containment will re-fulfil (or fail) it."""
        self._done = False
        self._batch = self._value = None

    def ready(self) -> bool:
        return self._done

    def failed(self) -> bool:
        return self._error is not None

    def error(self) -> Optional[Exception]:
        return self._error

    def result(self) -> Any:
        if self._error is not None:
            raise self._error
        if not self._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if self._value is None:
            slot = self._slot
            self._value = jax.tree_util.tree_map(lambda x: x[slot], self._batch)
            self._batch = None
        return self._value


class RangeFuture:
    """One future for a contiguous range of ``count`` tasks (the bulk-
    submission analogue of :class:`TaskFuture`).

    A range enters the queue as ONE entry; the greedy drain may still split
    it across several bucketed launches, so fulfilment is segmented: each
    launch contributes ``(range_offset, batch, slot, n)``.  ``result()``
    assembles the full ``(count, ...)`` batch — zero-copy when one launch
    covered the whole range in order, which is the steady-state fast path
    (``submit_range`` of a full wave -> one mega-bucket launch -> the
    launch output IS the result).

    Containment (DESIGN.md §11) may mark individual offsets of the range
    FAILED: ``failed_indices()`` lists them, ``error(i)`` returns a task's
    :class:`~repro.core.faults.TaskFailedError`, ``task_result(i)`` reads
    one surviving task, and ``result()``/``gather_futures`` raise rather
    than assemble a batch with garbage slots in it.
    """

    __slots__ = ("_parts", "_count", "_value", "_failed")

    def __init__(self, count: int):
        self._parts: List[Tuple[int, Any, int, int]] = []
        self._count = count
        self._value = None
        self._failed: Dict[int, Exception] = {}

    def __len__(self) -> int:
        return self._count

    def _fulfil_range(self, batch_out: Any, slot: int, offset: int,
                      n: int) -> None:
        self._parts.append((offset, batch_out, slot, n))

    def _fail_range(self, offset: int, n: int, err: Exception) -> None:
        for i in range(offset, offset + n):
            self._failed[i] = err

    def _retract(self, batch_out: Any) -> None:
        """Drop every segment a tripped launch contributed (containment
        re-fulfils or fails those offsets after bisection)."""
        self._parts = [p for p in self._parts if p[1] is not batch_out]

    def ready(self) -> bool:
        if self._value is not None:     # resolved (parts were released)
            return True
        return (sum(p[3] for p in self._parts) + len(self._failed)
                == self._count)

    def failed(self) -> bool:
        return bool(self._failed)

    def failed_indices(self) -> List[int]:
        return sorted(self._failed)

    def error(self, index: Optional[int] = None) -> Optional[Exception]:
        if index is not None:
            return self._failed.get(index)
        return next(iter(self._failed.values()), None)

    def result(self) -> Any:
        """The whole range as one batched pytree (task axis leading)."""
        if self._failed:
            raise TaskFailedError(
                f"{len(self._failed)} of {self._count} tasks in this range "
                f"failed (indices {self.failed_indices()}) — read survivors "
                f"individually with task_result()",
                task_ids=self.failed_indices())
        if self._value is None:
            if not self.ready():
                raise RuntimeError(
                    "range not fully launched yet — call executor.flush()")
            self._value = _assemble_segments(
                [(batch, slot, n)
                 for _, batch, slot, n in sorted(self._parts,
                                                 key=lambda p: p[0])])
            self._parts = []
        return self._value

    def task_result(self, index: int) -> Any:
        """One task's result (raises its error if containment failed it)."""
        if index in self._failed:
            raise self._failed[index]
        if not 0 <= index < self._count:
            raise IndexError(f"task {index} out of range [0, {self._count})")
        if self._value is not None:
            return jax.tree_util.tree_map(lambda x: x[index], self._value)
        for off, batch, slot, n in self._parts:
            if off <= index < off + n:
                i = slot + (index - off)
                return jax.tree_util.tree_map(lambda x: x[i], batch)
        raise RuntimeError("task not launched yet — call executor.flush()")

    def _segments(self):
        if self._failed:
            raise TaskFailedError(
                f"range contains {len(self._failed)} failed tasks "
                f"(indices {self.failed_indices()}) — gather_futures would "
                f"assemble garbage slots; read survivors with task_result()",
                task_ids=self.failed_indices())
        if self._value is not None:
            leaves = jax.tree_util.tree_leaves(self._value)
            yield self._value, 0, leaves[0].shape[0]
            return
        if not self.ready():
            raise RuntimeError(
                "range not fully launched yet — call executor.flush()")
        for _, batch, slot, n in sorted(self._parts, key=lambda p: p[0]):
            yield batch, slot, n


def _assemble_segments(segments: List[Tuple[Any, int, int]]) -> Any:
    """Merge ``(batch, start_slot, n)`` runs into one batched pytree.

    Consecutive runs on the same launch output coalesce; a run covering a
    whole launch in order contributes the batch itself (zero-copy), a
    contiguous partial run is one slice, anything else one ``jnp.take``.
    """
    parts = []
    i = 0
    while i < len(segments):
        batch = segments[i][0]
        runs = []                                  # [(start, n)] on `batch`
        while i < len(segments) and segments[i][0] is batch:
            s0, n = segments[i][1], segments[i][2]
            if runs and runs[-1][0] + runs[-1][1] == s0:
                runs[-1] = (runs[-1][0], runs[-1][1] + n)
            else:
                runs.append((s0, n))
            i += 1
        n_slots = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if runs == [(0, n_slots)]:
            parts.append(batch)       # the whole launch, in order: zero-copy
        elif len(runs) == 1:
            s0, n = runs[0]
            parts.append(jax.tree_util.tree_map(
                lambda x: jax.lax.slice_in_dim(x, s0, s0 + n, axis=0), batch))
        else:
            idx = jnp.asarray([s for s0, n in runs
                               for s in range(s0, s0 + n)], jnp.int32)
            parts.append(jax.tree_util.tree_map(
                lambda x: jnp.take(x, idx, axis=0), batch))
    if len(parts) == 1:
        return parts[0]
    return _concat_parts(parts)


def _concat_parts(parts: List[Any]) -> Any:
    task_specs = {tuple((tuple(x.shape[1:]), np.dtype(x.dtype).str)
                        for x in jax.tree_util.tree_leaves(p))
                  for p in parts}
    if len(task_specs) > 1:
        raise ValueError(
            f"futures span task families with different output "
            f"shapes/dtypes {sorted(task_specs)} — gather each family "
            f"separately")
    return jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *parts)


def gather_futures(futs: Sequence[Any]) -> Any:
    """Assemble many futures' results into one batched array, lazily.

    Futures fulfilled by the same launch share one batched output; a run of
    such futures in slot order contributes the batch itself (zero-copy).
    Out-of-order runs become a single ``jnp.take``; distinct launches are
    joined with one ``jnp.concatenate``.  This replaces the seed's
    per-future slice + re-stack (2n device ops for n tasks) with O(launches)
    ops.

    ``TaskFuture`` and ``RangeFuture`` entries may be interleaved freely (a
    range contributes its launch segments in range order), as may launches
    from different regions — but all results must share one output
    task-shape to concatenate; gather each family separately otherwise.
    """
    if not futs:
        raise ValueError("gather_futures needs at least one future")
    segments: List[Tuple[Any, int, int]] = []
    parts = []

    def emit_segments():
        if segments:
            parts.append(_assemble_segments(segments))
            segments.clear()

    for f in futs:
        if isinstance(f, RangeFuture):
            segments.extend(f._segments())
            continue
        if f._error is not None:      # a failed task never assembles
            raise f._error
        if not f._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if f._batch is None:          # already resolved individually
            emit_segments()
            parts.append(jax.tree_util.tree_map(lambda x: x[None], f.result()))
        else:
            segments.append((f._batch, f._slot, 1))
    emit_segments()
    if len(parts) == 1:
        return parts[0]
    return _concat_parts(parts)


class SlotView:
    """Zero-copy task-input reference: ``parent[index]``, never sliced.

    Submitting SlotViews lets ``_launch`` stage a whole bucket with ONE
    ``jnp.take`` over the already-device-resident parent instead of n
    per-task slices — the index-batched staging mode.
    """

    __slots__ = ("parent", "index")

    def __init__(self, parent: jax.Array, index: int):
        self.parent = parent
        self.index = index


def _spec_of(a) -> Tuple[Tuple[int, ...], str]:
    """(shape, dtype-str) of one task argument (SlotView -> per-slot spec)."""
    if isinstance(a, SlotView):
        p = a.parent
        return tuple(p.shape[1:]), np.dtype(p.dtype).str
    if hasattr(a, "shape") and hasattr(a, "dtype"):   # jax array / SDS
        return tuple(a.shape), np.dtype(a.dtype).str
    arr = np.asarray(a)
    return arr.shape, np.dtype(jax.dtypes.canonicalize_dtype(arr.dtype)).str


@dataclass(frozen=True)
class TaskSignature:
    """What makes two fine-grained tasks aggregable: the kernel family id
    plus every argument's per-task shape and dtype.  The paper's SGMT
    compatibility check, reified as the region-registry key."""

    kernel: str
    arg_specs: Tuple[Tuple[Tuple[int, ...], str], ...]

    @classmethod
    def from_args(cls, kernel: str, args: Sequence[Any]) -> "TaskSignature":
        return cls(kernel, tuple(_spec_of(a) for a in args))

    def describe(self) -> str:
        """Unique human-readable key: shapes, with dtype appended whenever
        it is not the default float32 (so same-shape families of different
        dtypes never collide in ``stats["regions"]``)."""
        f32 = np.dtype(np.float32).str

        def one(spec):
            shape, dt = spec
            s = "x".join(map(str, shape)) or "scalar"
            return s if dt == f32 else f"{s}:{dt.lstrip('<>|=')}"

        return f"{self.kernel}[{','.join(one(s) for s in self.arg_specs)}]"


@dataclass
class _Pending:
    future: Any                                   # TaskFuture | RangeFuture
    slot: int = -1                               # ring mode: slot in the ring
    views: Optional[Tuple[SlotView, ...]] = None  # ref mode
    args: Optional[Tuple[Any, ...]] = None        # host mode
    count: int = 1                    # tasks in this entry (>1: slot range)
    fut_offset: int = 0               # this entry's offset in its RangeFuture
    wave_index: int = 0               # first task's wave-relative id (§11)

    def split(self, n: int) -> Tuple["_Pending", "_Pending"]:
        """Split a contiguous range entry: first ``n`` tasks / the rest.
        Both halves share the future (each fulfils its own offset)."""
        assert 0 < n < self.count and self.views is not None
        head = _Pending(future=self.future, views=self.views, count=n,
                        fut_offset=self.fut_offset,
                        wave_index=self.wave_index)
        tail = _Pending(
            future=self.future,
            views=tuple(SlotView(v.parent, v.index + n) for v in self.views),
            count=self.count - n, fut_offset=self.fut_offset + n,
            wave_index=self.wave_index + n)
        return head, tail


@dataclass
class _LaunchRecord:
    """Everything the post-drain guard needs to audit ONE launch and, on a
    trip, re-execute arbitrary slot subsets of it (DESIGN.md §11).

    ``parents`` + ``indices`` are the re-execution recipe: whatever the
    staging mode was, subset ``S`` re-runs as
    ``region.gather_jit(indices[S], *parents)`` — for ref staging the
    parents are the submitted parent arrays, for ring staging the launched
    ring buffers (held by reference, so a post-launch ``swap`` cannot
    invalidate them), for host staging the stacked input batch itself.
    ``poisoned`` records which wave-relative task ids carried an injected
    payload fault at launch time; re-executions re-apply exactly those (the
    poison is a property of the TASK, so bisection converges on it)."""

    region: "_Region"
    out: Any                          # the launch's batched output
    k: int                            # bucket size
    parents: Tuple[Any, ...]          # arrays gather_jit re-executes against
    indices: List[int]                # per-position absolute parent index
    tasks: List["_Pending"]           # the entries this launch fulfilled
    wave_ids: List[int]               # per-position wave-relative task id
    wave: int                         # region wave counter at launch
    poisoned: Dict[int, str]          # wave id -> injected payload mode
    verdict: Any = True               # in-flight all-finite device scalar,
                                      # dispatched at launch, forced at flush


def _split_taken(entries: List[_Pending], n: int
                 ) -> Tuple[List[_Pending], List[_Pending]]:
    """Split an already-TAKEN entry list at task boundary ``n`` (degraded
    re-draining: the queue bookkeeping was done by ``_take``, only the
    entries themselves still need carving to the smaller bucket)."""
    head: List[_Pending] = []
    rest = list(entries)
    need = n
    while need:
        e = rest[0]
        if e.count <= need:
            head.append(rest.pop(0))
            need -= e.count
        else:
            h, t = e.split(need)
            rest[0] = t
            head.append(h)
            need = 0
    return head, rest


class BucketCostModel:
    """Measured per-bucket wall times for ONE region (DESIGN.md §10).

    ``record`` accumulates raw timed samples per bucket size; ``time``
    reports the median (robust against scheduler hiccups on a noisy host);
    ``predict`` extends the table to unmeasured sizes by piecewise-linear
    interpolation in the bucket size — clamped below the smallest measured
    bucket (a launch never costs less than the smallest thing we timed,
    which is what stops the tuner from hallucinating free micro-launches)
    and extrapolated above the largest with the last measured segment's
    slope (floored at the largest measurement).

    The model is the common currency of the measured tuner: the ladder
    derivation minimizes ``predict_seq`` of each wave's greedy
    decomposition, and the ``"cost"`` flush policy compares split-drain
    against one-shot predictions.  ``as_stats`` is the JSON-safe table
    persisted into ``stats["regions"][fam]["cost_model"]`` and the BENCH
    rows (milliseconds, bucket-keyed).

    Execution paths (DESIGN.md §12): every method takes an optional
    ``path``.  The default ``"s3"`` table holds the bucketed-program
    timings above; ``"s2"`` holds per-launch times of the donated
    scatter-ring program keyed by coalesce WIDTH, and ``"fused"`` holds
    the one-launch whole-wave body keyed by wave size — so
    ``select_strategy`` compares all three strategies' measured wall
    times in one currency.

    Priors (DESIGN.md §13): ``seed_prior`` installs an ANALYTICAL
    estimate (the tunestore's :class:`RooflinePrior`) in a separate
    per-path table.  Measured samples always win: ``predict`` only
    consults a path's priors when that path has zero real samples, and
    counts every such consultation in ``prior_hits`` (the observability
    hook for "this decision ran on arithmetic, not a stopwatch").
    ``sources()`` labels every known bucket ``"measured" | "store" |
    "prior"`` so the stats surface can show where a table came from.
    """

    __slots__ = ("samples", "_paths", "priors", "_sources", "prior_hits")

    def __init__(self):
        self.samples: Dict[int, List[float]] = {}
        # path -> {bucket/width: raw samples}; "s3" aliases ``samples``
        # so the historical single-table surface keeps working unchanged
        self._paths: Dict[str, Dict[int, List[float]]] = {"s3": self.samples}
        self.priors: Dict[str, Dict[int, float]] = {}
        self._sources: Dict[Tuple[str, int], str] = {}
        self.prior_hits = 0

    def _table(self, path: str) -> Dict[int, List[float]]:
        t = self._paths.get(path)
        if t is None:
            t = self._paths[path] = {}
        return t

    def record(self, bucket: int, seconds: float, path: str = "s3",
               source: str = "measured") -> None:
        self._table(path).setdefault(int(bucket), []).append(float(seconds))
        self._sources[(path, int(bucket))] = source

    def seed_prior(self, bucket: int, seconds: float,
                   path: str = "s3") -> None:
        """Install an analytical estimate for one bucket.  Lives beside
        the sample tables, never in them — a prior must not suppress the
        real measurement of its bucket (``time`` stays None)."""
        self.priors.setdefault(path, {})[int(bucket)] = float(seconds)

    def clear(self) -> None:
        """Drop every sample on every path (the measurements' premise
        changed — e.g. the region's inner chunk was re-swept, so old
        timings describe programs that no longer exist)."""
        for table in self._paths.values():
            table.clear()
        self.priors.clear()
        self._sources.clear()

    def clear_priors(self) -> None:
        """Retire the analytical seeds (retune just measured for real —
        the §13 'fully replaced by measurements' contract)."""
        self.priors.clear()

    def measured(self, path: str = "s3") -> bool:
        return bool(self._paths.get(path))

    def seeded(self, path: str = "s3") -> bool:
        return bool(self.priors.get(path))

    def has_data(self, path: str = "s3") -> bool:
        """Can ``predict`` answer for this path (measured or seeded)?"""
        return self.measured(path) or self.seeded(path)

    def sources(self) -> Dict[str, Dict[int, str]]:
        """{path: {bucket: "measured" | "store" | "prior"}} — where each
        known bucket's number came from (priors shadowed by samples)."""
        out: Dict[str, Dict[int, str]] = {}
        for path, prior in self.priors.items():
            for b in prior:
                out.setdefault(path, {})[b] = "prior"
        for (path, b), src in self._sources.items():
            if self._paths.get(path, {}).get(b):
                out.setdefault(path, {})[b] = src
        return out

    def paths(self) -> Tuple[str, ...]:
        """The execution paths with at least one measurement."""
        return tuple(sorted(p for p, t in self._paths.items() if t))

    def buckets(self, path: str = "s3") -> Tuple[int, ...]:
        return tuple(sorted(self._paths.get(path, ())))

    def time(self, bucket: int, path: str = "s3") -> Optional[float]:
        s = self._paths.get(path, {}).get(bucket)
        return statistics.median(s) if s else None

    @staticmethod
    def _interp(bs: Sequence[int], val: Callable[[int], float],
                bucket: int) -> float:
        """Piecewise-linear table extension shared by the measured and
        prior paths: clamp below the smallest entry, interpolate inside,
        extrapolate above with the last segment's slope (floored)."""
        if bucket <= bs[0]:
            return val(bs[0])
        if bucket >= bs[-1]:
            hi = val(bs[-1])
            if len(bs) == 1:
                return hi * bucket / bs[-1]
            lo = val(bs[-2])
            slope = (hi - lo) / (bs[-1] - bs[-2])
            return max(hi, hi + slope * (bucket - bs[-1]))
        i = bisect.bisect_left(bs, bucket)
        b0, b1 = bs[i - 1], bs[i]
        t0, t1 = val(b0), val(b1)
        return t0 + (t1 - t0) * (bucket - b0) / (b1 - b0)

    def predict(self, bucket: int, path: str = "s3") -> float:
        t = self.time(bucket, path)
        if t is not None:
            return t
        bs = self.buckets(path)
        if bs:
            return self._interp(bs, lambda b: self.time(b, path), bucket)
        prior = self.priors.get(path)
        if prior:
            # analytical fallback — only ever consulted for a path with
            # ZERO real samples, so one measurement retires a whole table
            self.prior_hits += 1
            pbs = tuple(sorted(prior))
            return self._interp(pbs, prior.__getitem__, bucket)
        raise ValueError("cost model has no measurements or priors — "
                         "check has_data() before predicting")

    def predict_seq(self, buckets: Sequence[int], path: str = "s3") -> float:
        """Predicted wall time of one greedy drain (launch sequence)."""
        return sum(self.predict(b, path) for b in buckets)

    def predict_s2_wave(self, wave: int) -> Optional[Tuple[int, float]]:
        """(best coalesce width, predicted seconds) for scattering a
        ``wave``-task population through the measured s2 widths: each
        width-w launch covers w tasks, the remainder falls back to the
        width-1 program.  None before any "s2" measurement (or when a
        remainder would need an unmeasured width-1 program)."""
        ws = self.buckets("s2") or tuple(sorted(self.priors.get("s2", ())))
        if not ws:
            return None
        best = None
        for w in ws:
            if w > wave:
                continue
            rem = wave % w
            if rem and 1 not in ws:
                continue
            t = (wave // w) * self.predict(w, "s2")
            if rem:
                t += rem * self.predict(1, "s2")
            if best is None or t < best[1]:
                best = (w, t)
        return best

    def as_stats(self, path: str = "s3") -> Dict[int, float]:
        """{bucket: median milliseconds}, rounded for the stats surface."""
        return {b: round(self.time(b, path) * 1e3, 4)
                for b in self.buckets(path)}

    def as_stats_paths(self) -> Dict[str, Dict[int, float]]:
        """Every measured path's table — the DESIGN.md §12 observability
        surface backing per-family strategy selection."""
        return {p: self.as_stats(p) for p in self.paths()}


def greedy_decomposition(k: int, buckets: Sequence[int]) -> Tuple[int, ...]:
    """The bucket sequence the greedy drain launches for a queue of length
    k under a valid ladder (every bucket <= the cap by validation, so this
    models over-cap waves too: a 100-task wave under cap 64 is 64 + the
    greedy cover of 36).  Shared by the launch path, the ladder tuner and
    wave-only warmup — one definition of "what will actually launch"."""
    out = []
    while k:
        b = max(x for x in buckets if x <= k)
        out.append(b)
        k -= b
    return tuple(out)


def greedy_launches(k: int, buckets: Sequence[int]) -> int:
    """Launches the greedy drain performs for a queue of length k under a
    valid ladder (shared oracle; tests mirror it in conftest.py)."""
    return len(greedy_decomposition(k, buckets))


# ---------------------------------------------------------------------------
# s2 scatter-ring programs (DESIGN.md §12) — shared by the ``s2`` strategy,
# the ``mixed`` router and the executor's cost-model measurement pass, so
# the program that gets TIMED is byte-for-byte the program that RUNS.
# ---------------------------------------------------------------------------

def make_s2_scatter(batched_fn: Callable, width: int = 1) -> Callable:
    """One s2 launch: slice ``width`` contiguous tasks out of the parent
    arrays, run the batched body over them, scatter the results into a
    donated output ring — ONE compiled program, zero host staging.  Width
    1 is the paper's implicit aggregation; larger widths coalesce
    neighbouring tasks into one launch (ring sizing driven by the
    measured cost model).  Bit-identity holds for every width: the body
    is elementwise over the slot axis, so a width-w slice computes
    exactly the same values as w width-1 slices."""
    @partial(jax.jit, donate_argnums=(0,))
    def scatter(out_ring, i, *parents):
        task = tuple(jax.lax.dynamic_slice_in_dim(p, i, width, axis=0)
                     for p in parents)
        return jax.lax.dynamic_update_slice(
            out_ring, batched_fn(*task), (i,) + (0,) * (out_ring.ndim - 1))
    return scatter


def s2_width_candidates(wave: int) -> Tuple[int, ...]:
    """The coalesce widths the s2 cost measurement probes: 1 (the classic
    per-task scatter), 2, and the largest power of two fitting the wave.
    Every distinct width is a full XLA compile of the family body, so the
    probe set stays at three points — the endpoints bound the
    per-launch-overhead vs. batch-scaling tradeoff, and width 2 exposes a
    superlinear body (one where coalescing LOSES) without paying for the
    intermediate powers."""
    top = 1
    while top * 2 <= wave:
        top *= 2
    return tuple(sorted({1, min(2, wave), top}))


def measure_s2_widths(batched_fn: Callable, parents: Sequence[Any],
                      widths: Sequence[int], samples: int = 3,
                      cache: Optional[Dict[int, Callable]] = None
                      ) -> Dict[int, float]:
    """Time the donated scatter program per coalesce width on zero-filled
    parents: one warm (compile) call, then the median of ``samples`` timed
    launches each.  Returns {width: seconds per launch}.  ``cache`` (if
    given) receives the compiled scatter fns keyed by width, so a caller
    that will RUN the winning width reuses the warmed program.  Bodies
    whose batched output is not a single array skip measurement (the
    scatter ring is a single donated buffer)."""
    concrete = tuple(jnp.zeros(tuple(p.shape), p.dtype) for p in parents)
    wave = min(p.shape[0] for p in concrete)
    try:
        spec = jax.eval_shape(batched_fn, *concrete)
    except (TypeError, ValueError):
        return {}
    if not hasattr(spec, "shape"):           # pytree output: no single ring
        return {}
    out: Dict[int, float] = {}
    for w in sorted(set(widths)):
        if w > wave:
            continue
        fn = make_s2_scatter(batched_fn, w)
        ring = jnp.zeros(spec.shape, spec.dtype)
        i0 = jnp.int32(0)
        ring = fn(ring, i0, *concrete)                 # compile + warm
        jax.block_until_ready(ring)
        ts = []
        for _ in range(max(1, samples)):
            t0 = time.perf_counter()
            ring = fn(ring, i0, *concrete)
            jax.block_until_ready(ring)
            ts.append(time.perf_counter() - t0)
        out[w] = statistics.median(ts)
        if cache is not None:
            cache[w] = fn
    return out


def ladder_candidates(queue_hist: Mapping[int, int], cap: int) -> set:
    """The bucket sizes a ladder derivation considers: observed wave peaks
    clipped to the cap, their cap-split remainders, plus powers of two up
    to the cap.  Shared by :func:`derive_ladder` and the executor's
    cost-model measurement pass, so exactly the drain-reachable sizes the
    tuner may pick are the ones that get timed."""
    candidates = set()
    for k in queue_hist:
        if k <= 0:
            continue
        candidates.add(min(k, cap))
        if k > cap and k % cap:
            candidates.add(k % cap)   # the cap-split remainder of the wave
    b = 1
    while b <= cap:
        candidates.add(b)
        b *= 2
    return candidates


def derive_ladder(queue_hist: Mapping[int, int], cap: int, budget: int,
                  cost_model: Optional[BucketCostModel] = None
                  ) -> Tuple[int, ...]:
    """Re-derive a bucket ladder from an observed queue-length histogram.

    Starting from the mandatory ``{1}`` (the no-padding invariant needs a
    remainder bucket) seeded with the dominant wave's cap-decomposition
    (a single candidate search cannot learn that the cap bucket is only
    worth having TOGETHER with its remainder — e.g. a 100-task wave under
    cap 64 wants {64, 36} as a pair), greedily add the candidate size
    (:func:`ladder_candidates`) that most reduces the per-wave objective,
    until ``budget`` distinct bucket programs are reached or no candidate
    improves.  A steady k-task wave therefore converges on a ladder
    covering k exactly: one launch per cap-chunk, no ones-drain.

    The objective is *expected launches per wave* — the §9 proxy — unless
    a measured :class:`BucketCostModel` is supplied, in which case it is
    the *predicted wall time per wave* (DESIGN.md §10: the device's cost
    structure, not a launch count).  Under the model, a final prune drops
    any seeded bucket whose removal does not increase predicted time, so
    exact-cost ties always resolve to the smaller compile footprint
    (candidates are also tried smallest-first: an equal-cost pair admits
    the cheaper program).
    """
    # non-positive "wave lengths" carry no drain (and would crash the
    # greedy cover): drop them before they reach the objective
    queue_hist = {k: c for k, c in queue_hist.items() if k > 0}
    candidates = ladder_candidates(queue_hist, cap)
    # prior-seeded models qualify (DESIGN.md §13): an analytical table is
    # still a wall-time objective, which is the whole point of seeding
    use_model = cost_model is not None and cost_model.has_data()

    def cost(ladder):
        # candidate buckets never exceed the cap, so the greedy cover of
        # the FULL wave length models the real drain (cap-splits included)
        ls = sorted(ladder)
        if use_model:
            return sum(c * cost_model.predict_seq(greedy_decomposition(k, ls))
                       for k, c in queue_hist.items())
        return sum(c * greedy_launches(k, ls)
                   for k, c in queue_hist.items())

    ladder = {1}
    peaks = [k for k in queue_hist if k > 0]
    if peaks:
        top = max(peaks, key=lambda k: (queue_hist[k], k))
        seed = {cap, top % cap} if top > cap else {top}
        for b in sorted(seed - {0}, reverse=True):
            if len(ladder) < budget:
                ladder.add(b)

    def grow():
        while len(ladder) < budget:
            best, best_cost = None, cost(ladder)
            for c in sorted(candidates - ladder):
                cc = cost(ladder | {c})
                if cc < best_cost:
                    best, best_cost = c, cc
            if best is None:
                break
            ladder.add(best)

    grow()
    if use_model:
        # The seeds were added without a cost check (correct under the
        # launch-count objective, where a mega bucket can never lose);
        # measured time CAN say a big bucket is pessimal, so drop any
        # bucket whose removal keeps predicted time no worse — ties go to
        # the smaller compile footprint — then let the search refill the
        # freed budget (a pruned cap bucket may have been shadowing its
        # cheaper halves).  (cost, |ladder|) strictly decreases each
        # cycle, so the loop terminates.
        while True:
            pruned = False
            for b in sorted(ladder - {1}, reverse=True):
                if cost(ladder - {b}) <= cost(ladder):
                    ladder.discard(b)
                    pruned = True
                    break
            if not pruned:
                break
            grow()
    return tuple(sorted(ladder))


def _chunked_eval(batched_fn: Callable, chunk: int, *stacked):
    """Mega-bucket evaluation: run the batched body over the slot axis in
    sequential ``chunk``-slot pieces via ONE ``lax.map`` inside the same
    program.  Bit-identical to the flat call (a pure batch split of an
    independent-per-slot body); the win is cache locality — stencil-heavy
    bodies keep their intermediates resident instead of streaming a
    bucket-64-sized working set.  Falls back to the flat call whenever the
    chunk does not divide the bucket (no padding, ever)."""
    k = stacked[0].shape[0] if stacked else 0
    if chunk and 0 < chunk < k and k % chunk == 0:
        resh = tuple(a.reshape((k // chunk, chunk) + a.shape[1:])
                     for a in stacked)
        out = jax.lax.map(lambda xs: batched_fn(*xs), resh)
        return jax.tree_util.tree_map(
            lambda o: o.reshape((o.shape[0] * o.shape[1],) + o.shape[2:]),
            out)
    return batched_fn(*stacked)


class _Region:
    """One aggregation region: per-TaskSignature slot ring, submission queue
    and compiled-bucket cache.  Regions share the owning executor's pool,
    launch policy and config; everything shape- or body-specific lives here.
    """

    __slots__ = ("signature", "batched_fn", "ring", "queue", "compiled",
                 "host_jit", "gather_jit", "stats", "buckets", "chunk",
                 "chunk_tuned", "queued_tasks", "waves", "tuned",
                 "_wave_peak", "_aot_parents", "cost", "_retuned_waves",
                 "_retuned_peak", "_donate", "quarantine", "bad_buckets",
                 "_wave_submitted", "warmup_wave")

    def __init__(self, signature: TaskSignature, batched_fn: Callable,
                 donate: bool, buckets: Tuple[int, ...] = (1,),
                 chunk: int = 0, quarantine_threshold: int = 2):
        self.signature = signature
        self.batched_fn = batched_fn
        self._donate = donate
        self.ring: Optional[SlotRing] = None
        self.queue: List[_Pending] = []
        self.queued_tasks = 0         # tasks queued (entries carry counts)
        self.compiled: Dict[Tuple, Callable] = {}
        self.buckets = buckets        # per-region ladder (auto-tune target)
        self.chunk = chunk            # mega-bucket inner chunk (0 = flat)
        self.chunk_tuned = False      # "auto" tuning ran for this region
        self.waves = 0                # completed waves (queue drained to 0)
        self.tuned = False
        self._wave_peak = 0
        self._aot_parents: Dict[Tuple, Tuple] = {}  # pk -> parent structs
        self.cost = BucketCostModel()     # measured bucket wall times (§10)
        self._retuned_waves = -1      # waves counter at the last retune
        self._retuned_peak = 0        # largest wave peak seen at last retune
        # blast-radius containment state (DESIGN.md §11)
        self.quarantine = QuarantineList(threshold=quarantine_threshold)
        self.bad_buckets: set = set()     # rungs banned by degraded mode
        self._wave_submitted = 0      # wave-relative task ids, reset per wave
        self.warmup_wave = 0          # wave size warmup was told about (§12)
        # shared shape-polymorphic wrappers (jit re-specializes per shape,
        # so ONE wrapper serves every bucket / parent shape)
        self.reset_compiled()
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {},
                      "queue_hist": {}, "ladder": list(buckets),
                      # warm-start observability (DESIGN.md §13): launches
                      # spent on stopwatch measurement, and cost-model
                      # predictions answered from the analytical prior
                      "measurement_launches": 0, "prior_hits": 0,
                      "faults": {"trips": 0, "bisection_launches": 0,
                                 "failed_tasks": 0, "quarantined": [],
                                 "retries": 0, "compile_failures": 0,
                                 "launch_failures": 0,
                                 "degraded_launches": 0}}

    # -- bucketed programs -------------------------------------------------
    def _eval(self, *stacked):
        """The body over a staged bucket, chunk-aware (DESIGN.md §9)."""
        return _chunked_eval(self.batched_fn, self.chunk, *stacked)

    def _apply_host(self, *stacked):
        return self._eval(*stacked)

    def _apply_gathered(self, idx, *parents):
        """Index-batched staging: one gather feeds the aggregation body."""
        return self._eval(*(jnp.take(p, idx, axis=0) for p in parents))

    def _apply_ring_prefix(self, bucket: int, start, *rings):
        """Ring staging: the bucket reads a zero-copy view of the filled
        prefix [start, start+bucket) straight out of the slot ring."""
        sliced = tuple(jax.lax.dynamic_slice_in_dim(r, start, bucket, axis=0)
                       for r in rings)
        return self._eval(*sliced)

    # -- compilation cache -------------------------------------------------
    # Each bucket size is a genuinely distinct XLA program (static shapes),
    # cached under ("ring"|"host"|"prefix", bucket) — plus parent-shape-keyed
    # AOT entries ("gather"|"prefix_aot", bucket, parent_shapes) installed by
    # ``AggregationExecutor.warmup(parent_shapes=...)``.
    def compiled_for(self, bucket: int, mode: str = "ring") -> Callable:
        key = (mode, bucket)
        fn = self.compiled.get(key)
        if fn is None:
            if mode in ("ring", "prefix"):
                fn = jax.jit(partial(self._apply_ring_prefix, bucket))
            else:
                fn = self.host_jit
            self.compiled[key] = fn
        return fn

    def ensure_ring(self, capacity: int,
                    example_args: Sequence[Any]) -> SlotRing:
        if self.ring is None:
            self.ring = SlotRing(capacity, example_args)
        return self.ring

    def expected_peak(self) -> int:
        """The modal observed wave peak (ties to the larger) — what the
        adaptive flush policies treat as 'a full wave'; 0 before any wave
        has completed (policies then behave eagerly)."""
        qh = self.stats["queue_hist"]
        if not qh:
            return 0
        return max(qh, key=lambda k: (qh[k], k))

    # -- AOT lowering (ONE recipe shared by warmup and ladder retune, so
    # the cache keys the _launch lookup probes are spelled out once) ------
    def aot_ref(self, bucket: int, parents: Sequence[Any]) -> None:
        """Pre-compile the indexed-gather + contiguous-prefix programs for
        one bucket over one parent set (ShapeDtypeStructs)."""
        pk = tuple(tuple(p.shape) for p in parents)
        if ("gather", bucket, pk) not in self.compiled:
            idx = jax.ShapeDtypeStruct((bucket,), jnp.int32)
            self.compiled[("gather", bucket, pk)] = jax.jit(
                self._apply_gathered).lower(idx, *parents).compile()
        if ("prefix_aot", bucket, pk) not in self.compiled:
            start = jax.ShapeDtypeStruct((), jnp.int32)
            self.compiled[("prefix_aot", bucket, pk)] = jax.jit(
                partial(self._apply_ring_prefix, bucket)).lower(
                    start, *parents).compile()

    def aot_ring(self, bucket: int, ring_specs: Sequence[Any]) -> None:
        """Pre-compile the slot-ring prefix program for one bucket."""
        if ("ring", bucket) not in self.compiled:
            start = jax.ShapeDtypeStruct((), jnp.int32)
            self.compiled[("ring", bucket)] = jax.jit(
                partial(self._apply_ring_prefix, bucket)).lower(
                    start, *ring_specs).compile()

    def reset_compiled(self) -> None:
        """Drop every compiled program AND recreate the shared jit
        wrappers.  Needed when the inner chunk changes after compilation
        (a retune-time re-sweep): every cached trace baked the old chunk,
        and the shared wrappers' per-shape jit caches would silently keep
        serving it."""
        self.compiled.clear()
        self.host_jit = jax.jit(self._apply_host,
                                donate_argnums=(0,) if self._donate else ())
        self.gather_jit = jax.jit(self._apply_gathered)


class AggregationExecutor:
    """Aggregates submissions of *kernel families* into bucketed launches.

    A registry of aggregation regions keyed by :class:`TaskSignature` lets
    tasks of different kernels AND different shapes coexist: each family
    gets its own slot ring, queue and compiled buckets, while the launch
    policy, executor pool and statistics are shared.  ``flush`` drains the
    live regions round-robin, so families interleave on the device instead
    of serializing.

    Parameters
    ----------
    batched_fn : callable, optional
        ``batched_fn(*stacked_args) -> stacked_out`` where every arg/out has
        a leading slot axis.  Registered as the default kernel family under
        ``name``; further families via :meth:`register`.  The body is one
        traced function shared by all its aggregated tasks (SGMT by
        construction), and serves every task shape submitted to it (each
        distinct shape opens its own region over the same body).
    config : AggregationConfig
        ``max_aggregated`` caps the bucket size (the paper's second launch
        criterion); ``n_executors`` sizes the underlying executor pool
        (combining strategy 3 with strategy 2, as the paper's best rows do);
        ``staging`` selects device-resident (slot ring / indexed gather) or
        the seed's host staging.
    """

    def __init__(self, batched_fn: Optional[Callable] = None,
                 config: Optional[AggregationConfig] = None,
                 pool: Optional[ExecutorPool] = None,
                 buffer_pool: Optional[BufferPool] = None,
                 donate: bool = False,
                 name: str = "region",
                 fault_injector: Optional[FaultInjector] = None):
        self.name = name
        self.config = config or AggregationConfig()
        self.pool = pool or ExecutorPool(self.config.n_executors)
        self.buffers = buffer_pool or DEFAULT_POOL
        self._buckets = tuple(sorted(self.config.bucket_sizes()))
        self._donate = donate
        ic = getattr(self.config, "inner_chunk", 0)
        self._chunk = int(ic) if ic != "auto" else 0   # "auto": set at warmup
        self._chunk_auto = ic == "auto"
        self._staging = getattr(self.config, "staging", "device")
        if self._staging not in ("device", "host"):
            raise ValueError(f"unknown staging mode {self._staging!r}")
        self._flush_policy = getattr(self.config, "flush_policy", "eager")
        fp_values = (self._flush_policy.values()
                     if isinstance(self._flush_policy, Mapping)
                     else (self._flush_policy,))
        for fp in fp_values:
            if fp not in ("eager", "watermark", "cost"):
                raise ValueError(
                    f"unknown flush_policy {fp!r} — valid "
                    f"policies: eager, watermark, cost")
        self._cost_on = bool(getattr(self.config, "cost_model", False))
        self._cost_samples = max(1, int(getattr(self.config,
                                                "cost_samples", 3)))
        # warm-start subsystem (DESIGN.md §13): the persistent tune store
        # (None -> cold start unless REPRO_TUNE_STORE points somewhere)
        # and the analytical prior for first-contact ladder derivation
        self._store = TuneStore.open(getattr(self.config, "tune_store",
                                             None))
        prior_mode = getattr(self.config, "prior", "off")
        if prior_mode not in ("off", "roofline"):
            raise ValueError(f"unknown prior mode {prior_mode!r} — valid "
                             f"modes: off, roofline")
        self._prior: Optional[RooflinePrior] = None
        self._prior_on = prior_mode == "roofline"
        if self._store is not None:
            self._store.enable_compilation_cache()
        # blast-radius containment (DESIGN.md §11)
        self._guard = getattr(self.config, "guard", "off")
        if self._guard not in ("off", "finite"):
            raise ValueError(f"unknown guard mode {self._guard!r} — valid "
                             f"modes: off, finite")
        self._injector = fault_injector
        self._max_retries = max(0, int(getattr(self.config,
                                               "max_bucket_retries", 2)))
        self._retry_backoff = float(getattr(self.config,
                                            "retry_backoff_s", 0.0))
        self._qthreshold = max(1, int(getattr(self.config,
                                              "quarantine_threshold", 2)))
        self._guard_records: List[_LaunchRecord] = []
        self._bodies: Dict[str, Callable] = {}
        self._regions: Dict[TaskSignature, _Region] = {}
        self._default_kernel: Optional[str] = None
        # per-kernel routing cache for SlotView waves: kernel -> (parents,
        # sig).  A wave's submissions share one parent set per family, so
        # identity-comparing the parents skips the per-task signature
        # rebuild on the hot path — keyed per kernel so interleaved
        # multi-family waves (e.g. hydro + gravity) don't thrash it.
        self._sig_cache: Dict[str, Tuple[Tuple[Any, ...], TaskSignature]] = {}
        # statistics for the benchmark tables; per-family bucket histograms
        # live under "regions" (the multi-signature observability surface)
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {},
                      "staging_s": 0.0, "regions": {},
                      "warm_start": False,   # any region restored from store
                      "flush_policy": (dict(self._flush_policy)
                                       if isinstance(self._flush_policy,
                                                     Mapping)
                                       else self._flush_policy)}
        if batched_fn is not None:
            self.register(name, batched_fn)

    # -- region registry ---------------------------------------------------
    def register(self, kernel: str, batched_fn: Callable,
                 default: bool = False) -> str:
        """Register a kernel family's batched body.  The first registration
        (or ``default=True``) becomes the default for untagged submissions.
        Regions themselves are opened lazily, one per task signature."""
        if kernel in self._bodies and self._bodies[kernel] is not batched_fn:
            raise ValueError(
                f"kernel {kernel!r} already registered with a different body")
        self._bodies[kernel] = batched_fn
        if default or self._default_kernel is None:
            self._default_kernel = kernel
        return kernel

    def set_fault_injector(self,
                           injector: Optional[FaultInjector]) -> None:
        """Attach (or detach, with None) a deterministic fault schedule.
        Injection sites fire on the paths they model — payload faults on
        launch outputs, ring corruption at submission, compile/launch
        faults at dispatch — so containment is exercised end to end."""
        self._injector = injector

    def _region_for(self, kernel: str, args: Sequence[Any]) -> _Region:
        sig = TaskSignature.from_args(kernel, args)
        region = self._regions.get(sig)
        if region is None:
            body = self._bodies.get(kernel)
            if body is None:
                raise KeyError(f"no batched body registered for kernel "
                               f"{kernel!r} (have {sorted(self._bodies)})")
            region = _Region(sig, body, self._donate, buckets=self._buckets,
                             chunk=self._chunk,
                             quarantine_threshold=self._qthreshold)
            self._regions[sig] = region
            self.stats["regions"][sig.describe()] = region.stats
        return region

    def _region_for_views(self, kernel: str,
                          views: Sequence[SlotView]) -> _Region:
        """Region routing for all-SlotView submissions, cached on the
        parent-set identity (strong refs keep ids valid)."""
        parents = tuple(v.parent for v in views)
        c = self._sig_cache.get(kernel)
        if (c is not None and len(c[0]) == len(parents)
                and all(a is b for a, b in zip(c[0], parents))):
            region = self._regions.get(c[1])
            if region is not None:
                return region
        region = self._region_for(kernel, views)
        self._sig_cache[kernel] = (parents, region.signature)
        return region

    def _resolve_kernel(self, kernel: Optional[str]) -> str:
        kernel = kernel or self._default_kernel
        if kernel is None:
            raise RuntimeError("no kernel family registered — pass "
                               "batched_fn to the constructor or register()")
        return kernel

    @property
    def regions(self) -> Dict[TaskSignature, "_Region"]:
        """Live region registry (read-only view)."""
        return dict(self._regions)

    # -- single-region compatibility views --------------------------------
    def _sole_region(self) -> Optional[_Region]:
        if len(self._regions) == 1:
            return next(iter(self._regions.values()))
        return None

    @property
    def ring(self) -> Optional[SlotRing]:
        region = self._sole_region()
        return region.ring if region is not None else None

    @property
    def _queue(self) -> List[_Pending]:
        out: List[_Pending] = []
        for region in self._regions.values():
            out.extend(region.queue)
        return out

    @property
    def _compiled(self) -> Mapping[Tuple, Callable]:
        """Read-only view of the compiled-program caches (merged across
        regions); write through ``region.compiled`` instead — a write to
        this view would silently vanish in the multi-region case."""
        region = self._sole_region()
        if region is not None:
            return MappingProxyType(region.compiled)
        merged: Dict[Tuple, Callable] = {}
        for region in self._regions.values():
            merged.update(region.compiled)
        return MappingProxyType(merged)

    # -- warmup ------------------------------------------------------------
    def warmup(self, example_args: Optional[Tuple[Any, ...]] = None, *,
               kernel: Optional[str] = None,
               parent_shapes: Optional[Sequence[Any]] = None,
               buckets: Optional[Sequence[int]] = None,
               store: Optional[Any] = None) -> None:
        """AOT pre-compile every bucket size (amortized startup, like stream
        pre-allocation in CPPuddle).

        Buckets are lowered with ``.lower().compile()`` — no example
        execution, no broadcast staging, and no tracer hit on the first
        real submission.  Two modes, combinable:

        * ``example_args`` — per-task example inputs; pre-compiles the slot
          ring (device staging) or host-stacked (host staging) buckets.
        * ``parent_shapes`` — shapes/dtypes of the parent arrays that
          ``submit_indexed``/``submit_range`` will reference (arrays or
          ShapeDtypeStructs); pre-compiles the indexed-gather AND
          contiguous-prefix programs those submissions hit, closing the
          gather-mode warmup gap (DESIGN.md §6 -> §7).

        ``buckets`` restricts which ladder buckets are AOT-compiled (e.g.
        just the steady wave's greedy decomposition — the caller's compile
        budget); default is the region's whole ladder.  Un-warmed buckets
        still compile lazily on first use.

        ``store`` (DESIGN.md §13) points this warmup at a persistent
        :class:`TuneStore` (path or instance), overriding the config's
        ``tune_store`` knob: regions with a valid stored entry LOAD their
        tuned state (ladder, chunk, cost tables, strategy selection)
        instead of measuring it — zero measurement launches — and bucket
        compiles become persistent-cache disk hits.
        """
        kernel = self._resolve_kernel(kernel)
        if store is not None:
            self._store = TuneStore.open(store)
            if self._store is not None:
                self._store.enable_compilation_cache()

        def aot_buckets(region):
            want = region.buckets if buckets is None else tuple(buckets)
            if region.stats.get("tuned_by") in ("store", "prior"):
                # a restored/seeded ladder is what the drain will use —
                # AOT ITS decomposition of the warmup wave too, or the
                # warm process pays lazy compiles the cold one never did
                # (callers pass ``buckets`` derived from the config
                # ladder, which the installed ladder supersedes)
                wave = region.warmup_wave
                if wave:
                    want = tuple(sorted(set(want).union(
                        greedy_decomposition(wave, region.buckets))))
            return want

        if parent_shapes is not None:
            parents = tuple(jax.ShapeDtypeStruct(tuple(p.shape), p.dtype)
                            for p in parent_shapes)
            task_specs = tuple(jax.ShapeDtypeStruct(p.shape[1:], p.dtype)
                               for p in parents)
            region = self._region_for(kernel, task_specs)
            pk = tuple(tuple(p.shape) for p in parents)
            region._aot_parents[pk] = parents    # retune re-AOTs from these
            restored = self._restore_region(region)
            if self._chunk_auto and not region.chunk_tuned:
                self._tune_chunk(region, parents)
            n_parent = min(p.shape[0] for p in parents)
            region.warmup_wave = max(region.warmup_wave, n_parent)
            if (self._prior_on and not restored
                    and not region.cost.measured()
                    and not region.cost.seeded()):
                self._seed_prior(region, parents)
            for b in (b for b in aot_buckets(region) if b <= n_parent):
                region.aot_ref(b, parents)
            if self._cost_on and not region.cost.seeded():
                self._measure_region(region, aot_buckets(region),
                                     parents=parents)
            if example_args is None:
                return
        if example_args is None:
            raise ValueError("warmup needs example_args and/or parent_shapes")
        region = self._region_for(kernel, example_args)
        restored = self._restore_region(region)
        specs = [jax.ShapeDtypeStruct(tuple(np.shape(a)),
                                      getattr(a, "dtype", None)
                                      or jnp.asarray(a).dtype)
                 for a in example_args]
        if self._chunk_auto and not region.chunk_tuned:
            # ring/host-staged regions tune too: a pseudo-parent of the
            # largest bucket's stacked shape drives the same measurement
            pseudo = tuple(jax.ShapeDtypeStruct(
                (max(region.buckets),) + s.shape, s.dtype) for s in specs)
            self._tune_chunk(region, pseudo)
        if (self._prior_on and not restored and not region.cost.measured()
                and not region.cost.seeded()):
            # ring-staged regions seed against the ring capacity: the
            # wave size is unknown before traffic, the cap bounds it
            pseudo = tuple(jax.ShapeDtypeStruct(
                (self.config.max_aggregated,) + s.shape, s.dtype)
                for s in specs)
            self._seed_prior(region, pseudo)
        if self._staging == "device":
            ring = region.ensure_ring(self.config.max_aggregated,
                                      example_args)
            ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                          for r in ring.buffers()]
            for b in aot_buckets(region):
                region.aot_ring(b, ring_specs)
            if self._cost_on and not region.cost.seeded():
                self._measure_region(region, aot_buckets(region),
                                     ring_specs=ring_specs)
        else:
            for b in aot_buckets(region):
                stacked = tuple(
                    jax.ShapeDtypeStruct((b,) + s.shape, s.dtype)
                    for s in specs)
                region.compiled[("host", b)] = region.host_jit.lower(
                    *stacked).compile()

    # -- persistent warm start (DESIGN.md §13) -----------------------------
    def _restore_region(self, region: _Region) -> bool:
        """Install the tune store's entry for this region, if one exists
        for this exact ``(backend, device_kind)`` + signature + code
        version: ladder (re-validated — a store is data, not trusted
        code), inner chunk, every cost-model path's table (tagged
        ``source="store"``, so ``_measure_region`` skips those buckets
        and ``_measure_alt_paths`` skips its probes), the observed queue
        histogram and the strategy selection.  The region comes up
        ``tuned``; autotune re-arms only on evidence beyond the stored
        histogram, exactly as after a live retune.  Any malformed field
        warns and leaves the region cold — a broken store must never
        crash (or mis-tune) the process it was meant to speed up."""
        if region.stats.get("tuned_by") == "store":
            return True                            # idempotent re-warmup
        if self._store is None:
            return False
        entry = self._store.get(_backend_key(), region.signature.describe())
        if not entry:
            return False
        try:
            ladder = validate_ladder([int(b) for b in entry["ladder"]],
                                     self.config.max_aggregated)
            cost_tables = {
                str(path): {int(b): float(t) for b, t in dict(table).items()}
                for path, table in dict(entry.get("cost_model",
                                                  {})).items()}
            queue_hist = {
                int(k): int(v)
                for k, v in dict(entry.get("queue_hist", {})).items()}
            chunk = entry.get("inner_chunk")
            chunk = None if chunk is None else int(chunk)
        except (KeyError, TypeError, ValueError) as err:
            warnings.warn(
                f"tune store entry for {region.signature.describe()} is "
                f"unusable ({err}) — falling back to cold-start "
                f"measurement", TuneStoreWarning, stacklevel=2)
            return False
        if chunk is not None:
            region.chunk = chunk
            region.chunk_tuned = True
            region.stats["inner_chunk"] = chunk
        for path, table in cost_tables.items():
            for b, sec in sorted(table.items()):
                region.cost.record(b, sec, path=path, source="store")
        region.buckets = ladder
        region.stats["ladder"] = list(ladder)
        qh = region.stats["queue_hist"]
        for k, c in queue_hist.items():
            qh[k] = qh.get(k, 0) + c
        region.warmup_wave = max(region.warmup_wave,
                                 int(entry.get("warmup_wave", 0) or 0))
        region.tuned = True
        region._retuned_waves = region.waves
        region._retuned_peak = max(queue_hist, default=0)
        for k in ("selected_strategy", "strategy_costs"):
            if k in entry:
                region.stats[k] = entry[k]
        if region.cost.measured():
            region.stats["cost_model"] = region.cost.as_stats()
        if len(region.cost.paths()) > 1:
            region.stats["cost_model_paths"] = region.cost.as_stats_paths()
        region.stats["tuned_by"] = "store"
        region.stats["cost_sources"] = {
            p: {b: s for b, s in t.items()}
            for p, t in region.cost.sources().items()}
        region.stats["warm_start"] = True
        self.stats["warm_start"] = True
        return True

    def _seed_prior(self, region: _Region,
                    parents: Sequence[Any]) -> None:
        """First contact without a stopwatch (DESIGN.md §13): fill the
        region's cost model with roofline estimates — every
        drain-reachable candidate bucket on "s3", the probe widths on
        "s2", the whole wave on "fused" — then derive a ladder from the
        analytical table.  Entries are tagged ``source="prior"`` and the
        region stays un-``tuned``: the normal autotune path re-derives
        from real measurements as waves arrive and retires the seeds."""
        wave = min(p.shape[0] for p in parents)
        if not wave:
            return
        if self._prior is None:
            self._prior = RooflinePrior(_backend_key())
        task_specs = tuple(jax.ShapeDtypeStruct(tuple(p.shape[1:]), p.dtype)
                           for p in parents)
        cap = self.config.max_aggregated
        for b in sorted(ladder_candidates({wave: 1}, cap)):
            region.cost.seed_prior(
                b, self._prior.predict(region.batched_fn, task_specs, b))
        for w in s2_width_candidates(wave):
            region.cost.seed_prior(
                w, self._prior.predict(region.batched_fn, task_specs, w),
                path="s2")
        region.cost.seed_prior(
            wave, self._prior.predict(region.batched_fn, task_specs, wave),
            path="fused")
        ladder = validate_ladder(
            derive_ladder({wave: 1}, cap, self.config.compile_budget,
                          region.cost), cap)
        region.buckets = ladder
        region.stats["ladder"] = list(ladder)
        region.stats["tuned_by"] = "prior"
        region.stats["cost_sources"] = {
            p: dict(t) for p, t in region.cost.sources().items()}
        region.stats["prior_hits"] = region.cost.prior_hits

    def _persist_region(self, region: _Region,
                        store: Optional[TuneStore] = None) -> None:
        """Write one region's tuned state into the store (measured
        medians only — priors are seeds, not knowledge worth saving)."""
        store = store or self._store
        entry: Dict[str, Any] = {
            "cost_model": {path: {str(b): region.cost.time(b, path)
                                  for b in region.cost.buckets(path)}
                           for path in region.cost.paths()},
            "ladder": [int(b) for b in region.buckets],
            "inner_chunk": int(region.chunk),
            "queue_hist": {str(k): int(v)
                           for k, v in region.stats["queue_hist"].items()},
            "warmup_wave": int(region.warmup_wave),
            "tuned_by": region.stats.get("tuned_by", "measured"),
        }
        for k in ("selected_strategy", "strategy_costs"):
            if k in region.stats:
                entry[k] = region.stats[k]
        store.put(_backend_key(), region.signature.describe(), entry)

    def save_tuning(self, store: Optional[Any] = None) -> Optional[str]:
        """Persist every tuned/measured region into the tune store (the
        executor's own, or an explicit ``store`` path/instance) and
        atomically write it to disk.  Returns the store file path, or
        None when there is no store to write to.  The write-back half of
        the §13 contract: ``warmup(store=...)`` loads, this saves."""
        target = TuneStore.open(store) if store is not None else self._store
        if target is None:
            return None
        wrote = False
        for region in self._regions.values():
            if region.tuned or region.cost.measured():
                self._persist_region(region, target)
                wrote = True
        if wrote or len(target) == 0:
            target.save()
        return target.path

    def _tune_chunk(self, region: _Region, parents: Sequence[Any],
                    force: bool = False) -> None:
        """``inner_chunk="auto"``: pick the region's mega-bucket chunk by
        timing the body on its largest bucket over candidate chunk sizes
        (0 = flat, then powers of two).  Runs once per region at warmup,
        before any bucket program is compiled, so every compiled program
        sees the chosen chunk; under ``cost_model=True`` the retune pass
        re-runs it with ``force=True`` (DESIGN.md §10 — the sweep follows
        the ladder to whatever bucket the tuner actually converged on,
        superseding the §9 warmup-only choice).  This is a measurement,
        not a lowering — tuning executes a handful of zero-filled buckets.
        Results are memoized per (backend+device kind, body, bucket
        shape), so re-tuning the same family in another executor (a
        benchmark sweep) is free, while a choice timed on one backend can
        never leak into another; ``force`` bypasses the memo read and
        overwrites the entry."""
        n_parent = min(p.shape[0] for p in parents)
        b = max((x for x in region.buckets if x <= n_parent), default=0)
        if b < 2:
            return
        key = (_backend_key(), id(region.batched_fn), b,
               tuple((tuple(p.shape[1:]), str(p.dtype)) for p in parents))
        memo = _CHUNK_TUNE_MEMO.get(key)
        if memo is not None and not force:
            region.chunk = memo[1]
            region.chunk_tuned = True
            region.stats["inner_chunk"] = memo[1]
            return
        stacked = tuple(jnp.zeros((b,) + tuple(p.shape[1:]), p.dtype)
                        for p in parents)
        best_chunk, best_t = 0, float("inf")
        for c in (0, 2, 4, 8):
            if c >= b or (c and b % c):
                continue
            fn = jax.jit(partial(_chunked_eval, region.batched_fn, c))
            try:
                jax.block_until_ready(fn(*stacked))    # compile + warm
            except (TypeError, ValueError):
                continue                               # body rejects chunking
            except Exception as err:
                # anything else (OOM, lowering bug, device loss) is NOT a
                # "this body dislikes chunking" signal — surface it with
                # the region/bucket context instead of silently pinning
                # chunk=0 (satellite of DESIGN.md §11)
                raise RegionFaultError(
                    f"inner-chunk tuning failed for region "
                    f"{region.signature.describe()} (bucket {b}, chunk "
                    f"{c}): {err}") from err
            # min-of-3 guards the choice against scheduler hiccups — the
            # memo pins it process-wide, so one noisy sample must not
            # lock in a pessimal chunk (~3.5x between best and worst here)
            region.stats["measurement_launches"] += 4   # warm + 3 timed
            t = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*stacked))
                t = min(t, time.perf_counter() - t0)
            if t < best_t:
                best_chunk, best_t = c, t
        # the memo holds a ref to the body so id() stays valid for the key
        while len(_CHUNK_TUNE_MEMO) >= _CHUNK_TUNE_MEMO_MAX:
            _CHUNK_TUNE_MEMO.pop(next(iter(_CHUNK_TUNE_MEMO)))
        _CHUNK_TUNE_MEMO[key] = (region.batched_fn, best_chunk)
        region.chunk = best_chunk
        region.chunk_tuned = True
        region.stats["inner_chunk"] = best_chunk

    # -- bucket cost measurement (DESIGN.md §10) ---------------------------
    def _measure_region(self, region: _Region, buckets: Sequence[int],
                        parents: Optional[Sequence[Any]] = None,
                        ring_specs: Optional[Sequence[Any]] = None) -> None:
        """Time each bucket's compiled program on zero-filled inputs into
        the region's :class:`BucketCostModel`: one warm call, then the
        median of ``cost_samples`` timed runs.  Ref-staged regions time
        the contiguous-prefix program (the steady bulk-submission fast
        path — gather-by-index costs the same body plus one take);
        ring-staged regions time the ring-prefix program.  Buckets that
        already have samples are skipped, so repeated warmups are free;
        a chunk re-sweep clears the model first (old timings described
        programs that no longer exist).  Host staging is never measured —
        it is the seed baseline, not a tuned hot path."""
        if parents is not None:
            concrete = tuple(jnp.zeros(tuple(p.shape), p.dtype)
                             for p in parents)

            def program(b):
                region.aot_ref(b, parents)
                pk = tuple(tuple(p.shape) for p in parents)
                return region.compiled[("prefix_aot", b, pk)]
        elif ring_specs is not None:
            concrete = tuple(jnp.zeros(tuple(r.shape), r.dtype)
                             for r in ring_specs)

            def program(b):
                region.aot_ring(b, ring_specs)
                return region.compiled[("ring", b)]
        else:
            return
        n_slots = min(c.shape[0] for c in concrete)
        start = jnp.int32(0)
        for b in sorted(set(buckets)):
            if b > n_slots or region.cost.time(b) is not None:
                continue
            fn = program(b)
            jax.block_until_ready(fn(start, *concrete))        # warm call
            region.stats["measurement_launches"] += 1 + self._cost_samples
            for _ in range(self._cost_samples):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(start, *concrete))
                region.cost.record(b, time.perf_counter() - t0)
        if parents is not None:
            self._measure_alt_paths(region, concrete)
        if region.cost.measured():
            region.stats["cost_model"] = region.cost.as_stats()
        if len(region.cost.paths()) > 1:
            region.stats["cost_model_paths"] = region.cost.as_stats_paths()

    def _measure_alt_paths(self, region: _Region,
                           concrete: Sequence[Any]) -> None:
        """Time the OTHER execution strategies' programs for this family
        (DESIGN.md §12), so ``select_strategy`` compares measured wall
        times instead of guessing: the s2 donated scatter per coalesce
        width, and the fused one-launch whole-wave body.  Measured once
        per region; the s2 widths probed are 1 plus powers of two up to
        the wave size.

        Families with an EXPLICIT route in ``family_strategies`` skip the
        probes whose result nothing would consult — each is a full XLA
        compile.  An explicit ``"s2"`` route still measures the s2 width
        table (the s2 strategy sizes its scatter ring from it); explicit
        ``"s3"`` / ``"fused"`` routes probe nothing here, and only
        ``"auto"`` (the default) measures every path for
        ``select_strategy`` to compare."""
        wave = min(c.shape[0] for c in concrete)
        if not wave:
            return
        route = resolve_family_option(
            getattr(self.config, "family_strategies", None),
            region.signature.kernel, "auto")
        if route in ("auto", "s2") and not region.cost.measured("s2"):
            widths = measure_s2_widths(region.batched_fn, concrete,
                                       s2_width_candidates(wave),
                                       samples=self._cost_samples)
            region.stats["measurement_launches"] += (
                len(widths) * (1 + self._cost_samples))
            for w, t in widths.items():
                region.cost.record(w, t, path="s2")
        if route == "auto" and not region.cost.measured("fused"):
            fn = jax.jit(region.batched_fn)
            try:
                jax.block_until_ready(fn(*concrete))           # warm call
            except (TypeError, ValueError):
                return                    # body rejects the flat whole wave
            region.stats["measurement_launches"] += 1 + self._cost_samples
            for _ in range(self._cost_samples):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*concrete))
                region.cost.record(wave, time.perf_counter() - t0,
                                   path="fused")

    # -- per-family strategy selection (DESIGN.md §12) ---------------------
    def strategy_costs(self, kernel: str) -> Dict[str, Any]:
        """Predicted per-wave wall time (ms) of running ``kernel``'s wave
        under each measured execution strategy — the selection rationale
        persisted into the BENCH rows.  Empty before any measurement."""
        region = self._primary_region(kernel)
        if region is None:
            return {}
        wave = region.expected_peak() or region.warmup_wave
        if not wave:
            return {}
        out: Dict[str, Any] = {}
        if region.cost.has_data("s3"):
            ladder = [b for b in region.buckets
                      if b not in region.bad_buckets] or [1]
            out["s3"] = round(region.cost.predict_seq(
                greedy_decomposition(wave, ladder)) * 1e3, 4)
        s2 = region.cost.predict_s2_wave(wave)
        if s2 is not None:
            out["s2"] = round(s2[1] * 1e3, 4)
            out["s2_width"] = s2[0]
        if region.cost.has_data("fused"):
            out["fused"] = round(region.cost.predict(wave, "fused") * 1e3, 4)
        return out

    def select_strategy(self, kernel: str) -> str:
        """Pick the cheapest measured execution strategy for ``kernel``'s
        steady wave ("s2" | "s3" | "fused"; ties prefer "s3" — the
        aggregated path — then "s2").  Defaults to "s3" before any
        measurement.  The choice and its justification land in
        ``stats["regions"][fam]["selected_strategy"]`` /
        ``["strategy_costs"]``."""
        costs = self.strategy_costs(kernel)
        order = ("s3", "s2", "fused")
        timed = [(costs[s], order.index(s)) for s in order if s in costs]
        choice = min(timed)[1] if timed else 0
        selected = order[choice]
        region = self._primary_region(kernel)
        if region is not None:
            region.stats["selected_strategy"] = selected
            if costs:
                region.stats["strategy_costs"] = costs
        return selected

    def record_selection(self, kernel: str, selected: str) -> None:
        """Persist an EXPLICIT per-family route (``family_strategies``)
        into the region stats, alongside whatever cost numbers exist —
        explicit and auto-selected assignments surface identically."""
        region = self._primary_region(kernel)
        if region is None:
            return
        region.stats["selected_strategy"] = selected
        costs = self.strategy_costs(kernel)
        if costs:
            region.stats["strategy_costs"] = costs

    def _primary_region(self, kernel: str) -> Optional[_Region]:
        """The region selection reasons about for a kernel: the one with
        the largest wave evidence (several regions per kernel can exist —
        one per task shape)."""
        regs = [r for s, r in self._regions.items() if s.kernel == kernel]
        if not regs:
            return None
        return max(regs, key=lambda r: (r.expected_peak() or r.warmup_wave))

    # -- submission API ----------------------------------------------------
    def submit(self, *args, kernel: Optional[str] = None) -> TaskFuture:
        """Queue one task, routed to its signature's region.  Args are
        either concrete per-task arrays (staged into the region's slot ring)
        or all :class:`SlotView` references (staged by a single gather at
        launch)."""
        kernel = self._resolve_kernel(kernel)
        fut = TaskFuture()
        is_ref = bool(args) and all(isinstance(a, SlotView) for a in args)
        if is_ref and self._staging == "device":
            region = self._region_for_views(kernel, args)
            if any(v.index != args[0].index for v in args[1:]):
                raise ValueError(
                    "SlotView args of one task must share one index — a "
                    "launch gathers the SAME slot from every parent "
                    "(use submit_indexed)")
            entry = _Pending(future=fut, views=tuple(args))
        elif self._staging == "host" or not args:
            region = self._region_for(kernel, args)
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            entry = _Pending(future=fut, args=args)
        else:
            region = self._region_for(kernel, args)
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            t0 = time.perf_counter()
            ring = region.ensure_ring(self.config.max_aggregated, args)
            if ring.fill >= ring.capacity:
                # watermark remainders left a partial prefix consumed; slide
                # the live tail to the front (one fused device op)
                first = region.queue[0].slot if region.queue else ring.fill
                ring.compact(first)
                for p in region.queue:
                    p.slot -= first
            slot = ring.write(args)
            if self._injector is not None:
                # ring-corruption site: this task's staged inputs go bad
                # between submission and launch (bad DMA / stale buffer)
                bad = self._injector.corrupt_ring(
                    kernel, region.waves, region._wave_submitted)
                if bad is not None:
                    ring.poison(slot, bad)
            entry = _Pending(future=fut, slot=slot)
            self.stats["staging_s"] += time.perf_counter() - t0
        self._enqueue(region, entry)
        return fut

    def submit_range(self, parents: Tuple[jax.Array, ...], start: int,
                     n: int, kernel: Optional[str] = None) -> RangeFuture:
        """Bulk submission: enqueue tasks ``start .. start+n-1`` of a parent
        set as ONE queue entry backed by ONE :class:`RangeFuture`.

        Replaces n ``submit_indexed`` calls (n ``TaskFuture`` allocations, n
        signature routings, n queue appends) with one of each — the
        submission loop stops being a per-task Python cost.  The range may
        still drain across several bucketed launches (greedy, in order);
        ``result()``/``gather_futures`` reassemble it, zero-copy in the
        steady one-launch case.  Launch criteria see all n tasks at once, so
        a full wave triggers its mega-bucket immediately on submission.
        """
        if n <= 0:
            raise ValueError(f"submit_range needs n >= 1, got {n}")
        if self._staging != "device":
            raise ValueError(
                "submit_range requires device staging — ranges reference "
                "device-resident parents by slot index (use per-task "
                "submit() under staging='host')")
        kernel = self._resolve_kernel(kernel)
        n_parent = min(p.shape[0] for p in parents)
        if start < 0 or start + n > n_parent:
            # XLA's dynamic_slice/take CLAMP out-of-bounds indices instead
            # of failing — an unchecked range would silently return data
            # from the wrong slots
            raise ValueError(
                f"range [{start}, {start + n}) out of bounds for parents "
                f"with {n_parent} slots")
        views = tuple(SlotView(p, start) for p in parents)
        region = self._region_for_views(kernel, views)
        fut = RangeFuture(n)
        entry = _Pending(future=fut, views=views, count=n)
        self._enqueue(region, entry)
        return fut

    def _enqueue(self, region: _Region, entry: _Pending) -> None:
        self._check_mode(region, entry)
        # wave-relative task identity (§11): position within the current
        # submission wave — stable across re-executions, and what payload
        # fault specs and the quarantine list key on
        entry.wave_index = region._wave_submitted
        region._wave_submitted += entry.count
        region.queue.append(entry)
        region.queued_tasks += entry.count
        region._wave_peak = max(region._wave_peak, region.queued_tasks)
        self.stats["submitted"] += entry.count
        region.stats["submitted"] += entry.count
        self._maybe_launch()

    def submit_indexed(self, parents: Tuple[jax.Array, ...], index: int,
                       kernel: Optional[str] = None) -> TaskFuture:
        """Sugar: submit task ``i`` whose j-th arg is ``parents[j][i]``."""
        return self.submit(*(SlotView(p, index) for p in parents),
                           kernel=kernel)

    def _check_mode(self, region: _Region, entry: _Pending) -> None:
        """A bucket must stage uniformly: same mode, and for ref entries the
        same parent arrays (a launch gathers from ONE parent set).  Launch
        the region's queue before admitting an incompatible entry."""
        if not region.queue:
            return
        head = region.queue[0]
        compatible = self._entry_mode(head) == self._entry_mode(entry)
        if compatible and entry.views is not None:
            compatible = all(a.parent is b.parent
                             for a, b in zip(head.views, entry.views))
        if not compatible:
            while region.queue:
                self._launch(region,
                             self._largest_bucket(region,
                                                  region.queued_tasks))

    @staticmethod
    def _entry_mode(entry: _Pending) -> str:
        if entry.views is not None:
            return "ref"
        if entry.args is not None:
            return "host"
        return "ring"

    def _maybe_launch(self) -> None:
        """The paper's launch policy, per region: launch when (a) the cap is
        reached, or (b) an underlying executor is idle AND the flush policy
        agrees that draining the partial queue now beats waiting for a
        fuller bucket; otherwise keep aggregating.  Regions progress
        independently — a full family never stalls behind another family's
        partial queue."""
        progress = True
        while progress:
            progress = False
            for region in self._regions.values():
                q = region.queued_tasks
                if q >= self.config.max_aggregated:
                    self._launch(region,
                                 self._largest_bucket(
                                     region, self.config.max_aggregated))
                    progress = True
                elif (q >= self.config.launch_watermark
                      and self.pool.any_idle()
                      and self._idle_drain_pays(region, q)):
                    self._launch(region, self._largest_bucket(region, q))
                    progress = True

    def _policy_for(self, region: _Region) -> str:
        """The region's flush policy: the config value, resolved per family
        when it is a mapping (exact kernel -> "+epi" base -> "*" -> eager,
        DESIGN.md §12)."""
        return resolve_family_option(self._flush_policy,
                                     region.signature.kernel, "eager")

    def _idle_drain_pays(self, region: _Region, q: int) -> bool:
        """The watermark-adaptive flush decision (DESIGN.md §10): should a
        partial queue of ``q`` tasks drain into an idle executor, or keep
        aggregating toward the region's typical wave?

        * ``eager`` — always drain (the §4 policy, and the fallback of the
          adaptive policies until a wave peak / cost model exists);
        * ``watermark`` — drain only at/after the *learned* wave peak, so
          partial buckets stop leaking once the steady wave size is known;
        * ``cost`` — drain early only when the measured model predicts the
          split drain (q now + the remainder later) to be no slower than
          waiting and draining the full wave in one greedy pass — i.e.
          exactly when the big bucket's measured cost is superlinear
          enough that splitting it is free.

        Non-eager consultations leave a decision trace in
        ``stats["regions"][fam]["flush_decisions"]`` (consulted /
        drained_early / held counters), so a policy's behaviour under a
        live watermark is observable in the BENCH rows.
        """
        policy = self._policy_for(region)
        if policy == "eager":
            return True
        trace = region.stats.setdefault(
            "flush_decisions", {"policy": policy, "consulted": 0,
                                "full_wave": 0, "drained_early": 0,
                                "held": 0})
        trace["consulted"] += 1
        peak = region.expected_peak()
        if not peak or q >= peak:
            trace["full_wave"] += 1
            return True               # no history yet, or a full wave: go
        if policy == "watermark":
            trace["held"] += 1
            return False
        if not region.cost.measured():
            trace["drained_early"] += 1
            return True               # "cost" without a model: eager
        split = (region.cost.predict_seq(
                     greedy_decomposition(q, region.buckets))
                 + region.cost.predict_seq(
                     greedy_decomposition(peak - q, region.buckets)))
        full = region.cost.predict_seq(
            greedy_decomposition(peak, region.buckets))
        pays = split <= full
        trace["drained_early" if pays else "held"] += 1
        return pays

    @staticmethod
    def _largest_bucket(region: _Region, k: int) -> int:
        best = region.buckets[0]
        for b in region.buckets:
            # degraded mode (§11): rungs banned after repeated compile/
            # launch failures are skipped; bucket 1 is never banned, so a
            # remainder bucket always survives
            if b <= k and b not in region.bad_buckets:
                best = b
        if best > k:
            raise RuntimeError(
                f"bucket {best} exceeds queue length {k} — ladder "
                f"{region.buckets} lacks a remainder bucket (validate_ladder "
                f"should have rejected it)")
        return best

    def _take(self, region: _Region, k: int) -> List[_Pending]:
        """Pop k tasks' worth of entries off the queue, splitting a range
        entry at the bucket boundary (both halves share the RangeFuture)."""
        taken: List[_Pending] = []
        need = k
        while need:
            e = region.queue[0]
            if e.count <= need:
                taken.append(region.queue.pop(0))
                need -= e.count
            else:
                head, tail = e.split(need)
                region.queue[0] = tail
                taken.append(head)
                need = 0
        region.queued_tasks -= k
        return taken

    def _launch(self, region: _Region, k: int) -> None:
        tasks = self._take(region, k)
        mode = self._entry_mode(tasks[0])
        self._launch_tasks(region, tasks, k, mode)
        if mode == "ring" and not region.queue:
            region.ring.swap()    # in-flight launch keeps the old buffer
        if not region.queue:
            self._wave_complete(region)

    def _stage(self, region: _Region, tasks: List[_Pending], k: int,
               mode: str):
        """One bucket's inputs -> (fn, call_args, parents, indices): the
        compiled program plus the §11 re-execution recipe — ``parents`` are
        the concrete arrays ``region.gather_jit`` can re-run any position
        subset against (parent set / launched ring buffers / stacked host
        batch), ``indices`` each position's absolute index into them."""
        if mode == "ref":
            indices: List[int] = []
            for t in tasks:
                i0 = t.views[0].index
                indices.extend(range(i0, i0 + t.count))
            parents = tuple(v.parent for v in tasks[0].views)
            pk = tuple(tuple(p.shape) for p in parents)
            if pk not in region._aot_parents:    # remember for retune AOT
                region._aot_parents[pk] = tuple(
                    jax.ShapeDtypeStruct(tuple(p.shape), p.dtype)
                    for p in parents)
            if indices == list(range(indices[0], indices[0] + k)):
                # contiguous slot run: one dynamic slice of the parent (the
                # parent IS the ring) — no gather, no index array
                fn = (region.compiled.get(("prefix_aot", k, pk))
                      or region.compiled_for(k, "prefix"))
                call_args = (jnp.int32(indices[0]),) + parents
            else:
                idx = jnp.asarray(indices, jnp.int32)
                fn = (region.compiled.get(("gather", k, pk))
                      or region.gather_jit)
                call_args = (idx,) + parents
        elif mode == "ring":
            first = tasks[0].slot
            parents = region.ring.buffers()   # concrete refs: a later swap
            indices = list(range(first, first + k))   # cannot invalidate
            fn = region.compiled_for(k, "ring")
            call_args = (jnp.int32(first),) + parents
        else:
            stacked = []
            for j in range(len(tasks[0].args)):
                parts = [t.args[j] for t in tasks]
                if k == 1:
                    stacked.append(jnp.asarray(parts[0])[None])
                elif isinstance(parts[0], jax.Array):
                    stacked.append(jnp.stack(parts))
                else:
                    stacked.append(jnp.asarray(self.buffers.stage(parts)))
            parents = tuple(stacked)
            indices = list(range(k))
            fn = region.compiled.get(("host", k), region.host_jit)
            call_args = parents
        return fn, call_args, parents, indices

    def _launch_tasks(self, region: _Region, tasks: List[_Pending], k: int,
                      mode: str, degraded: bool = False) -> None:
        """Stage + dispatch one bucket of TAKEN tasks and fulfil their
        futures; under ``guard="finite"`` the launch is also recorded for
        the post-drain audit.  A compile/launch fault degrades the bucket
        (``_degrade``) instead of propagating — the wave survives."""
        t0 = time.perf_counter()
        fn, call_args, parents, indices = self._stage(region, tasks, k, mode)
        self.stats["staging_s"] += time.perf_counter() - t0
        try:
            out = self._dispatch(region, fn, call_args, k)
        except (BucketCompileError, LaunchFaultError) as err:
            self._degrade(region, tasks, k, mode, err)
            return
        wave_ids: List[int] = []
        for t in tasks:
            wave_ids.extend(range(t.wave_index, t.wave_index + t.count))
        poisoned: Dict[int, str] = {}
        if self._injector is not None:
            # payload site: the matched tasks' outputs go non-finite (the
            # NaN blow-up / bad tenant input the guard exists to contain)
            hit = self._injector.poison_positions(
                region.signature.kernel, region.waves, wave_ids)
            if hit:
                out = poison_slots(out, sorted(hit), hit)
                poisoned = {wave_ids[p]: m for p, m in hit.items()}
        slot = 0
        for t in tasks:
            if isinstance(t.future, RangeFuture):
                t.future._fulfil_range(out, slot, t.fut_offset, t.count)
            else:
                t.future._fulfil(out, slot)
            slot += t.count
        if self._guard == "finite":
            # dispatch the finite reduction NOW (non-blocking) so it
            # overlaps the staging/dispatch of later launches in the
            # drain; _run_guard only forces the boolean post-drain
            self._guard_records.append(_LaunchRecord(
                region=region, out=out, k=k, parents=parents,
                indices=indices, tasks=list(tasks), wave_ids=wave_ids,
                wave=region.waves, poisoned=poisoned,
                verdict=all_finite_async(out)))
        self.stats["launches"] += 1
        hist = self.stats["aggregated_hist"]
        hist[k] = hist.get(k, 0) + 1
        region.stats["launches"] += 1
        rhist = region.stats["aggregated_hist"]
        rhist[k] = rhist.get(k, 0) + 1
        if degraded:
            region.stats["faults"]["degraded_launches"] += 1

    def _dispatch(self, region: _Region, fn: Callable, call_args, k: int):
        """One pool launch with the §11 dispatch-site injection and the
        bounded-retry policy: launch faults are transient by assumption
        (retried with exponential backoff from ``retry_backoff_s``),
        compile faults deterministic (never retried — the same program
        cannot succeed on attempt two)."""
        kern = region.signature.kernel
        faults = region.stats["faults"]
        attempts = 0
        while True:
            try:
                inj = self._injector
                if inj is not None:
                    if inj.compile_fails(kern, k):
                        faults["compile_failures"] += 1
                        raise BucketCompileError(
                            f"injected compile failure: kernel {kern!r} "
                            f"bucket {k}")
                    lf = inj.launch_fault(kern, k)
                    if lf is not None:
                        fmode, delay = lf
                        if fmode == "delay":
                            time.sleep(delay)
                        else:
                            faults["launch_failures"] += 1
                            raise LaunchFaultError(
                                f"injected launch failure: kernel {kern!r} "
                                f"bucket {k}")
                return self.pool.get().launch(fn, *call_args, family=kern)
            except BucketCompileError:
                raise
            except LaunchFaultError:
                if attempts >= self._max_retries:
                    raise
                attempts += 1
                faults["retries"] += 1
                if self._retry_backoff:
                    time.sleep(self._retry_backoff * (2 ** (attempts - 1)))

    def _degrade(self, region: _Region, tasks: List[_Pending], k: int,
                 mode: str, err: Exception) -> None:
        """Graceful degradation (§11): ban the failing rung and re-drain
        the taken tasks greedily through the remaining good rungs — down
        to per-task bucket-1 launches, the degraded floor.  A failure AT
        bucket 1 has nowhere smaller to fall: those tasks fail, with the
        dispatch error attached to their futures."""
        if k == 1:
            self._fail_tasks(region, tasks, err)
            return
        region.bad_buckets.add(k)
        remaining = list(tasks)
        n_left = sum(t.count for t in remaining)
        while n_left:
            good = [b for b in region.buckets
                    if b <= n_left and b not in region.bad_buckets]
            b = max(good) if good else 1
            head, remaining = _split_taken(remaining, b)
            self._launch_tasks(region, head, b, mode, degraded=True)
            n_left -= b

    def _fail_tasks(self, region: _Region, tasks: List[_Pending],
                    err: Exception) -> None:
        n = 0
        for t in tasks:
            ids = tuple(range(t.wave_index, t.wave_index + t.count))
            cause = TaskFailedError(
                f"task(s) {list(ids)} of {region.signature.describe()} "
                f"failed: {err}", task_ids=ids,
                kernel=region.signature.kernel)
            cause.__cause__ = err
            if isinstance(t.future, RangeFuture):
                t.future._fail_range(t.fut_offset, t.count, cause)
            else:
                t.future._fail(cause)
            n += t.count
        region.stats["faults"]["failed_tasks"] += n

    # -- post-drain guard: detection, bisection, containment (§11) ---------
    def _run_guard(self) -> None:
        """ONE scalar all-finite check per drained launch (the guarded-
        but-untripped cost); a tripped launch's futures are retracted and
        re-resolved by ladder bisection."""
        records, self._guard_records = self._guard_records, []
        for rec in records:
            if bool(rec.verdict):
                continue
            self._contain(rec)

    def _contain(self, rec: _LaunchRecord) -> None:
        """Isolate the offending slot(s) of a tripped launch in O(log
        bucket) re-executions: quarantined repeat offenders short-circuit
        to per-task groups, everything else halves recursively; clean
        groups re-fulfil their futures bit-identically (batch
        decomposition is exact), non-finite singletons fail."""
        region = rec.region
        faults = region.stats["faults"]
        faults["trips"] += 1
        for t in rec.tasks:
            if isinstance(t.future, RangeFuture):
                t.future._retract(rec.out)
            else:
                t.future._retract()
        # position -> (owning entry, entry's first position)
        owner: Dict[int, Tuple[_Pending, int]] = {}
        pos = 0
        for t in rec.tasks:
            for p in range(pos, pos + t.count):
                owner[p] = (t, pos)
            pos += t.count
        quarantined = [p for p in range(rec.k)
                       if rec.wave_ids[p] in region.quarantine]
        rest = [p for p in range(rec.k)
                if rec.wave_ids[p] not in region.quarantine]
        # the root group is KNOWN bad only when no quarantined position
        # could be carrying the trip — then its own re-execution is skipped
        groups: List[Tuple[List[int], bool]] = [([p], False)
                                                for p in quarantined]
        if rest:
            groups.append((rest, not quarantined))
        culprits: List[int] = []
        while groups:
            grp, known_bad = groups.pop()
            if known_bad:
                if len(grp) == 1:
                    culprits.append(grp[0])
                else:
                    mid = len(grp) // 2
                    groups.append((grp[:mid], False))
                    groups.append((grp[mid:], False))
                continue
            out = self._reexec(rec, grp)
            faults["bisection_launches"] += 1
            if all_finite(out):
                self._refulfil(rec, owner, grp, out)
            elif len(grp) == 1:
                culprits.append(grp[0])
            else:
                mid = len(grp) // 2
                groups.append((grp[:mid], False))
                groups.append((grp[mid:], False))
        for p in culprits:
            tid = rec.wave_ids[p]
            region.quarantine.record_offense(tid)
            faults["quarantined"] = region.quarantine.as_stats()
            err = TaskFailedError(
                f"non-finite output isolated to task {tid} of "
                f"{region.signature.describe()} (wave {rec.wave}, launch "
                f"bucket {rec.k})", task_ids=(tid,),
                kernel=region.signature.kernel)
            t, first = owner[p]
            if isinstance(t.future, RangeFuture):
                t.future._fail_range(t.fut_offset + (p - first), 1, err)
            else:
                t.future._fail(err)
        faults["failed_tasks"] += len(culprits)

    def _reexec(self, rec: _LaunchRecord, grp: List[int]):
        """Re-execute one position subset through the region's shape-
        polymorphic gather program.  Injected payload poison is re-applied
        by wave id (the poison is a property of the TASK), so a poisoned
        task stays non-finite at every bucket size and bisection converges
        on it; survivors come back bit-identical to their unaggregated
        results — the no-padding equivalence invariant."""
        region = rec.region
        idx = jnp.asarray([rec.indices[p] for p in grp], jnp.int32)
        out = self.pool.get().launch(region.gather_jit, idx, *rec.parents,
                                     family=region.signature.kernel)
        pois = {j: rec.poisoned[rec.wave_ids[p]]
                for j, p in enumerate(grp)
                if rec.wave_ids[p] in rec.poisoned}
        if pois:
            out = poison_slots(out, sorted(pois), pois)
        return out

    @staticmethod
    def _refulfil(rec: _LaunchRecord, owner: Dict[int, Tuple[_Pending, int]],
                  grp: List[int], out: Any) -> None:
        """Fulfil a clean re-executed group (bisection keeps groups as
        contiguous position runs, so segment assembly stays slice-shaped)."""
        for j, p in enumerate(grp):
            t, first = owner[p]
            if isinstance(t.future, RangeFuture):
                t.future._fulfil_range(out, j, t.fut_offset + (p - first), 1)
            else:
                t.future._fulfil(out, j)

    # -- ladder auto-tuning ------------------------------------------------
    def _wave_complete(self, region: _Region) -> None:
        """A wave ended (queue drained to zero): record its peak queue
        length and, past the warmup, re-derive the region's ladder."""
        region._wave_submitted = 0    # wave-relative task ids restart
        region.stats["prior_hits"] = region.cost.prior_hits
        peak = region._wave_peak
        if peak:
            qh = region.stats["queue_hist"]
            qh[peak] = qh.get(peak, 0) + 1
            region.waves += 1
            region._wave_peak = 0
            if region.tuned and peak > region._retuned_peak:
                # the workload outgrew anything the last retune SAW (e.g.
                # warmup saw only watermark-drained micro-waves, then a
                # bulk range arrived): re-arm the tuner instead of pinning
                # the small ladder forever.  The trigger is new EVIDENCE
                # (a peak beyond the tuned histogram), never the ladder
                # shape — a measured tuner may legitimately pick a ladder
                # whose max bucket is below the wave (splitting predicted
                # faster), and comparing against max(buckets) would then
                # re-arm, and re-tune, on every single wave
                region.tuned = False
        if (self.config.autotune and not region.tuned
                and region.waves >= self.config.autotune_warmup):
            self._retune_region(region)

    def _retune_region(self, region: _Region) -> None:
        """Swap in the ladder minimizing the per-wave objective — expected
        launches, or predicted wall time under ``cost_model=True`` — and
        AOT-compile the new buckets for every parent set seen, as the AMR
        follow-up work does once launch overhead stops dominating.

        The measured path (DESIGN.md §10) runs three extra steps first:
        re-sweep ``inner_chunk="auto"`` against the current backend (a
        chunk change invalidates every compiled program AND every cost
        sample — both are rebuilt), then time every drain-reachable
        candidate bucket (:func:`ladder_candidates`), then hand the model
        to :func:`derive_ladder`.  Candidate measurement compiles more
        programs than ``compile_budget`` — the budget bounds the ladder
        the steady state keeps, not what the tuner is allowed to probe.
        """
        region._retuned_waves = region.waves
        region._retuned_peak = max(
            (k for k in region.stats["queue_hist"] if k > 0), default=0)
        chunk_changed = False
        cost_model = None
        if self._cost_on:
            chunk_changed = self._resweep_chunk(region)
            cost_model = self._measure_candidates(region)
        ladder = derive_ladder(region.stats["queue_hist"],
                               self.config.max_aggregated,
                               self.config.compile_budget, cost_model)
        region.tuned = True
        region.stats["tuned_by"] = ("measured" if cost_model is not None
                                    else "launches")
        if cost_model is not None:
            # real measurements just landed: retire the analytical seeds
            # (DESIGN.md §13 — priors are fully replaced by retune)
            region.cost.clear_priors()
            region.stats["cost_sources"] = {
                p: dict(t) for p, t in region.cost.sources().items()}
        region.stats["prior_hits"] = region.cost.prior_hits
        if ladder != region.buckets or chunk_changed:
            region.buckets = ladder
            region.stats["ladder"] = list(ladder)
            # AOT only the buckets the observed waves will actually drain
            # through under the new ladder (the compile budget, honored)
            used = set()
            for k in region.stats["queue_hist"]:
                used.update(greedy_decomposition(k, ladder))
            if region.ring is not None:   # ring-staged regions retune too
                ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                              for r in region.ring.buffers()]
                for b in sorted(used):
                    region.aot_ring(b, ring_specs)
            # (host staging keeps lazy per-shape jit — it is the
            # measurable seed baseline, not a tuned hot path)
            for parents in region._aot_parents.values():
                n_parent = min(p.shape[0] for p in parents)
                for b in (b for b in sorted(used) if b <= n_parent):
                    region.aot_ref(b, parents)
        # write-back half of the warm-start contract: the tuned state a
        # retune just produced is exactly what process two wants to load
        if self._store is not None and (region.cost.measured()
                                        or region.tuned):
            self._persist_region(region)
            self._store.save()

    def _resweep_chunk(self, region: _Region) -> bool:
        """Retune-time ``inner_chunk="auto"`` re-sweep (supersedes the §9
        warmup-only choice): re-time the chunk candidates on the current
        backend, bypassing the memo.  Returns True when the chunk changed
        — the caller must then treat every compiled program and cost
        sample as stale (this method already resets both)."""
        if not self._chunk_auto:
            return False
        parents = self._primary_parents(region)
        if parents is None:
            return False
        old = region.chunk
        self._tune_chunk(region, parents, force=True)
        if region.chunk == old:
            return False
        region.reset_compiled()
        region.cost.clear()
        region.stats.pop("cost_model", None)
        return True

    @staticmethod
    def _primary_parents(region: _Region) -> Optional[Tuple[Any, ...]]:
        """The parent set measurements run against: the deepest one seen
        (biggest buckets fit), falling back to the ring's buffers."""
        best = None
        for parents in region._aot_parents.values():
            n = min(p.shape[0] for p in parents)
            if best is None or n > best[0]:
                best = (n, parents)
        if best is not None:
            return best[1]
        if region.ring is not None:
            return tuple(jax.ShapeDtypeStruct(r.shape, r.dtype)
                         for r in region.ring.buffers())
        return None

    def _measure_candidates(self, region: _Region
                            ) -> Optional[BucketCostModel]:
        """Time every drain-reachable candidate bucket for the region's
        observed waves (already-measured buckets are free), returning the
        model — or None when nothing could be measured (e.g. a host-staged
        region, which the cost path then treats as launch-count tuning)."""
        cands = sorted(ladder_candidates(region.stats["queue_hist"],
                                         self.config.max_aggregated))
        for parents in region._aot_parents.values():
            self._measure_region(region, cands, parents=parents)
        if region.ring is not None:
            ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                          for r in region.ring.buffers()]
            self._measure_region(region, cands, ring_specs=ring_specs)
        return region.cost if region.cost.measured() else None

    def retune(self) -> Dict[str, Tuple[int, ...]]:
        """Force a ladder retune of every region that has completed at
        least one NEW wave since its last retune; returns the ladders by
        family.  A region with an empty queue histogram — or none recorded
        since the last retune — is left untouched: re-deriving from no
        (new) evidence would only produce a degenerate ``(1,)`` ladder or
        burn AOT work reproducing the current one."""
        out = {}
        for region in self._regions.values():
            if (region.stats["queue_hist"]
                    and region.waves != region._retuned_waves):
                region.tuned = False
                self._retune_region(region)
            out[region.signature.describe()] = region.buckets
        return out

    def flush(self) -> None:
        """Launch everything still queued (greedy buckets) and drain.
        Live regions are drained round-robin — one launch per family per
        pass — so interleaved families pipeline on the device."""
        live = [r for r in self._regions.values() if r.queue]
        while live:
            for region in live:
                if region.queue:
                    self._launch(region,
                                 self._largest_bucket(region,
                                                      region.queued_tasks))
            live = [r for r in live if r.queue]
        self.pool.drain()
        if self._guard_records:
            self._run_guard()
        # the routing cache holds strong refs to the last wave's parent
        # arrays; the wave is over, release them (next wave re-primes)
        self._sig_cache.clear()

    def map(self, task_args: Sequence[Tuple[Any, ...]],
            kernel: Optional[str] = None) -> List[Any]:
        """Submit many tasks, flush, return their results in order."""
        futs = [self.submit(*a, kernel=kernel) for a in task_args]
        self.flush()
        return [f.result() for f in futs]


# ---------------------------------------------------------------------------
# Region API — the paper's "aggregation region" (a marked code region that
# compatible tasks may enter together).  Cosmetic sugar over the executor.
# ---------------------------------------------------------------------------

_REGIONS: Dict[str, AggregationExecutor] = {}


def aggregation_region(name: str, batched_fn: Callable,
                       config: Optional[AggregationConfig] = None,
                       **kw) -> AggregationExecutor:
    """Get-or-create the named region's executor (one Executor Pool per
    aggregation region, as in the paper's CPPuddle implementation)."""
    exe = _REGIONS.get(name)
    if exe is None:
        exe = AggregationExecutor(batched_fn, config or AggregationConfig(),
                                  name=name, **kw)
        _REGIONS[name] = exe
    return exe


def reset_regions() -> None:
    _REGIONS.clear()
