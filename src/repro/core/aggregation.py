"""The paper's strategy 3: on-the-fly explicit work aggregation, TPU-native.

Fine-grained tasks submit "launch kernel K on my inputs" requests.  While the
underlying executor is busy, compatible submissions accumulate; when it
becomes idle — or the ``max_aggregated`` cap is reached — the queued tasks
are fused into ONE batched kernel launch over a slot axis.  Each task gets a
future resolving to its slot of the batched output.

Multi-region runtime (DESIGN.md §7): one executor hosts MANY aggregation
regions at once.  Submissions are routed by :class:`TaskSignature` — kernel
id plus per-argument shape/dtype — to their family's slot ring, queue and
compiled-bucket cache, so heterogeneous task populations (the adaptive-
refinement regime of the follow-up AMR work, arXiv:2412.15518) aggregate
concurrently without serializing each other.  A region is created lazily the
first time a signature is seen, which also makes a single registered kernel
shape-polymorphic: new task shapes simply open new regions over the same
body.

TPU adaptation (DESIGN.md §2): XLA requires static shapes, so a dynamic
aggregation count is realized as a small set of pre-compiled *buckets*
(powers of two up to the cap).  A queue of length k is drained greedily with
the largest bucket <= k; because bucket 1 exists, no padding is ever needed
and results are *bit-identical* to unaggregated execution (the equivalence
invariant tested in tests/test_aggregation.py and tests/test_slot_ring.py).

Staging (DESIGN.md §3): the hot path is device-resident end to end.  Task
inputs either

* land in a pre-allocated :class:`~repro.core.buffers.SlotRing` via donated
  coalesced scatters (concrete per-task arrays), or
* stay where they already live and are referenced by a :class:`SlotView`
  ``(parent, index)``; a launch then performs ONE ``jnp.take`` gather inside
  the bucketed program (index-batched staging, zero per-task slicing).

The seed's slice -> host-stack -> launch cycle survives as
``staging="host"`` so benchmarks/launch_overhead.py can measure the win.

The paper's "Single-GPU-workload-Multiple-Tasks" constraint (all aggregated
tasks execute the same allocation/launch sequence) is enforced *statically*
here: each region's bucketed kernel is one traced function extended over the
slot axis, so divergence between aggregated tasks is impossible by
construction.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from types import MappingProxyType
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AggregationConfig
from repro.core.buffers import DEFAULT_POOL, BufferPool, SlotRing
from repro.core.executor import ExecutorPool


class TaskFuture:
    """HPX-future analogue: resolves to one task's slice of a batched launch.

    Resolution is lazy twice over: ``_fulfil`` only records (batch, slot) —
    no per-slot ``tree_map`` happens until ``result()`` is actually read —
    and callers that want the whole batch back should use
    :func:`gather_futures`, which recognises futures covering a full launch
    and returns the batched output itself with zero copies.
    """

    __slots__ = ("_value", "_batch", "_slot", "_done")

    def __init__(self):
        self._value = None
        self._batch = None
        self._slot = -1
        self._done = False

    def _fulfil(self, batch_out: Any, slot: int) -> None:
        self._batch, self._slot, self._done = batch_out, slot, True

    def ready(self) -> bool:
        return self._done

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if self._value is None:
            slot = self._slot
            self._value = jax.tree_util.tree_map(lambda x: x[slot], self._batch)
            self._batch = None
        return self._value


def gather_futures(futs: Sequence[TaskFuture]) -> Any:
    """Assemble many futures' results into one batched array, lazily.

    Futures fulfilled by the same launch share one batched output; a run of
    such futures in slot order contributes the batch itself (zero-copy).
    Out-of-order runs become a single ``jnp.take``; distinct launches are
    joined with one ``jnp.concatenate``.  This replaces the seed's
    per-future slice + re-stack (2n device ops for n tasks) with O(launches)
    ops.

    Futures may interleave launches from different regions freely — runs
    are grouped by launch identity — but all results must share one output
    task-shape to concatenate; gather each family separately otherwise.
    """
    if not futs:
        raise ValueError("gather_futures needs at least one future")
    parts = []
    i = 0
    while i < len(futs):
        f = futs[i]
        if not f._done:
            raise RuntimeError("task not launched yet — call executor.flush()")
        if f._batch is None:          # already resolved individually
            parts.append(jax.tree_util.tree_map(lambda x: x[None], f.result()))
            i += 1
            continue
        batch = f._batch
        slots = []
        while i < len(futs) and futs[i]._batch is batch:
            slots.append(futs[i]._slot)
            i += 1
        n_slots = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if slots == list(range(n_slots)):
            parts.append(batch)       # the whole launch, in order: zero-copy
        else:
            idx = jnp.asarray(slots, jnp.int32)
            parts.append(jax.tree_util.tree_map(
                lambda x: jnp.take(x, idx, axis=0), batch))
    if len(parts) == 1:
        return parts[0]
    task_specs = {tuple((tuple(x.shape[1:]), np.dtype(x.dtype).str)
                        for x in jax.tree_util.tree_leaves(p))
                  for p in parts}
    if len(task_specs) > 1:
        raise ValueError(
            f"futures span task families with different output "
            f"shapes/dtypes {sorted(task_specs)} — gather each family "
            f"separately")
    return jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *parts)


class SlotView:
    """Zero-copy task-input reference: ``parent[index]``, never sliced.

    Submitting SlotViews lets ``_launch`` stage a whole bucket with ONE
    ``jnp.take`` over the already-device-resident parent instead of n
    per-task slices — the index-batched staging mode.
    """

    __slots__ = ("parent", "index")

    def __init__(self, parent: jax.Array, index: int):
        self.parent = parent
        self.index = index


def _spec_of(a) -> Tuple[Tuple[int, ...], str]:
    """(shape, dtype-str) of one task argument (SlotView -> per-slot spec)."""
    if isinstance(a, SlotView):
        p = a.parent
        return tuple(p.shape[1:]), np.dtype(p.dtype).str
    if hasattr(a, "shape") and hasattr(a, "dtype"):   # jax array / SDS
        return tuple(a.shape), np.dtype(a.dtype).str
    arr = np.asarray(a)
    return arr.shape, np.dtype(jax.dtypes.canonicalize_dtype(arr.dtype)).str


@dataclass(frozen=True)
class TaskSignature:
    """What makes two fine-grained tasks aggregable: the kernel family id
    plus every argument's per-task shape and dtype.  The paper's SGMT
    compatibility check, reified as the region-registry key."""

    kernel: str
    arg_specs: Tuple[Tuple[Tuple[int, ...], str], ...]

    @classmethod
    def from_args(cls, kernel: str, args: Sequence[Any]) -> "TaskSignature":
        return cls(kernel, tuple(_spec_of(a) for a in args))

    def describe(self) -> str:
        """Unique human-readable key: shapes, with dtype appended whenever
        it is not the default float32 (so same-shape families of different
        dtypes never collide in ``stats["regions"]``)."""
        f32 = np.dtype(np.float32).str

        def one(spec):
            shape, dt = spec
            s = "x".join(map(str, shape)) or "scalar"
            return s if dt == f32 else f"{s}:{dt.lstrip('<>|=')}"

        return f"{self.kernel}[{','.join(one(s) for s in self.arg_specs)}]"


@dataclass
class _Pending:
    future: TaskFuture
    slot: int = -1                               # ring mode: slot in the ring
    views: Optional[Tuple[SlotView, ...]] = None  # ref mode
    args: Optional[Tuple[Any, ...]] = None        # host mode


class _Region:
    """One aggregation region: per-TaskSignature slot ring, submission queue
    and compiled-bucket cache.  Regions share the owning executor's pool,
    launch policy and config; everything shape- or body-specific lives here.
    """

    __slots__ = ("signature", "batched_fn", "ring", "queue", "compiled",
                 "host_jit", "gather_jit", "stats")

    def __init__(self, signature: TaskSignature, batched_fn: Callable,
                 donate: bool):
        self.signature = signature
        self.batched_fn = batched_fn
        self.ring: Optional[SlotRing] = None
        self.queue: List[_Pending] = []
        self.compiled: Dict[Tuple, Callable] = {}
        # shared shape-polymorphic wrappers (jit re-specializes per shape,
        # so ONE wrapper serves every bucket / parent shape)
        self.host_jit = jax.jit(batched_fn,
                                donate_argnums=(0,) if donate else ())
        self.gather_jit = jax.jit(self._apply_gathered)
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {}}

    # -- bucketed programs -------------------------------------------------
    def _apply_gathered(self, idx, *parents):
        """Index-batched staging: one gather feeds the aggregation body."""
        return self.batched_fn(*(jnp.take(p, idx, axis=0) for p in parents))

    def _apply_ring_prefix(self, bucket: int, start, *rings):
        """Ring staging: the bucket reads a zero-copy view of the filled
        prefix [start, start+bucket) straight out of the slot ring."""
        sliced = tuple(jax.lax.dynamic_slice_in_dim(r, start, bucket, axis=0)
                       for r in rings)
        return self.batched_fn(*sliced)

    # -- compilation cache -------------------------------------------------
    # Each bucket size is a genuinely distinct XLA program (static shapes),
    # cached under ("ring"|"host"|"prefix", bucket) — plus parent-shape-keyed
    # AOT entries ("gather"|"prefix_aot", bucket, parent_shapes) installed by
    # ``AggregationExecutor.warmup(parent_shapes=...)``.
    def compiled_for(self, bucket: int, mode: str = "ring") -> Callable:
        key = (mode, bucket)
        fn = self.compiled.get(key)
        if fn is None:
            if mode in ("ring", "prefix"):
                fn = jax.jit(partial(self._apply_ring_prefix, bucket))
            else:
                fn = self.host_jit
            self.compiled[key] = fn
        return fn

    def ensure_ring(self, capacity: int,
                    example_args: Sequence[Any]) -> SlotRing:
        if self.ring is None:
            self.ring = SlotRing(capacity, example_args)
        return self.ring


class AggregationExecutor:
    """Aggregates submissions of *kernel families* into bucketed launches.

    A registry of aggregation regions keyed by :class:`TaskSignature` lets
    tasks of different kernels AND different shapes coexist: each family
    gets its own slot ring, queue and compiled buckets, while the launch
    policy, executor pool and statistics are shared.  ``flush`` drains the
    live regions round-robin, so families interleave on the device instead
    of serializing.

    Parameters
    ----------
    batched_fn : callable, optional
        ``batched_fn(*stacked_args) -> stacked_out`` where every arg/out has
        a leading slot axis.  Registered as the default kernel family under
        ``name``; further families via :meth:`register`.  The body is one
        traced function shared by all its aggregated tasks (SGMT by
        construction), and serves every task shape submitted to it (each
        distinct shape opens its own region over the same body).
    config : AggregationConfig
        ``max_aggregated`` caps the bucket size (the paper's second launch
        criterion); ``n_executors`` sizes the underlying executor pool
        (combining strategy 3 with strategy 2, as the paper's best rows do);
        ``staging`` selects device-resident (slot ring / indexed gather) or
        the seed's host staging.
    """

    def __init__(self, batched_fn: Optional[Callable] = None,
                 config: Optional[AggregationConfig] = None,
                 pool: Optional[ExecutorPool] = None,
                 buffer_pool: Optional[BufferPool] = None,
                 donate: bool = False,
                 name: str = "region"):
        self.name = name
        self.config = config or AggregationConfig()
        self.pool = pool or ExecutorPool(self.config.n_executors)
        self.buffers = buffer_pool or DEFAULT_POOL
        self._buckets = tuple(sorted(self.config.bucket_sizes()))
        self._donate = donate
        self._staging = getattr(self.config, "staging", "device")
        if self._staging not in ("device", "host"):
            raise ValueError(f"unknown staging mode {self._staging!r}")
        self._bodies: Dict[str, Callable] = {}
        self._regions: Dict[TaskSignature, _Region] = {}
        self._default_kernel: Optional[str] = None
        # per-kernel routing cache for SlotView waves: kernel -> (parents,
        # sig).  A wave's submissions share one parent set per family, so
        # identity-comparing the parents skips the per-task signature
        # rebuild on the hot path — keyed per kernel so interleaved
        # multi-family waves (e.g. hydro + gravity) don't thrash it.
        self._sig_cache: Dict[str, Tuple[Tuple[Any, ...], TaskSignature]] = {}
        # statistics for the benchmark tables; per-family bucket histograms
        # live under "regions" (the multi-signature observability surface)
        self.stats = {"submitted": 0, "launches": 0, "aggregated_hist": {},
                      "staging_s": 0.0, "regions": {}}
        if batched_fn is not None:
            self.register(name, batched_fn)

    # -- region registry ---------------------------------------------------
    def register(self, kernel: str, batched_fn: Callable,
                 default: bool = False) -> str:
        """Register a kernel family's batched body.  The first registration
        (or ``default=True``) becomes the default for untagged submissions.
        Regions themselves are opened lazily, one per task signature."""
        if kernel in self._bodies and self._bodies[kernel] is not batched_fn:
            raise ValueError(
                f"kernel {kernel!r} already registered with a different body")
        self._bodies[kernel] = batched_fn
        if default or self._default_kernel is None:
            self._default_kernel = kernel
        return kernel

    def _region_for(self, kernel: str, args: Sequence[Any]) -> _Region:
        sig = TaskSignature.from_args(kernel, args)
        region = self._regions.get(sig)
        if region is None:
            body = self._bodies.get(kernel)
            if body is None:
                raise KeyError(f"no batched body registered for kernel "
                               f"{kernel!r} (have {sorted(self._bodies)})")
            region = _Region(sig, body, self._donate)
            self._regions[sig] = region
            self.stats["regions"][sig.describe()] = region.stats
        return region

    def _region_for_views(self, kernel: str,
                          views: Sequence[SlotView]) -> _Region:
        """Region routing for all-SlotView submissions, cached on the
        parent-set identity (strong refs keep ids valid)."""
        parents = tuple(v.parent for v in views)
        c = self._sig_cache.get(kernel)
        if (c is not None and len(c[0]) == len(parents)
                and all(a is b for a, b in zip(c[0], parents))):
            region = self._regions.get(c[1])
            if region is not None:
                return region
        region = self._region_for(kernel, views)
        self._sig_cache[kernel] = (parents, region.signature)
        return region

    def _resolve_kernel(self, kernel: Optional[str]) -> str:
        kernel = kernel or self._default_kernel
        if kernel is None:
            raise RuntimeError("no kernel family registered — pass "
                               "batched_fn to the constructor or register()")
        return kernel

    @property
    def regions(self) -> Dict[TaskSignature, "_Region"]:
        """Live region registry (read-only view)."""
        return dict(self._regions)

    # -- single-region compatibility views --------------------------------
    def _sole_region(self) -> Optional[_Region]:
        if len(self._regions) == 1:
            return next(iter(self._regions.values()))
        return None

    @property
    def ring(self) -> Optional[SlotRing]:
        region = self._sole_region()
        return region.ring if region is not None else None

    @property
    def _queue(self) -> List[_Pending]:
        out: List[_Pending] = []
        for region in self._regions.values():
            out.extend(region.queue)
        return out

    @property
    def _compiled(self) -> Mapping[Tuple, Callable]:
        """Read-only view of the compiled-program caches (merged across
        regions); write through ``region.compiled`` instead — a write to
        this view would silently vanish in the multi-region case."""
        region = self._sole_region()
        if region is not None:
            return MappingProxyType(region.compiled)
        merged: Dict[Tuple, Callable] = {}
        for region in self._regions.values():
            merged.update(region.compiled)
        return MappingProxyType(merged)

    # -- warmup ------------------------------------------------------------
    def warmup(self, example_args: Optional[Tuple[Any, ...]] = None, *,
               kernel: Optional[str] = None,
               parent_shapes: Optional[Sequence[Any]] = None) -> None:
        """AOT pre-compile every bucket size (amortized startup, like stream
        pre-allocation in CPPuddle).

        Buckets are lowered with ``.lower().compile()`` — no example
        execution, no broadcast staging, and no tracer hit on the first
        real submission.  Two modes, combinable:

        * ``example_args`` — per-task example inputs; pre-compiles the slot
          ring (device staging) or host-stacked (host staging) buckets.
        * ``parent_shapes`` — shapes/dtypes of the parent arrays that
          ``submit_indexed`` will reference (arrays or ShapeDtypeStructs);
          pre-compiles the indexed-gather AND contiguous-prefix programs
          those submissions hit, closing the gather-mode warmup gap
          (DESIGN.md §6 -> §7).
        """
        kernel = self._resolve_kernel(kernel)
        if parent_shapes is not None:
            parents = tuple(jax.ShapeDtypeStruct(tuple(p.shape), p.dtype)
                            for p in parent_shapes)
            task_specs = tuple(jax.ShapeDtypeStruct(p.shape[1:], p.dtype)
                               for p in parents)
            region = self._region_for(kernel, task_specs)
            pk = tuple(tuple(p.shape) for p in parents)
            start = jax.ShapeDtypeStruct((), jnp.int32)
            n_parent = min(p.shape[0] for p in parents)
            for b in (b for b in self._buckets if b <= n_parent):
                idx = jax.ShapeDtypeStruct((b,), jnp.int32)
                region.compiled[("gather", b, pk)] = jax.jit(
                    region._apply_gathered).lower(idx, *parents).compile()
                region.compiled[("prefix_aot", b, pk)] = jax.jit(
                    partial(region._apply_ring_prefix, b)).lower(
                        start, *parents).compile()
            if example_args is None:
                return
        if example_args is None:
            raise ValueError("warmup needs example_args and/or parent_shapes")
        region = self._region_for(kernel, example_args)
        specs = [jax.ShapeDtypeStruct(tuple(np.shape(a)),
                                      getattr(a, "dtype", None)
                                      or jnp.asarray(a).dtype)
                 for a in example_args]
        start = jax.ShapeDtypeStruct((), jnp.int32)
        if self._staging == "device":
            ring = region.ensure_ring(self.config.max_aggregated,
                                      example_args)
            ring_specs = [jax.ShapeDtypeStruct(r.shape, r.dtype)
                          for r in ring.buffers()]
            for b in self._buckets:
                fn = jax.jit(partial(region._apply_ring_prefix, b))
                region.compiled[("ring", b)] = fn.lower(
                    start, *ring_specs).compile()
        else:
            for b in self._buckets:
                stacked = tuple(
                    jax.ShapeDtypeStruct((b,) + s.shape, s.dtype)
                    for s in specs)
                region.compiled[("host", b)] = region.host_jit.lower(
                    *stacked).compile()

    # -- submission API ----------------------------------------------------
    def submit(self, *args, kernel: Optional[str] = None) -> TaskFuture:
        """Queue one task, routed to its signature's region.  Args are
        either concrete per-task arrays (staged into the region's slot ring)
        or all :class:`SlotView` references (staged by a single gather at
        launch)."""
        kernel = self._resolve_kernel(kernel)
        fut = TaskFuture()
        is_ref = bool(args) and all(isinstance(a, SlotView) for a in args)
        if is_ref and self._staging == "device":
            region = self._region_for_views(kernel, args)
            if any(v.index != args[0].index for v in args[1:]):
                raise ValueError(
                    "SlotView args of one task must share one index — a "
                    "launch gathers the SAME slot from every parent "
                    "(use submit_indexed)")
            entry = _Pending(future=fut, views=tuple(args))
        elif self._staging == "host" or not args:
            region = self._region_for(kernel, args)
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            entry = _Pending(future=fut, args=args)
        else:
            region = self._region_for(kernel, args)
            args = tuple(a.parent[a.index] if isinstance(a, SlotView) else a
                         for a in args)
            t0 = time.perf_counter()
            ring = region.ensure_ring(self.config.max_aggregated, args)
            if ring.fill >= ring.capacity:
                # watermark remainders left a partial prefix consumed; slide
                # the live tail to the front (one fused device op)
                first = region.queue[0].slot if region.queue else ring.fill
                ring.compact(first)
                for p in region.queue:
                    p.slot -= first
            entry = _Pending(future=fut, slot=ring.write(args))
            self.stats["staging_s"] += time.perf_counter() - t0
        self._check_mode(region, entry)
        region.queue.append(entry)
        self.stats["submitted"] += 1
        region.stats["submitted"] += 1
        self._maybe_launch()
        return fut

    def submit_indexed(self, parents: Tuple[jax.Array, ...], index: int,
                       kernel: Optional[str] = None) -> TaskFuture:
        """Sugar: submit task ``i`` whose j-th arg is ``parents[j][i]``."""
        return self.submit(*(SlotView(p, index) for p in parents),
                           kernel=kernel)

    def _check_mode(self, region: _Region, entry: _Pending) -> None:
        """A bucket must stage uniformly: same mode, and for ref entries the
        same parent arrays (a launch gathers from ONE parent set).  Launch
        the region's queue before admitting an incompatible entry."""
        if not region.queue:
            return
        head = region.queue[0]
        compatible = self._entry_mode(head) == self._entry_mode(entry)
        if compatible and entry.views is not None:
            compatible = all(a.parent is b.parent
                             for a, b in zip(head.views, entry.views))
        if not compatible:
            while region.queue:
                self._launch(region, self._largest_bucket(len(region.queue)))

    @staticmethod
    def _entry_mode(entry: _Pending) -> str:
        if entry.views is not None:
            return "ref"
        if entry.args is not None:
            return "host"
        return "ring"

    def _maybe_launch(self) -> None:
        """The paper's launch policy, per region: launch when (a) the cap is
        reached, or (b) an underlying executor is idle; otherwise keep
        aggregating.  Regions progress independently — a full family never
        stalls behind another family's partial queue."""
        progress = True
        while progress:
            progress = False
            for region in self._regions.values():
                q = len(region.queue)
                if q >= self.config.max_aggregated:
                    self._launch(region, self.config.max_aggregated)
                    progress = True
                elif (q >= self.config.launch_watermark
                      and self.pool.any_idle()):
                    self._launch(region, self._largest_bucket(q))
                    progress = True

    def _largest_bucket(self, k: int) -> int:
        best = self._buckets[0]
        for b in self._buckets:
            if b <= k:
                best = b
        return best

    def _launch(self, region: _Region, k: int) -> None:
        tasks, region.queue = region.queue[:k], region.queue[k:]
        mode = self._entry_mode(tasks[0])
        t0 = time.perf_counter()
        if mode == "ref":
            indices = [t.views[0].index for t in tasks]
            parents = tuple(v.parent for v in tasks[0].views)
            pk = tuple(tuple(p.shape) for p in parents)
            if indices == list(range(indices[0], indices[0] + k)):
                # contiguous slot run: one dynamic slice of the parent (the
                # parent IS the ring) — no gather, no index array
                fn = (region.compiled.get(("prefix_aot", k, pk))
                      or region.compiled_for(k, "prefix"))
                call_args = (jnp.int32(indices[0]),) + parents
            else:
                idx = jnp.asarray(indices, jnp.int32)
                fn = (region.compiled.get(("gather", k, pk))
                      or region.gather_jit)
                call_args = (idx,) + parents
        elif mode == "ring":
            fn = region.compiled_for(k, "ring")
            call_args = (jnp.int32(tasks[0].slot),) + region.ring.buffers()
        else:
            stacked = []
            for j in range(len(tasks[0].args)):
                parts = [t.args[j] for t in tasks]
                if k == 1:
                    stacked.append(jnp.asarray(parts[0])[None])
                elif isinstance(parts[0], jax.Array):
                    stacked.append(jnp.stack(parts))
                else:
                    stacked.append(jnp.asarray(self.buffers.stage(parts)))
            fn = region.compiled.get(("host", k), region.host_jit)
            call_args = tuple(stacked)
        self.stats["staging_s"] += time.perf_counter() - t0
        exe = self.pool.get()
        out = exe.launch(fn, *call_args, family=region.signature.kernel)
        for slot, t in enumerate(tasks):
            t.future._fulfil(out, slot)
        if mode == "ring" and not region.queue:
            region.ring.swap()    # in-flight launch keeps the old buffer
        self.stats["launches"] += 1
        hist = self.stats["aggregated_hist"]
        hist[k] = hist.get(k, 0) + 1
        region.stats["launches"] += 1
        rhist = region.stats["aggregated_hist"]
        rhist[k] = rhist.get(k, 0) + 1

    def flush(self) -> None:
        """Launch everything still queued (greedy buckets) and drain.
        Live regions are drained round-robin — one launch per family per
        pass — so interleaved families pipeline on the device."""
        live = [r for r in self._regions.values() if r.queue]
        while live:
            for region in live:
                if region.queue:
                    self._launch(region,
                                 self._largest_bucket(len(region.queue)))
            live = [r for r in live if r.queue]
        self.pool.drain()
        # the routing cache holds strong refs to the last wave's parent
        # arrays; the wave is over, release them (next wave re-primes)
        self._sig_cache.clear()

    def map(self, task_args: Sequence[Tuple[Any, ...]],
            kernel: Optional[str] = None) -> List[Any]:
        """Submit many tasks, flush, return their results in order."""
        futs = [self.submit(*a, kernel=kernel) for a in task_args]
        self.flush()
        return [f.result() for f in futs]


# ---------------------------------------------------------------------------
# Region API — the paper's "aggregation region" (a marked code region that
# compatible tasks may enter together).  Cosmetic sugar over the executor.
# ---------------------------------------------------------------------------

_REGIONS: Dict[str, AggregationExecutor] = {}


def aggregation_region(name: str, batched_fn: Callable,
                       config: Optional[AggregationConfig] = None,
                       **kw) -> AggregationExecutor:
    """Get-or-create the named region's executor (one Executor Pool per
    aggregation region, as in the paper's CPPuddle implementation)."""
    exe = _REGIONS.get(name)
    if exe is None:
        exe = AggregationExecutor(batched_fn, config or AggregationConfig(),
                                  name=name, **kw)
        _REGIONS[name] = exe
    return exe


def reset_regions() -> None:
    _REGIONS.clear()
