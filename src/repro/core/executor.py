"""Device executors and the pre-allocated executor pool (CPPuddle analogue).

A ``DeviceExecutor`` is the TPU/XLA analogue of one GPU stream: a handle that
tracks its in-flight launches so the aggregation layer can ask "is this
executor busy?" — the paper's launch criterion for strategy 3.  Under XLA,
dispatch is asynchronous (enqueue returns immediately); an executor is busy
while any of its enqueued launches has not yet produced ready buffers.

The ``ExecutorPool`` mirrors CPPuddle's pre-allocated pool: created once at
startup (stream/executor creation at runtime would synchronize a GPU device;
under XLA the analogous cost is re-tracing/compilation, which the pool also
caches), handed out round-robin or by load.

Hardware-adaptation note (DESIGN.md §2): XLA:TPU runs one kernel at a time
per core, so executors do not add device-side concurrency the way CUDA
streams can on an A100.  They still pipeline host dispatch against device
execution — exactly the regime in which the paper found strategy 2 to be
insufficient on MI100, which we reproduce on this third runtime.
"""
from __future__ import annotations

import itertools
import time
from typing import Any, Callable, List, Optional, Sequence

import jax


def _is_ready(x) -> bool:
    """True if a jax array's backing buffer is available (non-blocking)."""
    try:
        return bool(x.is_ready())
    except AttributeError:          # non-jax leaf (python scalar etc.)
        return True


def _is_deleted(x) -> bool:
    """True if a tracked buffer was donated to (consumed by) a later
    launch — e.g. an s2 scatter-ring carry.  Such a buffer is not
    waitable, and needn't be: the chain's liveness rides on the NEWEST
    buffer, which is tracked too."""
    try:
        return bool(x.is_deleted())
    except AttributeError:
        return False


class DeviceExecutor:
    """One launch queue.  Tracks outstanding results for busy-detection."""

    def __init__(self, index: int, max_inflight_tracked: int = 64):
        self.index = index
        self._inflight: List[Any] = []
        self._max_tracked = max_inflight_tracked
        self.launches = 0           # statistics
        self.launches_by_family: dict = {}   # kernel-family tag -> count
        self.dispatch_s = 0.0       # host time spent enqueueing launches

    def launch(self, fn: Callable, *args, family: Optional[str] = None) -> Any:
        """Enqueue fn(*args) (async under XLA) and track its outputs.

        ``family`` tags the launch with its kernel family (TaskSignature
        kernel id) so interleaved multi-region dispatch is observable.

        A raising ``fn`` must leave the executor consistent: the host time
        spent before the raise still lands in ``dispatch_s`` (the overhead
        was paid), while the launch counters and in-flight tracking only
        record launches that actually enqueued — a failed dispatch must
        not make ``busy()``/``drain()`` wait on buffers that don't exist.
        """
        t0 = time.perf_counter()
        try:
            out = fn(*args)
        finally:
            self.dispatch_s += time.perf_counter() - t0
        self.launches += 1
        if family is not None:
            self.launches_by_family[family] = \
                self.launches_by_family.get(family, 0) + 1
        leaves = jax.tree_util.tree_leaves(out)
        if leaves:
            self._inflight.append(leaves[-1])
            if len(self._inflight) > self._max_tracked:
                self._inflight = self._inflight[-self._max_tracked:]
        return out

    def busy(self) -> bool:
        self._inflight = [x for x in self._inflight
                          if not _is_deleted(x) and not _is_ready(x)]
        return bool(self._inflight)

    def drain(self) -> None:
        """Block until every tracked launch is ready.  XLA surfaces
        device-side failures at block time, not at enqueue — so a drain
        must not stop at (or silently swallow) the first bad buffer:
        every buffer is waited on, tracking is always cleared, and the
        FIRST deferred error is re-raised."""
        first: Optional[BaseException] = None
        for x in self._inflight:
            if _is_deleted(x):          # donated to a later launch: skip
                continue
            try:
                jax.block_until_ready(x)
            except Exception as e:      # deferred device-side error
                if first is None:
                    first = e
        self._inflight.clear()
        if first is not None:
            raise first


class ExecutorPool:
    """Pre-allocated pool of executors with round-robin / least-loaded
    scheduling (CPPuddle's ``executor_pool`` analogue)."""

    def __init__(self, n_executors: int = 1, scheduling: str = "round_robin"):
        assert n_executors >= 1
        self.executors = [DeviceExecutor(i) for i in range(n_executors)]
        self.scheduling = scheduling
        self._rr = itertools.cycle(range(n_executors))

    def __len__(self) -> int:
        return len(self.executors)

    def get(self) -> DeviceExecutor:
        if self.scheduling == "load":
            idle = [e for e in self.executors if not e.busy()]
            if idle:
                return idle[0]
        return self.executors[next(self._rr)]

    def any_idle(self) -> bool:
        return any(not e.busy() for e in self.executors)

    def drain(self) -> None:
        """Drain every executor; the first deferred error surfaces after
        ALL executors have been drained (no half-drained pool)."""
        first: Optional[BaseException] = None
        for e in self.executors:
            try:
                e.drain()
            except Exception as err:
                if first is None:
                    first = err
        if first is not None:
            raise first

    @property
    def total_launches(self) -> int:
        return sum(e.launches for e in self.executors)

    @property
    def total_dispatch_s(self) -> float:
        """Aggregate host dispatch wall time (the launch-overhead metric
        reported by benchmarks/launch_overhead.py)."""
        return sum(e.dispatch_s for e in self.executors)

    @property
    def launches_by_family(self) -> dict:
        """Pool-wide launch counts per kernel family tag."""
        out: dict = {}
        for e in self.executors:
            for k, v in e.launches_by_family.items():
                out[k] = out.get(k, 0) + v
        return out
