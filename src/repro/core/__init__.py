"""The paper's primary contribution: task-based work aggregation for TPU.

* ``executor``    — device executors + pre-allocated pool (streams analogue)
* ``buffers``     — recycled staging slabs (CPPuddle allocator analogue)
* ``aggregation`` — the on-the-fly explicit work-aggregation executor (S3),
                    a multi-region runtime keyed by ``TaskSignature``
* ``faults``      — deterministic fault injection + the error taxonomy
                    behind the ``guard="finite"`` containment path
                    (DESIGN.md §11)
* ``scenario``    — the Scenario plugin protocol: declarative workloads
                    (uniform Sedov, two-level AMR, hydro+gravity) exposing
                    kernel families, task populations and fused references
* ``strategies``  — the Strategy plugin registry (s2 / s3 / s2+s3 / fused)
                    and the single ``StrategyRunner`` facade that drives
                    any scenario under any strategy — including
                    cross-solver aggregation of several kernel families
                    through one executor
* ``tunestore``   — persistent warm start (DESIGN.md §13): the on-disk
                    TuneStore of measured tuning state + the analytical
                    RooflinePrior that seeds first-contact ladders
"""
from repro.core.aggregation import (
    AggregationExecutor, BucketCostModel, RangeFuture, SlotView, TaskFuture,
    TaskSignature, aggregation_region, derive_ladder, gather_futures,
    greedy_launches, ladder_candidates, reset_regions,
)
from repro.core.buffers import DEFAULT_POOL, BufferPool, SlotRing
from repro.core.executor import DeviceExecutor, ExecutorPool
from repro.core.faults import (
    BucketCompileError, FaultError, FaultInjector, FaultSpec,
    LaunchFaultError, NonFiniteStateError, QuarantineList, RegionFaultError,
    TaskFailedError, all_finite,
)
from repro.core.scenario import (
    AMRSedovScenario, GravityScenario, KernelFamily, Scenario,
    TaskPopulation, UniformSedovScenario, stage_family, xla_task_body,
)
from repro.core.strategies import (
    AMRStrategyRunner, HydroStrategyRunner, RunContext, Strategy,
    StrategyRunner, available_strategies, register_strategy,
)
from repro.core.tunestore import RooflinePrior, TuneStore, TuneStoreWarning

__all__ = [
    "AggregationExecutor", "BucketCostModel", "RangeFuture", "SlotView",
    "TaskFuture", "TaskSignature", "aggregation_region", "derive_ladder",
    "gather_futures", "greedy_launches", "ladder_candidates",
    "reset_regions",
    "BufferPool", "DEFAULT_POOL", "SlotRing", "DeviceExecutor", "ExecutorPool",
    "FaultError", "FaultSpec", "FaultInjector", "BucketCompileError",
    "LaunchFaultError", "TaskFailedError", "RegionFaultError",
    "NonFiniteStateError", "QuarantineList", "all_finite",
    "Scenario", "KernelFamily", "TaskPopulation", "stage_family",
    "UniformSedovScenario", "AMRSedovScenario", "GravityScenario",
    "Strategy", "RunContext", "StrategyRunner",
    "available_strategies", "register_strategy",
    "AMRStrategyRunner", "HydroStrategyRunner", "xla_task_body",
    "TuneStore", "TuneStoreWarning", "RooflinePrior",
]
