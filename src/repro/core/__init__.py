"""The paper's primary contribution: task-based work aggregation for TPU.

* ``executor``    — device executors + pre-allocated pool (streams analogue)
* ``buffers``     — recycled staging slabs (CPPuddle allocator analogue)
* ``aggregation`` — the on-the-fly explicit work-aggregation executor (S3),
                    a multi-region runtime keyed by ``TaskSignature``
* ``strategies``  — S1/S2/S3/fused strategy runners over the hydro tasks,
                    uniform-grid and two-level AMR
"""
from repro.core.aggregation import (
    AggregationExecutor, SlotView, TaskFuture, TaskSignature,
    aggregation_region, gather_futures, reset_regions,
)
from repro.core.buffers import DEFAULT_POOL, BufferPool, SlotRing
from repro.core.executor import DeviceExecutor, ExecutorPool
from repro.core.strategies import (
    AMRStrategyRunner, HydroStrategyRunner, xla_task_body,
)

__all__ = [
    "AggregationExecutor", "SlotView", "TaskFuture", "TaskSignature",
    "aggregation_region", "gather_futures", "reset_regions",
    "BufferPool", "DEFAULT_POOL", "SlotRing", "DeviceExecutor", "ExecutorPool",
    "AMRStrategyRunner", "HydroStrategyRunner", "xla_task_body",
]
