"""The on-disk tuning store (DESIGN.md §13): schema-versioned, atomically
written JSON that round-trips everything a warm process needs to reach
tuned steady state without a single measurement launch — per-family
``BucketCostModel`` tables (every execution path: s3 buckets, s2 coalesce
widths, fused waves), derived bucket ladders, ``inner_chunk`` choices,
the per-family ``selected_strategy``/``strategy_costs`` verdicts, and the
observed queue histograms the flush policies key on.

Keying (staleness = a key mismatch, never a guess):

* the file is valid only for ONE ``(schema, code salt)`` pair — the salt
  hashes the tuning-relevant sources, so measurements taken by different
  code are ignored wholesale (they may describe programs that no longer
  exist);
* each entry is keyed ``backend|device_kind|TaskSignature.describe()`` —
  the same identity the in-process memoes use (``_backend_key``), so a
  table timed on one device can never warm-start another;
* the payload carries a content hash; a truncated or hand-edited file
  fails closed (a warning and a cold start, never a crash and never a
  silently wrong ladder).

Writes go through a same-directory temp file + ``os.replace`` so a
concurrent reader sees either the old store or the new one, never a
torn JSON.  The store directory also hosts the JAX persistent
compilation-cache dir (``xla-cache/``), so one ``tune_store=`` knob
removes both re-measurement AND re-compilation from process two.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import warnings
from typing import Any, Dict, Optional, Tuple

SCHEMA_VERSION = 1
STORE_FILENAME = "tunestore.json"
XLA_CACHE_DIRNAME = "xla-cache"

# env var consulted when no explicit ``tune_store`` path is configured —
# the production-serving knob: point every process of a deployment at one
# shared directory (documented in README "Warm start")
STORE_ENV_VAR = "REPRO_TUNE_STORE"

_SALT_SOURCES = ("aggregation.py",)   # relative to repro/core
_code_salt_memo: Optional[str] = None

# process-global set of cache dirs already handed to jax.config — the
# compilation cache dir is process-wide state; flipping it per executor
# would thrash the cache without buying anything
_COMPILE_CACHE_ENABLED: set = set()


def code_salt() -> str:
    """Hash of the tuning-relevant sources (the aggregation runtime and
    this module): measured choices describe compiled programs, so a store
    written by a different code version is stale by definition."""
    global _code_salt_memo
    if _code_salt_memo is None:
        h = hashlib.blake2b(digest_size=8)
        here = os.path.dirname(os.path.abspath(__file__))
        core = os.path.dirname(here)
        for path in [os.path.join(core, s) for s in _SALT_SOURCES] + [
                os.path.abspath(__file__)]:
            try:
                with open(path, "rb") as f:
                    h.update(f.read())
            except OSError:
                h.update(path.encode())
        _code_salt_memo = h.hexdigest()
    return _code_salt_memo


def entry_key(backend_key: Tuple[str, str], family: str) -> str:
    """``backend|device_kind|TaskSignature.describe()`` — the identity a
    stored tuning entry is valid for (mirrors the in-process memo key)."""
    backend, device_kind = backend_key
    return f"{backend}|{device_kind}|{family}"


def _content_hash(entries: Dict[str, Any]) -> str:
    blob = json.dumps(entries, sort_keys=True,
                      separators=(",", ":")).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


class TuneStoreWarning(UserWarning):
    """A store file was unusable (corrupt, stale schema/salt, bad hash);
    the process falls back to cold-start measurement."""


class TuneStore:
    """One warm-start store rooted at a directory.

    ``load()`` is fail-closed: any structural problem (unparsable JSON,
    missing keys, schema/salt mismatch, content-hash mismatch) degrades
    to an empty entry table with a :class:`TuneStoreWarning` — a warm
    start is an optimization, never a correctness dependency.
    ``save()`` is atomic (temp file + rename) and keyed writes merge
    into whatever valid entries the file already holds, so concurrent
    processes tuning DIFFERENT families do not clobber each other's
    last-writer entries wholesale.
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(str(root))
        self.path = os.path.join(self.root, STORE_FILENAME)
        self._entries: Dict[str, Dict[str, Any]] = {}
        self._loaded = False

    # -- construction ------------------------------------------------------
    @classmethod
    def open(cls, spec: Any) -> Optional["TuneStore"]:
        """Resolve a config knob into a store: an existing
        :class:`TuneStore` passes through, a path string opens one, and
        ``None`` consults the ``REPRO_TUNE_STORE`` env var (unset env →
        no store, the cold-start default)."""
        if spec is None:
            spec = os.environ.get(STORE_ENV_VAR) or None
            if spec is None:
                return None
        if isinstance(spec, TuneStore):
            return spec
        return cls(str(spec))

    # -- persistence -------------------------------------------------------
    def _ensure_loaded(self) -> None:
        if not self._loaded:
            self._entries = self._read_file()
            self._loaded = True

    def _read_file(self) -> Dict[str, Dict[str, Any]]:
        try:
            with open(self.path) as f:
                payload = json.load(f)
        except FileNotFoundError:
            return {}
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as err:
            warnings.warn(
                f"tune store {self.path} is unreadable ({err}) — "
                f"falling back to cold-start measurement",
                TuneStoreWarning, stacklevel=3)
            return {}
        if not isinstance(payload, dict):
            warnings.warn(
                f"tune store {self.path} has a non-object top level — "
                f"ignoring it", TuneStoreWarning, stacklevel=3)
            return {}
        if payload.get("schema") != SCHEMA_VERSION:
            warnings.warn(
                f"tune store {self.path} has schema "
                f"{payload.get('schema')!r} (this code reads "
                f"{SCHEMA_VERSION}) — ignoring it",
                TuneStoreWarning, stacklevel=3)
            return {}
        if payload.get("salt") != code_salt():
            warnings.warn(
                f"tune store {self.path} was written by a different code "
                f"version (salt {payload.get('salt')!r} != {code_salt()!r})"
                f" — its measurements describe programs that no longer "
                f"exist; ignoring it", TuneStoreWarning, stacklevel=3)
            return {}
        entries = payload.get("entries")
        if not isinstance(entries, dict) or not all(
                isinstance(v, dict) for v in entries.values()):
            warnings.warn(
                f"tune store {self.path} has a malformed entry table — "
                f"ignoring it", TuneStoreWarning, stacklevel=3)
            return {}
        if payload.get("hash") != _content_hash(entries):
            warnings.warn(
                f"tune store {self.path} fails its content hash "
                f"(truncated or hand-edited write) — ignoring it",
                TuneStoreWarning, stacklevel=3)
            return {}
        return entries

    def save(self) -> None:
        """Atomic write: merge this process's entries over whatever valid
        entries are on disk, then temp-file + ``os.replace``."""
        os.makedirs(self.root, exist_ok=True)
        self._ensure_loaded()
        with warnings.catch_warnings():
            # a corrupt on-disk file must not block the REPAIRING write
            warnings.simplefilter("ignore", TuneStoreWarning)
            merged = self._read_file()
        merged.update(self._entries)
        self._entries = merged
        payload = {"schema": SCHEMA_VERSION, "salt": code_salt(),
                   "entries": merged, "hash": _content_hash(merged)}
        fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".tunestore-",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- entry access ------------------------------------------------------
    def get(self, backend_key: Tuple[str, str],
            family: str) -> Optional[Dict[str, Any]]:
        """The stored entry for one ``(backend, device_kind)`` + family
        describe key, or None.  Entries under other backend keys are
        simply different keys — a CPU process never sees TPU tables."""
        self._ensure_loaded()
        return self._entries.get(entry_key(backend_key, family))

    def put(self, backend_key: Tuple[str, str], family: str,
            entry: Dict[str, Any]) -> None:
        self._ensure_loaded()
        self._entries[entry_key(backend_key, family)] = entry

    def entries(self) -> Dict[str, Dict[str, Any]]:
        self._ensure_loaded()
        return dict(self._entries)

    def __len__(self) -> int:
        self._ensure_loaded()
        return len(self._entries)

    # -- the compilation half of warm start --------------------------------
    @property
    def xla_cache_dir(self) -> str:
        return os.path.join(self.root, XLA_CACHE_DIRNAME)

    def enable_compilation_cache(self) -> bool:
        """Point JAX's persistent compilation cache at this store's
        ``xla-cache/`` dir, so process two's bucket AOT compiles are disk
        hits instead of XLA recompiles.  Thresholds are dropped to zero —
        bucket programs are small but numerous, which is exactly the
        population the default min-compile-time filter would skip.
        Process-global and idempotent; returns whether the cache is on."""
        if self.xla_cache_dir in _COMPILE_CACHE_ENABLED:
            return True
        try:
            import jax
            os.makedirs(self.xla_cache_dir, exist_ok=True)
            jax.config.update("jax_compilation_cache_dir",
                              self.xla_cache_dir)
            for flag, val in (
                    ("jax_persistent_cache_min_compile_time_secs", 0.0),
                    ("jax_persistent_cache_min_entry_size_bytes", -1)):
                try:
                    jax.config.update(flag, val)
                except (AttributeError, ValueError):
                    pass          # older jax: keep its default thresholds
        except Exception as err:  # cache is an optimization, never fatal
            warnings.warn(
                f"could not enable the JAX persistent compilation cache "
                f"at {self.xla_cache_dir}: {err}",
                TuneStoreWarning, stacklevel=2)
            return False
        _COMPILE_CACHE_ENABLED.add(self.xla_cache_dir)
        return True
