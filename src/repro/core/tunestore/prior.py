"""Analytical roofline prior for unmeasured bucket costs (DESIGN.md §13).

With an empty store, the measured tuner's only option used to be timing
every candidate bucket at warmup.  The prior replaces that first contact
with arithmetic: a bucket-``b`` launch of a kernel family is modeled as

    t(b) = t_launch + max(bytes_moved(b) / BW_peak,  flops(b) / FLOPs_peak)

— the classic roofline, plus the constant per-launch overhead that the
whole aggregation ladder exists to amortize.  ``bytes_moved`` comes from
the family's argument shapes/dtypes (inputs read + ``jax.eval_shape``'d
outputs written, scaled by the bucket); ``flops`` comes from XLA's own
cost analysis of the bucket-1 program when available (one lowering, zero
launches), falling back to a fixed arithmetic-intensity guess.  Device
peaks come from a small table keyed by ``device_kind``; unknown devices
get a measured-once micro-benchmark (one bandwidth op, one matmul, one
empty launch — memoized for the process).

The absolute numbers only need to be roughly right: ``derive_ladder``
consumes RATIOS between bucket sizes, and any model of the form
``overhead + monotone traffic`` already encodes the paper's core fact —
few big launches beat many small ones — which is what makes the
prior-seeded ladder sane before the first real wave.  Every seeded entry
is tagged ``source="prior"`` in the cost model and evicted the moment
``retune()`` measures for real.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

# device_kind substring (lowercased) -> (bytes/s, flop/s, launch seconds).
# Deliberately coarse: sustained streaming numbers, not datasheet peaks,
# because the prior's job is ladder SHAPE, not absolute wall time.  "cpu"
# must stay in this table so CPU-only CI never pays the calibration run.
DEVICE_PEAKS: Dict[str, Tuple[float, float, float]] = {
    "cpu":        (2.0e10, 5.0e10, 2.0e-5),
    "tpu v5":     (8.0e11, 2.0e14, 5.0e-5),
    "tpu v4":     (1.2e12, 2.7e14, 5.0e-5),
    "tpu":        (7.0e11, 1.0e14, 5.0e-5),
    "h100":       (3.0e12, 5.0e14, 1.0e-5),
    "a100":       (1.5e12, 1.5e14, 1.0e-5),
    "gpu":        (8.0e11, 5.0e13, 1.0e-5),
}

# flops per element when XLA's cost analysis is unavailable: a band
# between pure-streaming (≈1) and stencil/PPM-style bodies (tens)
FALLBACK_FLOPS_PER_ELEM = 16.0

# measured-once calibration memo: backend key -> (bw, flops, launch)
_CALIBRATION: Dict[Tuple[str, str], Tuple[float, float, float]] = {}


def _lookup_peaks(device_kind: str) -> Optional[Tuple[float, float, float]]:
    kind = (device_kind or "").lower()
    for key, peaks in DEVICE_PEAKS.items():
        if key in kind:
            return peaks
    return None


def _microbenchmark() -> Tuple[float, float, float]:
    """Measure this device once: streaming bandwidth from a large
    elementwise sum, FLOP throughput from a matmul, launch overhead from
    a no-op-sized program.  Medians of a handful of runs — calibration
    happens once per process per unknown device, so a second of timing
    is acceptable where per-bucket timing at every warmup was not."""
    import jax
    import jax.numpy as jnp

    def timed(fn, *args, runs=5):
        jax.block_until_ready(fn(*args))          # compile + warm
        ts = []
        for _ in range(runs):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]

    n = 1 << 22                                   # 16 MiB of f32
    x = jnp.zeros((n,), jnp.float32)
    t_bw = timed(jax.jit(lambda a: a * 2.0 + 1.0), x)
    bw = (2 * n * 4) / max(t_bw, 1e-9)            # one read + one write

    m = 512
    a = jnp.zeros((m, m), jnp.float32)
    t_mm = timed(jax.jit(lambda p, q: p @ q), a, a)
    flops = (2.0 * m ** 3) / max(t_mm, 1e-9)

    t_launch = timed(jax.jit(lambda s: s + 1.0), jnp.float32(0.0))
    return bw, flops, max(t_launch, 1e-7)


def device_peaks(backend_key: Tuple[str, str]) -> Tuple[float, float, float]:
    """(bytes/s, flop/s, launch seconds) for the keyed device: table hit
    by ``device_kind`` substring, else the memoized micro-benchmark."""
    known = _lookup_peaks(backend_key[1])
    if known is not None:
        return known
    cal = _CALIBRATION.get(backend_key)
    if cal is None:
        cal = _CALIBRATION[backend_key] = _microbenchmark()
    return cal


class RooflinePrior:
    """Seconds-per-launch estimates for one process's device, computed
    from shapes instead of stopwatches.  Stateless apart from per-family
    flop-count and output-spec memos (keyed on the body's identity plus
    the task specs, mirroring the chunk-tune memo's keying rationale)."""

    def __init__(self, backend_key: Optional[Tuple[str, str]] = None):
        if backend_key is None:
            import jax
            try:
                kind = getattr(jax.devices()[0], "device_kind", "")
            except RuntimeError:
                kind = ""
            backend_key = (jax.default_backend(), kind)
        self.backend_key = backend_key
        self.bandwidth, self.peak_flops, self.launch_overhead = \
            device_peaks(backend_key)
        # (body id, task specs) -> (flops per task, out bytes per task);
        # the body ref rides along to keep id() valid (cf. _CHUNK_TUNE_MEMO)
        self._family_memo: Dict[Tuple, Tuple[Any, float, float]] = {}

    # -- per-family analysis -----------------------------------------------
    @staticmethod
    def _spec_key(task_specs: Sequence[Any]) -> Tuple:
        return tuple((tuple(s.shape), np.dtype(s.dtype).str)
                     for s in task_specs)

    @staticmethod
    def _nbytes(shape: Sequence[int], dtype: Any) -> float:
        return float(math.prod(shape) * np.dtype(dtype).itemsize)

    def _analyze_family(self, batched_fn: Any,
                        task_specs: Sequence[Any]) -> Tuple[float, float]:
        """(flops, output bytes) for ONE task of this family."""
        key = (id(batched_fn), self._spec_key(task_specs))
        memo = self._family_memo.get(key)
        if memo is not None:
            return memo[1], memo[2]
        import jax

        b1 = tuple(jax.ShapeDtypeStruct((1,) + tuple(s.shape), s.dtype)
                   for s in task_specs)
        in_elems = sum(math.prod(s.shape) for s in task_specs)
        try:
            out = jax.eval_shape(batched_fn, *b1)
            leaves = jax.tree_util.tree_leaves(out)
            out_bytes = sum(self._nbytes(l.shape, l.dtype) for l in leaves)
            out_elems = sum(math.prod(l.shape) for l in leaves)
        except (TypeError, ValueError):
            # body rejects a bucket-1 batch (e.g. fixed-wave-only fused
            # twin): charge it as write-what-you-read streaming
            out_bytes = sum(self._nbytes(s.shape, s.dtype)
                            for s in task_specs)
            out_elems = in_elems
        flops = self._xla_flops(batched_fn, b1)
        if flops is None:
            flops = FALLBACK_FLOPS_PER_ELEM * max(in_elems, out_elems, 1)
        self._family_memo[key] = (batched_fn, float(flops), out_bytes)
        return float(flops), out_bytes

    @staticmethod
    def _xla_flops(batched_fn: Any, b1_specs: Tuple) -> Optional[float]:
        """XLA's own FLOP count of the bucket-1 program — a lowering plus
        cost analysis, never an execution.  None when the backend or body
        does not support it (the caller then falls back to the
        intensity guess)."""
        import jax
        try:
            analysis = jax.jit(batched_fn).lower(*b1_specs).cost_analysis()
        except Exception:
            return None
        if isinstance(analysis, (list, tuple)):       # older jax returns
            analysis = analysis[0] if analysis else None  # one per device
        if not isinstance(analysis, dict):
            return None
        flops = analysis.get("flops")
        if flops is None or not np.isfinite(flops) or flops < 0:
            return None
        return float(flops)

    # -- the prediction ----------------------------------------------------
    def predict(self, batched_fn: Any, task_specs: Sequence[Any],
                bucket: int) -> float:
        """Predicted seconds for ONE launch of a ``bucket``-task program
        of this family: launch overhead + roofline of the bucket's
        traffic.  Per-task flops/bytes scale linearly in the bucket —
        exact for the elementwise-over-slots bodies aggregation accepts."""
        flops1, out_bytes1 = self._analyze_family(batched_fn, task_specs)
        in_bytes1 = sum(self._nbytes(s.shape, s.dtype) for s in task_specs)
        b = max(1, int(bucket))
        bytes_moved = b * (in_bytes1 + out_bytes1)
        flops = b * flops1
        return self.launch_overhead + max(bytes_moved / self.bandwidth,
                                          flops / self.peak_flops)
