"""Persistent warm-start subsystem (DESIGN.md §13).

Two halves, both feeding the same :class:`~repro.core.aggregation.
BucketCostModel` currency:

* :class:`TuneStore` — the on-disk table of everything a tuned process
  knows (cost tables, ladders, inner chunks, strategy selections), plus
  the JAX persistent-compilation-cache hookup, so process two measures
  nothing and recompiles nothing;
* :class:`RooflinePrior` — the analytical fallback for process ONE, so
  an empty store still yields a sane ladder without zero-fill timing.
"""
from repro.core.tunestore.prior import (
    DEVICE_PEAKS, RooflinePrior, device_peaks,
)
from repro.core.tunestore.store import (
    SCHEMA_VERSION, STORE_ENV_VAR, TuneStore, TuneStoreWarning, code_salt,
    entry_key,
)

__all__ = [
    "DEVICE_PEAKS", "RooflinePrior", "device_peaks",
    "SCHEMA_VERSION", "STORE_ENV_VAR", "TuneStore", "TuneStoreWarning",
    "code_salt", "entry_key",
]
