"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py                 # full run
  PYTHONPATH=src python examples/train_lm.py --steps 30      # shorter
  PYTHONPATH=src python examples/train_lm.py --arch qwen2-moe-a2.7b --reduced

Uses the production trainer (repro.launch.train): same code path that runs
on the multi-pod mesh, here on CPU with a ~100M-class granite-family config.
Checkpoints land in --ckpt-dir and the run resumes from the latest one.
"""
import argparse

from repro.configs import ARCHS, get_config
from repro.launch import train as trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving tiny config instead of ~100M")
    args = ap.parse_args()

    if args.reduced:
        _, _, losses = trainer.train(args.arch, args.steps, args.seq_len,
                                     args.batch, reduced=True,
                                     ckpt_dir=args.ckpt_dir)
    else:
        # ~100M-class config of the chosen family (keeps the family's
        # structure; sized so CPU trains a few hundred steps in minutes)
        import repro.launch.train as t
        from repro.configs import reduced as reduce_cfg
        cfg = get_config(args.arch)
        small = cfg.replace(
            n_layers=min(cfg.n_layers, 8),
            d_model=512, n_heads=8,
            n_kv_heads=min(cfg.n_kv_heads, 8) if cfg.n_kv_heads < cfg.n_heads
            else 8,
            head_dim=64, d_ff=2048 if cfg.d_ff else 0,
            vocab_size=32_768, remat=False, dtype="float32",
            **({"n_experts": 8, "top_k": 2} if cfg.n_experts else {}),
            **({"n_encoder_layers": 4} if cfg.n_encoder_layers else {}),
            **({"cross_attn_every": 4} if cfg.cross_attn_every else {}),
            **({"shared_attn_every": 4} if cfg.shared_attn_every else {}),
            **({"slstm_every": 4} if cfg.slstm_every else {}),
        )
        import repro.configs as C

        # route through the trainer with the custom config
        orig = C.get_config
        try:
            C.get_config = lambda name: small          # noqa
            t.get_config = C.get_config
            _, _, losses = trainer.train(args.arch, args.steps, args.seq_len,
                                         args.batch, reduced=False,
                                         ckpt_dir=args.ckpt_dir)
        finally:
            C.get_config = orig
            t.get_config = orig
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")
    assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
