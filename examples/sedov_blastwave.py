"""The paper's scenario end-to-end: Sedov-Taylor blast wave with selectable
work-aggregation strategy.

  PYTHONPATH=src python examples/sedov_blastwave.py --strategy s2+s3 \
      --executors 4 --max-aggregated 16 --steps 5 [--subgrid 16] [--levels 2]

Prints per-step timing, launch counts, conservation drift, and the shock
radius vs the Sedov similarity law R ~ (E t^2 / rho)^(1/5).
"""
import argparse
import time

import jax.numpy as jnp

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core import StrategyRunner, UniformSedovScenario
from repro.hydro.state import sedov_init
from repro.hydro.stepper import courant_dt, shock_radius, total_conserved


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="s2+s3",
                    choices=("fused", "s2", "s3", "s2+s3", "mixed"))
    ap.add_argument("--executors", type=int, default=4)
    ap.add_argument("--max-aggregated", type=int, default=16)
    ap.add_argument("--subgrid", type=int, default=8)
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    cfg = HydroConfig(subgrid=args.subgrid, ghost=3, levels=args.levels)
    agg = AggregationConfig(strategy=args.strategy,
                            n_executors=args.executors,
                            max_aggregated=args.max_aggregated)
    print(f"Sedov blast wave: {cfg.cells_total} cells, "
          f"{cfg.n_subgrids} sub-grids of {cfg.subgrid}^3, "
          f"strategy={args.strategy} (exec={args.executors}, "
          f"max_agg={args.max_aggregated})")

    st = sedov_init(cfg)
    h = cfg.domain / st.u.shape[-1]
    c0 = total_conserved(st.u, h)
    runner = StrategyRunner(UniformSedovScenario(cfg), agg)

    u, t = st.u, 0.0
    for step in range(args.steps):
        dt = courant_dt(u, cfg)
        t0 = time.perf_counter()
        u = runner.rk3_step(u, dt)
        u.block_until_ready()
        wall = time.perf_counter() - t0
        t += float(dt)
        r = float(shock_radius(u, cfg))
        print(f"step {step + 1}: dt={float(dt):.3e}  t={t:.3e}  "
              f"R_shock={r:.4f}  {wall * 1e3:.0f} ms "
              f"({runner.stats['kernel_launches']} launches total)")

    c1 = total_conserved(u, h)
    print(f"mass drift    : {abs(float((c1[0] - c0[0]) / c0[0])):.2e}")
    print(f"energy drift  : {abs(float((c1[4] - c0[4]) / c0[4])):.2e}")
    print(f"Sedov check   : R ∝ t^0.4 -> R/t^0.4 = "
          f"{float(shock_radius(u, cfg)) / t ** 0.4:.3f} (constant in time)")
    assert not bool(jnp.any(jnp.isnan(u))), "solution went NaN"


if __name__ == "__main__":
    main()
