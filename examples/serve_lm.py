"""Serve a small model with batched requests through the aggregation engine.

  PYTHONPATH=src python examples/serve_lm.py --arch granite-8b --requests 16

Demonstrates the paper's strategy 3 at the serving layer: requests arrive as
fine-grained decode tasks; the engine fuses active requests into bucketed
batched kernels (continuous batching), and reports the aggregation histogram
— how many kernels ran at each bucket size.
"""
import argparse
import time

import jax

from repro.configs import ARCHS, get_config, reduced
from repro.models import model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=args.max_batch, max_len=64)

    reqs = [Request(i, [(3 * i + 1) % cfg.vocab_size,
                        (5 * i + 2) % cfg.vocab_size],
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    # staggered arrival: half now, half mid-flight (continuous batching)
    for r in reqs[: len(reqs) // 2]:
        eng.submit(r)
    t0 = time.perf_counter()
    for _ in range(3):
        eng.step()
    for r in reqs[len(reqs) // 2:]:
        eng.submit(r)
    eng.run()
    wall = time.perf_counter() - t0

    done = sum(r.done for r in reqs)
    print(f"arch={cfg.name} requests={done}/{len(reqs)} "
          f"tokens={eng.stats['tokens']}")
    print(f"throughput : {eng.stats['tokens'] / wall:.1f} tok/s "
          f"(CPU, reduced config)")
    print(f"launches   : {eng.stats['launches']} aggregated kernels "
          f"(vs {eng.stats['tokens']} unaggregated)")
    print(f"buckets    : {eng.stats['aggregated_hist']}")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.output}")


if __name__ == "__main__":
    main()
