"""Quickstart: the aggregation executor in 60 lines.

Fine-grained tasks (here: tiny per-sub-problem stencils) are submitted to an
AggregationExecutor; while the device is busy, compatible tasks fuse into one
bucketed kernel launch — the paper's strategy 3, TPU-native.

Staging is device-resident (DESIGN.md §3): each submission writes its inputs
into a pre-allocated, double-buffered device *slot ring* via a donated
in-place update, and every launch reads a zero-copy prefix view of the
filled slots — no host round-trip on the hot path.  Tasks that are rows of
an existing device array can skip even that via
``exe.submit_indexed((parent,), i)``, which stages a whole bucket with one
gather.  ``AggregationConfig(staging="host")`` selects the legacy
slice→stack→launch cycle for comparison (see
benchmarks/launch_overhead.py), and ``exe.warmup(example_args)``
AOT-compiles every bucket size up front.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import AggregationConfig
from repro.core import AggregationExecutor


def task_body(x):
    """One fine-grained task: a small stencil update (think: one sub-grid)."""
    inner = x[1:-1] * 0.5 + 0.25 * (x[:-2] + x[2:])
    return x.at[1:-1].set(inner)


def main():
    # the batched body is ONE traced function extended over the slot axis —
    # the paper's "Single-workload-Multiple-Tasks" constraint by construction
    batched = jax.vmap(task_body)

    # launch policy: fuse when the executor is busy OR >= watermark tasks
    # are waiting.  (These toy tasks finish instantly, so the busy-criterion
    # alone would never engage — exactly the paper's observation that
    # aggregation kicks in when the device is saturated, not when idle.)
    agg = AggregationConfig(strategy="s3", n_executors=2, max_aggregated=8,
                            launch_watermark=4)
    exe = AggregationExecutor(batched, agg, name="quickstart")

    # submit 30 fine-grained tasks; the executor aggregates on the fly
    futures = [exe.submit(jnp.linspace(0.0, float(i), 64))
               for i in range(30)]
    exe.flush()

    results = [f.result() for f in futures]
    print(f"tasks submitted : {exe.stats['submitted']}")
    print(f"kernel launches : {exe.stats['launches']}")
    print(f"bucket histogram: {exe.stats['aggregated_hist']}")

    # equivalence invariant: identical to unaggregated execution
    for i, r in enumerate(results):
        expect = task_body(jnp.linspace(0.0, float(i), 64))
        assert jnp.array_equal(r, expect)
    print("equivalence: aggregated results identical to per-task execution")


if __name__ == "__main__":
    main()
