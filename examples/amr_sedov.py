"""Two-level AMR Sedov quickstart: the multi-region aggregation runtime.

A coarse grid covers the whole domain; a centred fine patch refines the
blast at 2x resolution.  Every RK3 iteration produces a MIXED task list —
coarse and fine sub-grids, with per-level cell width ``h`` as a traced task
argument — driven through one AggregationExecutor.  With ``--mixed`` the
levels use different sub-grid sizes, so TWO TaskSignature families
aggregate concurrently (distinct rings/buckets, interleaved launches).

Every strategy's result is checked bit-identical to the per-level fused
reference, the equivalence invariant of the aggregation substrate.

  PYTHONPATH=src python examples/amr_sedov.py [--mixed] [--steps N]
"""
import argparse

import numpy as np

from repro.configs.amr_sedov import CONFIG, CONFIG_MIXED
from repro.configs.base import AggregationConfig
from repro.core import AMRSedovScenario, StrategyRunner
from repro.hydro.state import amr_sedov_init
from repro.hydro.stepper import amr_courant_dt, amr_reference_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mixed", action="store_true",
                    help="different per-level sub-grid sizes (two families)")
    ap.add_argument("--steps", type=int, default=1)
    args = ap.parse_args()
    cfg = CONFIG_MIXED if args.mixed else CONFIG
    print(f"{cfg.name}: coarse {cfg.n_coarse}^3 (h={cfg.h_coarse:.4f}) + "
          f"fine {cfg.n_fine}^3 patch (h={cfg.h_fine:.4f}), "
          f"{cfg.n_subgrids_coarse}+{cfg.n_subgrids_fine} tasks/iteration")

    st = amr_sedov_init(cfg)
    dt = amr_courant_dt(st.uc, st.uf, cfg)
    ref_c, ref_f = st.uc, st.uf
    for _ in range(args.steps):
        ref_c, ref_f = amr_reference_step(ref_c, ref_f, dt, cfg)

    for strat, n_exec, max_agg in [("fused", 1, 1), ("s2", 2, 1),
                                   ("s3", 1, 16), ("s2+s3", 4, 16)]:
        agg = AggregationConfig(strategy=strat, n_executors=n_exec,
                                max_aggregated=max_agg,
                                launch_watermark=10 ** 9)
        r = StrategyRunner(AMRSedovScenario(cfg), agg)
        uc, uf = st.uc, st.uf
        for _ in range(args.steps):
            uc, uf = r.rk3_step((uc, uf), dt)
        ok = (np.array_equal(np.asarray(uc), np.asarray(ref_c))
              and np.array_equal(np.asarray(uf), np.asarray(ref_f)))
        fams = ""
        if r.executor is not None:
            hists = {k: v["aggregated_hist"]
                     for k, v in r.executor.stats["regions"].items()}
            fams = f"  families={hists}"
        print(f"  {strat:6s} launches={r.stats['kernel_launches']:4d}  "
              f"bit-identical={ok}{fams}")
        assert ok, f"strategy {strat} diverged from the per-level reference"
    print("all strategies bit-identical to the per-level fused reference")


if __name__ == "__main__":
    main()
