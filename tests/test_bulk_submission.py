"""DESIGN.md §9: bulk range submission, validated/auto-tuned bucket
ladders, and epilogue-fused mega-buckets.

Invariants pinned here:

* a ``submit_range`` wave is ONE queue entry / ONE ``RangeFuture``, drains
  with the exact greedy decomposition, and gathers zero-copy in the
  steady one-launch case;
* ladder validation rejects unsorted/duplicated/non-positive ladders and
  any ladder missing bucket 1 (the (4, 8)-with-3-queued over-launch bug);
* property: for ANY valid ladder and ANY queue length k the greedy drain
  covers k exactly — no padding, no over-launch — and random
  ``submit_range`` + ``submit_indexed`` interleavings gather
  bit-identically to the direct computation;
* the per-region auto-tuner converges a steady k-wave onto a ladder
  containing k (one mega-bucket launch per wave);
* chunked (``inner_chunk``) mega-bucket evaluation is bit-identical to
  flat evaluation;
* the epilogue-fused RK stage path is bit-identical across s3/s2+s3/fused
  and to ``Scenario.reference_stage``, and the legacy runner shims emit
  ``DeprecationWarning``.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import greedy_launches
from hypothesis import given, settings, strategies as st

from repro.configs.base import AggregationConfig, HydroConfig, validate_ladder
# NOTE: greedy_launches comes from conftest — the INDEPENDENT oracle the
# ladder tests compare the production derive_ladder/greedy code against
# (importing repro.core's twin here would make those assertions circular)
from repro.core import (
    AggregationExecutor, RangeFuture, StrategyRunner, UniformSedovScenario,
    derive_ladder, gather_futures,
)
from repro.hydro.state import sedov_init
from repro.hydro.stepper import courant_dt

WM = 10 ** 9
CFG = HydroConfig(subgrid=8, ghost=3, levels=1)


def _affine(x):
    return 2.0 * x + 1.0


# ---------------------------------------------------------------------------
# ladder validation (the _largest_bucket over-launch bugfix)
# ---------------------------------------------------------------------------

def test_ladder_without_bucket_one_rejected():
    with pytest.raises(ValueError) as ei:
        AggregationConfig(buckets=(4, 8), max_aggregated=8).bucket_sizes()
    assert "bucket size 1" in str(ei.value)
    # executor construction fails fast too — a (4, 8) ladder with 3 queued
    # tasks would otherwise launch a 4-bucket over a garbage slot
    with pytest.raises(ValueError):
        AggregationExecutor(jax.vmap(_affine), AggregationConfig(
            buckets=(4, 8), max_aggregated=8))


@pytest.mark.parametrize("bad,frag", [
    ((1, 4, 4), "unique"),
    ((4, 1), "sorted"),
    ((1, 0, 2), "positive"),
    ((1, 64), "exceeds max_aggregated"),
])
def test_ladder_validation_messages(bad, frag):
    with pytest.raises(ValueError) as ei:
        validate_ladder(bad, 32)
    assert frag in str(ei.value)


def test_custom_full_population_ladder_accepted():
    agg = AggregationConfig(buckets=(1, 5, 40), max_aggregated=40)
    assert agg.bucket_sizes() == (1, 5, 40)


# ---------------------------------------------------------------------------
# property: greedy drain covers any k exactly under any valid ladder
# ---------------------------------------------------------------------------

def _random_ladder(rng, cap):
    sizes = {1} | {rng.randint(2, cap) for _ in range(rng.randint(0, 4))}
    return tuple(sorted(sizes))


@given(k=st.integers(1, 48), cap=st.integers(2, 48),
       seed=st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_any_ladder_any_queue_exact_cover_property(k, cap, seed):
    """Greedy decomposition covers k exactly: histogram mass == k (no
    padding), launches == the shared oracle (no over-launch)."""
    ladder = _random_ladder(random.Random(seed), cap)
    cfg = AggregationConfig(strategy="s3", buckets=ladder,
                            max_aggregated=cap, launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(float(k * 3)).reshape(k, 3)
    fut = exe.submit_range((parent,), 0, k)
    exe.flush()
    hist = exe.stats["aggregated_hist"]
    assert sum(b * c for b, c in hist.items()) == k          # exact cover
    assert all(b in ladder for b in hist)                    # ladder only
    # greedy is bounded by the cap at every launch decision
    expect = 0
    q = k
    while q:
        q -= max(b for b in ladder if b <= min(q, cap))
        expect += 1
    assert exe.stats["launches"] == expect
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


@given(n=st.integers(1, 32), max_agg=st.integers(1, 16),
       seed=st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_range_and_indexed_interleavings_gather_bit_identical(n, max_agg,
                                                              seed):
    """ANY random split of a wave into ranges and per-task submissions
    gathers bit-identically to the direct computation, in order."""
    rng = random.Random(seed)
    cfg = AggregationConfig(strategy="s3", max_aggregated=max_agg,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(float(n * 2)).reshape(n, 2)
    futs = []
    i = 0
    while i < n:
        span = rng.randint(1, n - i)
        if span > 1 and rng.random() < 0.7:
            futs.append(exe.submit_range((parent,), i, span))
        else:
            span = 1
            futs.append(exe.submit_indexed((parent,), i))
        i += span
    exe.flush()
    out = gather_futures(futs)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(2.0 * parent + 1.0))
    assert exe.stats["submitted"] == n


# ---------------------------------------------------------------------------
# RangeFuture semantics
# ---------------------------------------------------------------------------

def test_range_is_one_queue_entry_one_future():
    cfg = AggregationConfig(strategy="s3", max_aggregated=16,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(24.0).reshape(8, 3)
    fut = exe.submit_range((parent,), 0, 8)
    assert isinstance(fut, RangeFuture) and len(fut) == 8
    assert len(exe._queue) == 1                 # ONE entry, not 8
    assert exe.stats["submitted"] == 8          # but 8 tasks accounted
    with pytest.raises(RuntimeError):
        fut.result()                            # not launched yet
    exe.flush()
    assert exe.stats["aggregated_hist"] == {8: 1}
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


def test_full_wave_range_gathers_zero_copy():
    """One range covering one launch: gather returns the launch output
    itself — no take, no concat."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(16.0).reshape(8, 2)
    fut = exe.submit_range((parent,), 0, 8)     # cap hit -> launches now
    assert exe.stats["launches"] == 1
    exe.flush()
    out = gather_futures([fut])
    assert out is fut.result()                  # zero-copy: the batch itself


def test_range_split_across_buckets_reassembles_in_order():
    cfg = AggregationConfig(strategy="s3", max_aggregated=4,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(33.0).reshape(11, 3)
    fut = exe.submit_range((parent,), 0, 11)
    exe.flush()
    assert exe.stats["aggregated_hist"] == {4: 2, 2: 1, 1: 1}
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


def test_submit_range_rejects_out_of_bounds():
    """dynamic_slice/take CLAMP out-of-bounds indices — an unchecked range
    would silently compute over the wrong slots, so bounds fail loudly."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=16,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(16.0).reshape(8, 2)
    with pytest.raises(ValueError):
        exe.submit_range((parent,), 4, 8)        # 4..11 of 8 slots
    with pytest.raises(ValueError):
        exe.submit_range((parent,), -1, 4)


def test_range_future_stays_ready_after_result():
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    fut = exe.submit_range((jnp.arange(8.0).reshape(4, 2),), 0, 4)
    assert not fut.ready()
    exe.flush()
    assert fut.ready()
    fut.result()
    assert fut.ready()                           # resolution is sticky


def test_derive_ladder_models_over_cap_waves():
    """A wave larger than the cap drains as cap-bucket + remainder; the
    tuner must keep a bucket covering the remainder, not score the wave
    as one launch."""
    ladder = derive_ladder({100: 5}, cap=64, budget=4)
    assert greedy_launches(100, ladder) == 2     # 64 + 36
    assert 64 in ladder and 36 in ladder


def test_submit_range_requires_device_staging():
    cfg = AggregationConfig(strategy="s3", staging="host",
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    with pytest.raises(ValueError):
        exe.submit_range((jnp.zeros((4, 2)),), 0, 4)


def test_population_submit_to_helper():
    from repro.core import TaskPopulation
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(12.0).reshape(6, 2)
    pop = TaskPopulation("region", (parent,))
    fut = pop.submit_to(exe)
    exe.flush()
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


# ---------------------------------------------------------------------------
# ladder auto-tuning
# ---------------------------------------------------------------------------

def test_derive_ladder_steady_wave_converges_on_mega_bucket():
    ladder = derive_ladder({24: 5}, cap=32, budget=4)
    assert 1 in ladder and 24 in ladder
    assert greedy_launches(24, ladder) == 1


def test_derive_ladder_respects_compile_budget():
    ladder = derive_ladder({3: 1, 7: 1, 13: 1, 24: 1, 31: 1}, cap=32,
                           budget=3)
    assert len(ladder) <= 3 and 1 in ladder


def test_autotuner_retunes_after_warmup_waves():
    cfg = AggregationConfig(strategy="s3", max_aggregated=32,
                            launch_watermark=WM, autotune=True,
                            autotune_warmup=2)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(40.0).reshape(20, 2)
    for _ in range(3):
        exe.submit_range((parent,), 0, 20)
        exe.flush()
    region = next(iter(exe.regions.values()))
    assert region.stats["queue_hist"].get(20, 0) >= 2
    assert 20 in region.buckets                  # tuned onto the wave size
    assert region.stats["ladder"] == list(region.buckets)
    before = exe.stats["launches"]
    fut = exe.submit_range((parent,), 0, 20)
    exe.flush()
    assert exe.stats["launches"] == before + 1   # ONE mega-bucket launch
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


def test_autotuner_rearms_when_wave_outgrows_ladder():
    """Warmup seeing only watermark-drained micro-waves must not pin a
    (1,) ladder forever: a later wave larger than the ladder max re-arms
    the tuner, and the following wave drains bucketed again."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=32,
                            launch_watermark=1, autotune=True,
                            autotune_warmup=2)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    for i in range(3):                    # idle pool -> per-task drains
        exe.submit(jnp.full((2,), float(i)))
        exe.flush()
    region = next(iter(exe.regions.values()))
    assert region.buckets == (1,)         # tuned to the micro-waves
    parent = jnp.arange(64.0).reshape(32, 2)
    exe.submit_range((parent,), 0, 32)    # outgrows the ladder
    exe.flush()
    assert 32 in region.buckets           # re-armed and retuned
    before = exe.stats["launches"]
    fut = exe.submit_range((parent,), 0, 32)
    exe.flush()
    assert exe.stats["launches"] == before + 1   # bucketed again
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


# ---------------------------------------------------------------------------
# chunked mega-bucket evaluation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [2, 4])
def test_inner_chunk_bit_identical_on_hydro(chunk):
    st_ = sedov_init(CFG)
    scn = UniformSedovScenario(CFG)
    ref = scn.reference_rhs(st_.u)
    agg = AggregationConfig(strategy="s3", max_aggregated=CFG.n_subgrids,
                            launch_watermark=WM, inner_chunk=chunk)
    r = StrategyRunner(UniformSedovScenario(CFG), agg)
    out = r.rhs(st_.u)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert r.stats["kernel_launches"] == 1       # still ONE mega-bucket


def test_inner_chunk_non_dividing_falls_back_flat():
    """A chunk that does not divide the bucket must not pad — the program
    falls back to flat evaluation, bit-identically."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=WM, inner_chunk=3)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(14.0).reshape(7, 2)
    fut = exe.submit_range((parent,), 0, 7)
    exe.flush()
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


# ---------------------------------------------------------------------------
# epilogue-fused RK stages
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sedov():
    st_ = sedov_init(CFG)
    dt = courant_dt(st_.u, CFG)
    return st_, dt


def test_epilogue_stage_path_bit_identical_across_strategies(sedov):
    """s3 / s2+s3 epilogue-fused steps == the fused stage reference, bit
    for bit (same traced composition, only batch decomposition differs)."""
    st_, dt = sedov
    scn = UniformSedovScenario(CFG)
    u1 = scn.reference_stage(st_.u, st_.u, dt, 0.0, 1.0)
    ref = scn.reference_stage(st_.u, u1, dt, 0.75, 0.25)
    fused = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="fused", fuse_epilogue=True))
    out_f = fused.rk3_step(st_.u, dt)
    for strategy, n_exec in [("s3", 1), ("s2+s3", 2)]:
        r = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
            strategy=strategy, n_executors=n_exec,
            max_aggregated=CFG.n_subgrids, launch_watermark=WM,
            fuse_epilogue=True, inner_chunk=4))
        out = r.rk3_step(st_.u, dt)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_f))
        # one launch per stage: the whole wave is one mega-bucket
        assert r.stats["kernel_launches"] == 3
        assert r.stats["iterations"] == 3
    # intermediate stage oracle agrees with the runner decomposition
    np.testing.assert_array_equal(
        np.asarray(scn.reference_stage(st_.u, u1, dt, 0.75, 0.25)),
        np.asarray(ref))


def test_epilogue_stage_path_close_to_generic_combine(sedov):
    """The fused-stage step reassociates (~1e-5 rel) vs the eager global
    combine — allclose, never asserted bit-equal across the two forms."""
    st_, dt = sedov
    generic = StrategyRunner(UniformSedovScenario(CFG),
                             AggregationConfig(strategy="fused"))
    ref = generic.rk3_step(st_.u, dt)
    r = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="s3", max_aggregated=CFG.n_subgrids, launch_watermark=WM,
        fuse_epilogue=True))
    out = r.rk3_step(st_.u, dt)
    scale = float(np.max(np.abs(np.asarray(ref))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5 * scale, rtol=1e-5)


def test_s2_ignores_fuse_epilogue_and_falls_back(sedov):
    """A strategy without run_stage silently uses the generic path."""
    st_, dt = sedov
    generic = StrategyRunner(UniformSedovScenario(CFG),
                             AggregationConfig(strategy="s2"))
    ref = generic.rk3_step(st_.u, dt)
    r = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="s2", fuse_epilogue=True))
    out = r.rk3_step(st_.u, dt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_legacy_runner_shims_warn():
    from repro.core import AMRStrategyRunner, HydroStrategyRunner
    from repro.configs.amr_sedov import CONFIG as AMR_CONFIG
    with pytest.warns(DeprecationWarning):
        HydroStrategyRunner(CFG, AggregationConfig(strategy="fused"))
    with pytest.warns(DeprecationWarning):
        AMRStrategyRunner(AMR_CONFIG, AggregationConfig(strategy="fused"))
