import os

# Tests run on the single real CPU device; only launch/dryrun.py forces the
# 512-device host platform (per the dry-run spec, NOT set globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def greedy_launches(q: int, buckets) -> int:
    """Shared oracle: launches the executor's greedy bucket decomposition
    performs for a queue of length q (import from tests as
    ``from conftest import greedy_launches``)."""
    n = 0
    while q:
        b = max(x for x in buckets if x <= q)
        q -= b
        n += 1
    return n

# ---------------------------------------------------------------------------
# hypothesis fallback: the container image ships without `hypothesis`, which
# made test_aggregation.py / test_moe.py fail at collection.  When the real
# package is absent, install a minimal deterministic stand-in (integers
# strategy + @given/@settings) so the property tests still run: strategy
# endpoints first, then seeded random draws.  Remove once the dependency is
# available in CI images.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import functools
    import inspect as _inspect
    import random as _random
    import sys
    import types

    _MAX_EXAMPLES = 10

    class _IntegersStrategy:
        def __init__(self, min_value, max_value):
            self.min_value, self.max_value = min_value, max_value

        def sample(self, rng):
            return rng.randint(self.min_value, self.max_value)

    def _st_integers(min_value, max_value):
        return _IntegersStrategy(min_value, max_value)

    def _settings(**kw):
        max_examples = min(kw.get("max_examples", _MAX_EXAMPLES),
                           _MAX_EXAMPLES)

        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def _given(**strats):
        def deco(fn):
            n_examples = getattr(fn, "_stub_max_examples", _MAX_EXAMPLES)

            @functools.wraps(fn)
            def wrapper():
                rng = _random.Random(0)
                names = list(strats)
                cases = [{n: strats[n].min_value for n in names},
                         {n: strats[n].max_value for n in names}]
                while len(cases) < n_examples:
                    cases.append({n: strats[n].sample(rng) for n in names})
                for kw in cases:
                    fn(**kw)
            # pytest must see a zero-arg test, not the wrapped signature
            # (the strategy params would otherwise look like fixtures)
            del wrapper.__wrapped__
            wrapper.__signature__ = _inspect.Signature()
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp_st = types.ModuleType("hypothesis.strategies")
    _hyp_st.integers = _st_integers
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _hyp_st
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp_st
