import os

# Tests run on the single real CPU device; only launch/dryrun.py forces the
# 512-device host platform (per the dry-run spec, NOT set globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
