"""DESIGN.md §13: the persistent tune store + the analytical roofline prior.

Invariants pinned here:

* the store round-trips entries through disk (atomic write, content hash,
  schema + code-salt keying) and ``load -> save -> load`` is a fixed
  point (hypothesis property, stub-compatible);
* a corrupt / truncated / hash-tampered / stale-schema / stale-salt file
  degrades to an EMPTY store with a :class:`TuneStoreWarning` — a warm
  start is an optimization, never a crash or a silently wrong ladder;
* entries are keyed ``backend|device_kind|describe``: a table stored for
  another device kind is invisible, and a malformed entry for THIS key
  warns and leaves the region cold (it measures as if no store existed);
* the executor round trip — a cold process measures and persists, a
  second process against the same directory restores ladder / chunk /
  cost tables / histograms and reaches tuned steady state with
  ``measurement_launches == 0`` and bit-identical results;
* the roofline prior seeds unmeasured regions with a ``validate_ladder``-
  clean ladder (``tuned_by == "prior"``, every table entry tagged
  ``source="prior"``) that a launch-overhead cost model scores within
  1.5x of its own tuned ladder, and a live retune RETIRES the seeds
  wholesale (``tuned_by == "measured"``, prior tables empty);
* families with an explicit (non-"auto") route in ``family_strategies``
  skip the alt-path probes nothing would consult (satellite of §12/§13).
"""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import AggregationConfig, validate_ladder
from repro.core import AggregationExecutor, derive_ladder
from repro.core.aggregation import (
    BucketCostModel, _backend_key, greedy_decomposition,
)
from repro.core.tunestore import (
    SCHEMA_VERSION, RooflinePrior, TuneStore, TuneStoreWarning, code_salt,
    device_peaks, entry_key,
)

WM = 10 ** 9


def _affine(x):
    return 2.0 * x + 1.0


def _entry(ladder=(1, 16)):
    return {"cost_model": {"s3": {"1": 1e-4, "16": 2e-4}},
            "ladder": list(ladder), "inner_chunk": 0,
            "queue_hist": {"16": 3}, "warmup_wave": 16,
            "tuned_by": "measured"}


def _cfg(tmp_path, **kw):
    base = dict(strategy="s3", max_aggregated=16, launch_watermark=WM,
                autotune=True, autotune_warmup=1, cost_model=True,
                cost_samples=1, tune_store=str(tmp_path))
    base.update(kw)
    return AggregationConfig(**base)


# ---------------------------------------------------------------------------
# store mechanics
# ---------------------------------------------------------------------------

def test_store_roundtrip(tmp_path):
    store = TuneStore(str(tmp_path))
    store.put(("cpu", "cpu0"), "fam[16x2,f32]", _entry())
    store.save()
    again = TuneStore(str(tmp_path))
    assert len(again) == 1
    assert again.get(("cpu", "cpu0"), "fam[16x2,f32]") == _entry()
    assert again.get(("tpu", "v5"), "fam[16x2,f32]") is None  # other device


def test_open_resolution(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_TUNE_STORE", raising=False)
    assert TuneStore.open(None) is None           # cold-start default
    store = TuneStore.open(str(tmp_path))
    assert isinstance(store, TuneStore)
    assert TuneStore.open(store) is store         # instance passthrough
    monkeypatch.setenv("REPRO_TUNE_STORE", str(tmp_path))
    via_env = TuneStore.open(None)
    assert via_env is not None and via_env.root == store.root


def test_save_merges_concurrent_entries(tmp_path):
    """Two processes tuning DIFFERENT families must not clobber each
    other: the later save merges over the valid on-disk entries."""
    a, b = TuneStore(str(tmp_path)), TuneStore(str(tmp_path))
    a.put(("cpu", "cpu0"), "fam_a[8x2,f32]", _entry())
    a.save()
    b.put(("cpu", "cpu0"), "fam_b[8x3,f32]", _entry((1, 8)))
    b.save()
    merged = TuneStore(str(tmp_path)).entries()
    assert set(merged) == {entry_key(("cpu", "cpu0"), "fam_a[8x2,f32]"),
                           entry_key(("cpu", "cpu0"), "fam_b[8x3,f32]")}


def _assert_falls_back_empty(root):
    with pytest.warns(TuneStoreWarning):
        assert len(TuneStore(root)) == 0


def test_corrupt_file_warns_and_falls_back(tmp_path):
    path = os.path.join(str(tmp_path), "tunestore.json")
    with open(path, "w") as f:
        f.write("{not json at all")
    _assert_falls_back_empty(str(tmp_path))


def test_truncated_file_warns_and_falls_back(tmp_path):
    store = TuneStore(str(tmp_path))
    store.put(("cpu", "cpu0"), "fam[16x2,f32]", _entry())
    store.save()
    with open(store.path) as f:
        blob = f.read()
    with open(store.path, "w") as f:
        f.write(blob[:len(blob) // 2])            # torn write
    _assert_falls_back_empty(str(tmp_path))


def test_hash_tamper_warns_and_falls_back(tmp_path):
    store = TuneStore(str(tmp_path))
    store.put(("cpu", "cpu0"), "fam[16x2,f32]", _entry())
    store.save()
    with open(store.path) as f:
        payload = json.load(f)
    key = entry_key(("cpu", "cpu0"), "fam[16x2,f32]")
    payload["entries"][key]["ladder"] = [1, 999]  # hand edit, stale hash
    with open(store.path, "w") as f:
        json.dump(payload, f)
    _assert_falls_back_empty(str(tmp_path))


@pytest.mark.parametrize("field,value", [
    ("schema", SCHEMA_VERSION + 1),
    ("salt", "0000000000000000"),
])
def test_stale_schema_or_salt_ignored(tmp_path, field, value):
    store = TuneStore(str(tmp_path))
    store.put(("cpu", "cpu0"), "fam[16x2,f32]", _entry())
    store.save()
    with open(store.path) as f:
        payload = json.load(f)
    payload[field] = value                        # hash still matches
    with open(store.path, "w") as f:
        json.dump(payload, f)
    _assert_falls_back_empty(str(tmp_path))


def test_save_repairs_corrupt_file(tmp_path):
    """A save over a corrupt file must succeed (the repairing write) and
    leave a loadable store behind."""
    path = os.path.join(str(tmp_path), "tunestore.json")
    with open(path, "w") as f:
        f.write("garbage")
    store = TuneStore(str(tmp_path))
    with pytest.warns(TuneStoreWarning):
        store.put(("cpu", "cpu0"), "fam[16x2,f32]", _entry())
    store.save()
    assert len(TuneStore(str(tmp_path))) == 1


@given(n=st.integers(1, 6), seed=st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_load_save_load_fixed_point(n, seed):
    """Property: one save of arbitrary entries, then load -> save -> load
    reproduces the identical entry table (idempotent persistence)."""
    entries = {}
    for i in range(n):
        fam = f"fam{(seed + i) % 7}[{i + 1}x2,f32]"
        entries[entry_key(("cpu", f"dev{i % 3}"), fam)] = {
            "cost_model": {"s3": {str(1 << i): (seed % 97 + 1) * 1e-5}},
            "ladder": [1, i + 1], "inner_chunk": i % 4,
            "queue_hist": {str(i + 1): seed % 13 + 1},
            "warmup_wave": i + 1, "tuned_by": "measured"}
    root = tempfile.mkdtemp(prefix="tunestore-prop-")
    store = TuneStore(root)
    for key, entry in entries.items():
        backend, device, fam = key.split("|", 2)
        store.put((backend, device), fam, entry)
    store.save()
    first = TuneStore(root)
    snapshot = first.entries()
    assert snapshot == entries
    first.save()                                  # save with zero changes
    assert TuneStore(root).entries() == snapshot


# ---------------------------------------------------------------------------
# executor round trip: cold measures + persists, warm restores
# ---------------------------------------------------------------------------

def _run_wave(exe, parent, n=16):
    fut = exe.submit_range((parent,), 0, n)
    exe.flush()
    return np.asarray(fut.result())


def test_executor_cold_then_warm(tmp_path):
    parent = jnp.arange(32.0).reshape(16, 2)
    cold = AggregationExecutor(jax.vmap(_affine), _cfg(tmp_path))
    cold.warmup(parent_shapes=(parent,))
    for _ in range(3):
        want = _run_wave(cold, parent)
    region = next(iter(cold.regions.values()))
    assert region.stats["tuned_by"] == "measured"
    assert region.stats["measurement_launches"] > 0
    assert cold.save_tuning() == os.path.join(str(tmp_path),
                                              "tunestore.json")

    warm = AggregationExecutor(jax.vmap(_affine), _cfg(tmp_path))
    warm.warmup(parent_shapes=(parent,))
    wregion = next(iter(warm.regions.values()))
    assert wregion.stats["tuned_by"] == "store"
    assert wregion.stats["warm_start"] is True
    assert warm.stats["warm_start"] is True
    assert wregion.buckets == region.buckets      # the tuned ladder
    assert wregion.chunk == region.chunk
    assert wregion.tuned                          # no autotune re-arm due
    got = _run_wave(warm, parent)
    np.testing.assert_array_equal(got, want)      # bit-identical
    np.testing.assert_array_equal(got, np.asarray(2.0 * parent + 1.0))
    # the §13 acceptance counter: a warm process never starts a stopwatch
    assert wregion.stats["measurement_launches"] == 0
    srcs = wregion.stats["cost_sources"]
    assert srcs and all(v == "store" for tbl in srcs.values()
                        for v in tbl.values())


def test_malformed_entry_falls_back_to_measuring(tmp_path):
    """An entry for THIS key with an unusable ladder warns and leaves the
    region cold: it measures exactly as if no store existed."""
    parent = jnp.arange(32.0).reshape(16, 2)
    cold = AggregationExecutor(jax.vmap(_affine), _cfg(tmp_path))
    cold.warmup(parent_shapes=(parent,))
    describe = next(iter(cold.regions.values())).signature.describe()
    store = TuneStore(str(tmp_path))
    bad = _entry()
    bad["ladder"] = ["not", "buckets"]
    store.put(_backend_key(), describe, bad)
    store.save()

    exe = AggregationExecutor(jax.vmap(_affine), _cfg(tmp_path))
    with pytest.warns(TuneStoreWarning, match="unusable"):
        exe.warmup(parent_shapes=(parent,))
    region = next(iter(exe.regions.values()))
    assert region.stats.get("tuned_by") != "store"
    assert not region.stats.get("warm_start")
    assert region.cost.measured()                 # it measured instead
    np.testing.assert_array_equal(_run_wave(exe, parent),
                                  np.asarray(2.0 * parent + 1.0))


def test_stored_entry_for_other_device_is_invisible(tmp_path):
    parent = jnp.arange(32.0).reshape(16, 2)
    probe = AggregationExecutor(jax.vmap(_affine), _cfg(tmp_path))
    probe.warmup(parent_shapes=(parent,))
    describe = next(iter(probe.regions.values())).signature.describe()
    store = TuneStore(str(tmp_path))
    store.put(("tpu", "TPU v5"), describe, _entry((1, 999)))
    store.save()

    exe = AggregationExecutor(jax.vmap(_affine), _cfg(tmp_path))
    exe.warmup(parent_shapes=(parent,))           # no warning: just a miss
    region = next(iter(exe.regions.values()))
    assert region.stats.get("tuned_by") != "store"
    assert 999 not in region.buckets


# ---------------------------------------------------------------------------
# roofline prior
# ---------------------------------------------------------------------------

def test_device_peaks_and_prior_shape():
    bw, flops, launch = device_peaks(("cpu", "cpu0"))
    assert bw > 0 and flops > 0 and launch > 0
    prior = RooflinePrior(("cpu", "cpu0"))
    specs = (jax.ShapeDtypeStruct((2,), jnp.float32),)
    fn = jax.vmap(_affine)
    t1, t8, t16 = (prior.predict(fn, specs, b) for b in (1, 8, 16))
    assert 0 < t1 < t8 < t16                      # monotone in bucket
    assert t8 - t1 == pytest.approx((t16 - t1) * 7 / 15)  # linear slope


def test_prior_seeds_sane_ladder_without_measuring(tmp_path):
    parent = jnp.arange(32.0).reshape(16, 2)
    exe = AggregationExecutor(jax.vmap(_affine),
                              _cfg(tmp_path, prior="roofline"))
    exe.warmup(parent_shapes=(parent,))
    region = next(iter(exe.regions.values()))
    assert region.stats["tuned_by"] == "prior"
    assert region.stats["measurement_launches"] == 0   # no stopwatch ran
    assert not region.cost.measured()
    assert region.cost.seeded() and region.cost.seeded("s2") \
        and region.cost.seeded("fused")
    assert validate_ladder(region.buckets, 16) == region.buckets
    srcs = region.stats["cost_sources"]
    assert all(v == "prior" for tbl in srcs.values() for v in tbl.values())
    assert not region.tuned                       # seeds never pin tuning


def test_prior_ladder_within_1p5x_of_tuned(tmp_path):
    """Acceptance: score the prior-seeded ladder under a launch-overhead
    measured model — it must cost at most 1.5x that model's OWN tuned
    ladder for the observed wave (the prior also charges per launch, so
    both converge on wave-covering buckets)."""
    parent = jnp.arange(32.0).reshape(16, 2)
    exe = AggregationExecutor(jax.vmap(_affine),
                              _cfg(tmp_path, prior="roofline"))
    exe.warmup(parent_shapes=(parent,))
    prior_ladder = next(iter(exe.regions.values())).buckets

    measured = BucketCostModel()
    for b in range(1, 17):
        measured.record(b, 1.0 + 0.01 * b)        # overhead-dominated
    tuned = derive_ladder({16: 1}, cap=16, budget=4, cost_model=measured)
    cost_prior = measured.predict_seq(greedy_decomposition(16, prior_ladder))
    cost_tuned = measured.predict_seq(greedy_decomposition(16, tuned))
    assert cost_prior <= 1.5 * cost_tuned


def test_retune_retires_prior_seeds(tmp_path):
    parent = jnp.arange(32.0).reshape(16, 2)
    exe = AggregationExecutor(jax.vmap(_affine),
                              _cfg(tmp_path, prior="roofline"))
    exe.warmup(parent_shapes=(parent,))
    region = next(iter(exe.regions.values()))
    assert region.stats["tuned_by"] == "prior"
    for _ in range(3):                            # real waves -> retune
        got = _run_wave(exe, parent)
    assert region.stats["tuned_by"] == "measured"
    assert not region.cost.priors                 # seeds retired wholesale
    srcs = region.stats["cost_sources"]
    assert all(v == "measured" for tbl in srcs.values()
               for v in tbl.values())
    np.testing.assert_array_equal(got, np.asarray(2.0 * parent + 1.0))


def test_bad_prior_mode_fails_fast(tmp_path):
    with pytest.raises(ValueError, match="prior"):
        AggregationExecutor(jax.vmap(_affine),
                            _cfg(tmp_path, prior="bogus"))


# ---------------------------------------------------------------------------
# explicit routes skip the probes nothing would consult (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route,want_s2,want_fused", [
    (None, True, True),                           # "auto": measure all
    ("s2", True, False),                          # s2 needs its width table
    ("s3", False, False),                         # nothing consults probes
])
def test_explicit_route_skips_alt_probes(tmp_path, route, want_s2,
                                         want_fused):
    parent = jnp.arange(16.0).reshape(8, 2)
    strategies = None if route is None else {"region": route}
    cfg = _cfg(tmp_path, max_aggregated=8, family_strategies=strategies,
               tune_store=None)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    exe.warmup(parent_shapes=(parent,))
    region = next(iter(exe.regions.values()))
    assert region.cost.measured()                 # s3 always measured
    assert region.cost.measured("s2") is want_s2
    assert region.cost.measured("fused") is want_fused
