"""Scenario/Strategy plugin API: registry validation, extensibility, the
uniform trajectory driver, and the two-family drain property.

* unknown strategy names fail at ``StrategyRunner`` CONSTRUCTION with the
  valid names listed (not on the first rhs() deep inside an iteration);
* a user-defined toy Scenario runs unmodified under every registered
  strategy and matches its own fused reference exactly (the "adding a
  scenario is one file" claim);
* the ``lax.scan`` whole-trajectory driver is uniform across scenarios —
  the AMR scenario gets the same ``use_scan`` path the uniform runner had;
* property test (hypothesis, falls back to the deterministic shim in
  conftest.py): ANY random interleaving of two TaskSignature families
  drains with each family's exact greedy bucket decomposition, and
  ``gather_futures`` reassembles per-family results in submission order;
  mixed-family gathers across output shapes fail loudly.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import greedy_launches
from hypothesis import given, settings, strategies as st

from repro.configs.amr_sedov import CONFIG as AMR_CONFIG
from repro.configs.base import AggregationConfig
from repro.core import (
    AMRSedovScenario, AggregationExecutor, KernelFamily, Scenario,
    StrategyRunner, TaskPopulation, available_strategies, gather_futures,
)
from repro.hydro.state import amr_sedov_init
from repro.hydro.stepper import amr_courant_dt

WM = 10 ** 9


# ---------------------------------------------------------------------------
# registry validation (fail fast, not deep inside rhs)
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_strategies():
    names = available_strategies()
    for name in ("s2", "s3", "s2+s3", "fused"):
        assert name in names


def test_unknown_strategy_fails_at_construction():
    with pytest.raises(ValueError) as ei:
        StrategyRunner(_ToyScenario(5),
                       AggregationConfig(strategy="warp10"))
    msg = str(ei.value)
    assert "warp10" in msg
    for name in available_strategies():     # the error lists valid names
        assert name in msg


# ---------------------------------------------------------------------------
# extensibility: a toy scenario is one class, runs under every strategy
# ---------------------------------------------------------------------------

def _toy_body(x, w):
    return 2.0 * x + w[..., None]


class _ToyScenario(Scenario):
    """Minimal Scenario: state (n, 4), one family, per-task traced weight."""

    name = "toy"

    def __init__(self, n: int):
        self.n = n
        self.w = jnp.arange(float(n))
        self._families = (KernelFamily("toy_affine", jax.vmap(_toy_body)),)

    def families(self):
        return self._families

    def populations(self, state):
        return (TaskPopulation("toy_affine", (state, self.w)),)

    def assemble(self, state, outs):
        return outs[0]

    def warmup_parent_specs(self):
        return (("toy_affine", (
            jax.ShapeDtypeStruct((self.n, 4), jnp.float32),
            jax.ShapeDtypeStruct((self.n,), jnp.float32))),)


@pytest.mark.parametrize("strategy,n_exec,max_agg", [
    ("fused", 1, 1),
    ("s2", 2, 1),
    ("s3", 1, 4),
    ("s2+s3", 2, 8),
])
def test_toy_scenario_runs_under_every_strategy(strategy, n_exec, max_agg):
    n = 5
    sc = _ToyScenario(n)
    state = jnp.arange(float(n * 4)).reshape(n, 4)
    ref = sc.reference_rhs(state)
    r = StrategyRunner(_ToyScenario(n), AggregationConfig(
        strategy=strategy, n_executors=n_exec, max_aggregated=max_agg,
        launch_watermark=WM))
    out = r.rhs(state)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert r.stats["iterations"] == 1 and r.stats["kernel_launches"] >= 1


class _SparseScenario(_ToyScenario):
    """Two families, one of which is EMPTY this iteration — the dynamic
    task structure (a refinement level with no patches) the plugin API
    must tolerate under every strategy."""

    def __init__(self, n: int):
        super().__init__(n)
        self._families = self._families + (
            KernelFamily("toy_square", jax.vmap(_toy_square)),)

    def populations(self, state):
        return (TaskPopulation("toy_affine", (state, self.w)),
                TaskPopulation("toy_square", (state[:0], self.w[:0])))

    def assemble(self, state, outs):
        return outs[0] + jnp.sum(outs[1])


def _toy_square(x, w):
    return x * x + w[..., None]


@pytest.mark.parametrize("strategy", ["fused", "s2", "s3", "s2+s3"])
def test_zero_task_population_is_tolerated(strategy):
    n = 4
    sc = _SparseScenario(n)
    state = jnp.arange(float(n * 4)).reshape(n, 4)
    ref = sc.reference_rhs(state)
    r = StrategyRunner(_SparseScenario(n), AggregationConfig(
        strategy=strategy, n_executors=2, max_aggregated=4,
        launch_watermark=WM))
    out = r.rhs(state)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_toy_scenario_warmup_via_facade():
    r = StrategyRunner(_ToyScenario(6), AggregationConfig(
        strategy="s3", max_aggregated=4, launch_watermark=WM))
    r.warmup()
    assert len(r.executor.regions) == 1
    state = jnp.ones((6, 4))
    np.testing.assert_array_equal(
        np.asarray(r.rhs(state)),
        np.asarray(_ToyScenario(6).reference_rhs(state)))


# ---------------------------------------------------------------------------
# uniform trajectory driver: AMR now has the use_scan path (API parity)
# ---------------------------------------------------------------------------

def test_amr_trajectory_scan_matches_step_loop():
    st = amr_sedov_init(AMR_CONFIG)
    dt = amr_courant_dt(st.uc, st.uf, AMR_CONFIG)
    r = StrategyRunner(AMRSedovScenario(AMR_CONFIG),
                       AggregationConfig(strategy="fused"))
    loop = (st.uc, st.uf)
    for _ in range(2):
        loop = r.rk3_step(loop, dt)
    before = r.stats["kernel_launches"]
    scan = r.rk3_trajectory((st.uc, st.uf), dt, 2)
    assert r.stats["kernel_launches"] == before + 1   # ONE dispatch
    for got, want in zip(scan, loop):
        scale = float(np.max(np.abs(np.asarray(want))))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5 * scale, rtol=1e-5)
    # the caller's state arrays must survive (the driver donates a copy);
    # materialize them — a donated buffer raises on read, not on .shape
    assert np.asarray(st.uc).shape[0] == AMR_CONFIG.n_fields
    assert np.asarray(st.uf).shape[0] == AMR_CONFIG.n_fields


def test_amr_time_step_accepts_use_scan():
    st = amr_sedov_init(AMR_CONFIG)
    dt = amr_courant_dt(st.uc, st.uf, AMR_CONFIG)
    r = StrategyRunner(AMRSedovScenario(AMR_CONFIG),
                       AggregationConfig(strategy="fused"))
    sec = r.time_step((st.uc, st.uf), dt, n_steps=2, use_scan=True)
    assert sec > 0.0
    assert r.stats["iterations"] == 6


# ---------------------------------------------------------------------------
# property: random two-family interleavings drain greedily, gather in order
# ---------------------------------------------------------------------------

def _affine(x):
    return 2.0 * x + 1.0


def _square(x):
    return x * x + 3.0


@given(n_a=st.integers(0, 24), n_b=st.integers(1, 24),
       max_agg=st.integers(1, 8), seed=st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_random_two_family_interleaving_property(n_a, n_b, max_agg, seed):
    """For ANY submission interleaving of two families (distinct kernels,
    distinct shapes): each family drains with ITS OWN exact greedy bucket
    decomposition, per-family results gather in submission order, and a
    cross-family gather fails loudly."""
    cfg = AggregationConfig(strategy="s3", n_executors=1,
                            max_aggregated=max_agg, launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg, name="affine")
    exe.register("square", jax.vmap(_square))
    order = ["a"] * n_a + ["b"] * n_b
    random.Random(seed).shuffle(order)
    counters = {"a": 0, "b": 0}
    futs = {"a": [], "b": []}
    for fam in order:
        i = counters[fam]
        counters[fam] += 1
        if fam == "a":
            futs["a"].append(exe.submit(jnp.full((2,), float(i))))
        else:
            futs["b"].append(exe.submit(jnp.full((3,), float(i)),
                                        kernel="square"))
    exe.flush()
    buckets = cfg.bucket_sizes()
    assert exe.stats["launches"] == (greedy_launches(n_a, buckets)
                                     + greedy_launches(n_b, buckets))
    by_region = {k.split("[")[0]: v
                 for k, v in exe.stats["regions"].items()}
    assert sum(k * v for k, v in
               by_region["square"]["aggregated_hist"].items()) == n_b
    if n_a:
        assert sum(k * v for k, v in
                   by_region["affine"]["aggregated_hist"].items()) == n_a
        out_a = np.asarray(gather_futures(futs["a"]))
        np.testing.assert_array_equal(
            out_a, np.stack([np.full(2, 2.0 * i + 1.0)
                             for i in range(n_a)]))
    out_b = np.asarray(gather_futures(futs["b"]))
    np.testing.assert_array_equal(
        out_b, np.stack([np.full(3, float(i) ** 2 + 3.0)
                         for i in range(n_b)]))
    if n_a:                                 # mixed-family error path
        with pytest.raises(ValueError):
            gather_futures(futs["a"] + futs["b"])
