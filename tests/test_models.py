"""Per-architecture smoke tests (reduced configs) + family-specific checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced, shape_applicable
from repro.configs.base import LONG_500K, SHAPES_BY_NAME
from repro.models import model, ssm
from repro.optim.adamw import OptConfig, opt_init, opt_update

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, with_labels=True, s=S):
    b = {"tokens": jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)}
    if with_labels:
        b["labels"] = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        b["vision"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        b["frames"] = 0.1 * jax.random.normal(
            KEY, (B, s * cfg.encoder_seq_ratio, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_forward_smoke(arch):
    """One forward pass: output shapes + finite values (assignment spec)."""
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits = model.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = model.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    # loss at init should be near ln(vocab) for random tokens
    assert float(loss) == pytest.approx(np.log(cfg.vocab_size), rel=0.35)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step_smoke(arch):
    """One grad + optimizer step on CPU: finite grads, params change."""
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, KEY)
    batch = _batch(cfg)
    opt_state = opt_init(params)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(cfg, p, batch))(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves)
    new_p, new_s, metrics = opt_update(grads, opt_state, params, OptConfig())
    assert float(metrics["grad_norm"]) > 0.0
    # at least the embedding moved
    delta = float(jnp.max(jnp.abs(
        new_p["embed"]["emb"].astype(jnp.float32)
        - params["embed"]["emb"].astype(jnp.float32))))
    assert delta > 0.0
    assert int(new_s["step"]) == 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the forward logits exactly
    (same math, cache path) — the serving correctness invariant."""
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, KEY)
    batch = _batch(cfg, with_labels=False, s=8)
    full = model.forward(cfg, params, batch)
    cache = model.init_cache(cfg, params, batch, B, max_len=8)
    for t in range(8):
        lg, cache = model.decode_step(cfg, params, cache,
                                      batch["tokens"][:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-3)


def test_long_context_applicability_rules():
    """long_500k runs for ssm/hybrid/SWA archs, is excluded for full attn."""
    runs = {a: shape_applicable(get_config(a), LONG_500K)[0] for a in ARCHS}
    assert runs["xlstm-125m"] and runs["zamba2-2.7b"] and \
        runs["h2o-danube-1.8b"]
    for a in ("starcoder2-15b", "granite-8b", "qwen1.5-32b", "dbrx-132b",
              "qwen2-moe-a2.7b", "seamless-m4t-large-v2",
              "llama-3.2-vision-90b"):
        assert not runs[a], a


def test_swa_rolling_cache_is_bounded():
    """Sliding-window decode memory must not grow with max_len."""
    cfg = reduced(get_config("h2o-danube-1.8b"))
    assert cfg.sliding_window == 8
    params = model.init_params(cfg, KEY)
    batch = _batch(cfg, with_labels=False)
    cache = model.init_cache(cfg, params, batch, B, max_len=10_000)
    assert cache["kv"]["k"].shape[2] == cfg.sliding_window


def test_swa_decode_matches_windowed_forward():
    """After the window rolls, decode must equal the windowed forward."""
    cfg = reduced(get_config("h2o-danube-1.8b"))
    params = model.init_params(cfg, KEY)
    s = 24  # 3x the window of 8
    batch = {"tokens": jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)}
    full = model.forward(cfg, params, batch)
    cache = model.init_cache(cfg, params, batch, B, max_len=s)
    for t in range(s):
        lg, cache = model.decode_step(cfg, params, cache,
                                      batch["tokens"][:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-3)


def test_ssm_decode_state_is_constant_size():
    cfg = reduced(get_config("xlstm-125m"))
    params = model.init_params(cfg, KEY)
    batch = _batch(cfg, with_labels=False)
    c1 = model.init_cache(cfg, params, batch, B, max_len=100)
    c2 = model.init_cache(cfg, params, batch, B, max_len=100_000)
    s1 = jax.tree_util.tree_map(lambda x: x.shape, c1)
    s2 = jax.tree_util.tree_map(lambda x: x.shape, c2)
    assert s1 == s2          # O(1) state: what qualifies it for long_500k


def test_mamba2_chunk_size_invariance():
    """S1 knob: chunk size must not change results (only performance)."""
    cfg = reduced(get_config("zamba2-2.7b"))
    p = ssm.mamba2_init(KEY, cfg, jnp.float32)
    x = 0.5 * jax.random.normal(KEY, (2, 32, cfg.d_model))
    y8, _ = ssm.mamba2_apply(p, x, cfg.replace(ssm_chunk=8))
    y32, _ = ssm.mamba2_apply(p, x, cfg.replace(ssm_chunk=32))
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               atol=1e-5, rtol=1e-4)


def test_zamba2_shared_block_is_shared():
    """Zamba2's attention block: ONE set of weights, G invocations."""
    cfg = get_config("zamba2-2.7b")
    r = reduced(cfg)
    params = model.init_params(r, KEY)
    # shared block params are not stacked over groups
    assert params["shared"]["attn"]["wq"].ndim == 2
    # mamba params are stacked (groups, every, ...)
    assert params["mamba"]["in_proj"].ndim == 4


def test_moe_param_count_active_vs_total():
    cfg = get_config("dbrx-132b")
    total = cfg.param_count(active_only=False)
    active = cfg.param_count(active_only=True)
    assert total > 2.5 * active          # 16 experts, top-4
    assert 1.0e11 < total < 1.6e11       # ~132B
    g = get_config("granite-8b")
    assert 7e9 < g.param_count() < 9e9   # ~8B


def test_loss_decreases_on_tiny_model():
    """End-to-end training sanity: 30 steps on structured synthetic data."""
    from repro.data.pipeline import DataConfig, SyntheticLMStream
    cfg = reduced(get_config("granite-8b")).replace(n_layers=2)
    data = SyntheticLMStream(DataConfig(seq_len=64, global_batch=8,
                                        vocab_size=cfg.vocab_size))
    params = model.init_params(cfg, KEY)
    opt_state = opt_init(params)
    ocfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=50)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch))(params)
        new_p, new_s, _ = opt_update(grads, opt_state, params, ocfg)
        return new_p, new_s, loss

    losses = []
    for i in range(30):
        params, opt_state, loss = step(params, opt_state, data.batch(i))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
