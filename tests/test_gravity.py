"""Gravity kernel family + cross-solver aggregation (the redesign's proof).

The acceptance invariants (ISSUE 3):
* one RK3 iteration submits hydro AND gravity tasks interleaved through ONE
  ``AggregationExecutor``: TWO concurrent ``TaskSignature`` families, each
  draining with its own bucket ladder (asserted via ``stats["regions"]``
  and the pool's per-family launch tags);
* s3 / s2+s3 / fused are bit-identical to the per-family fused reference
  (``Scenario.reference_rhs``) — the equivalence invariant extended across
  solver families;
* the Pallas gravity twin matches the jnp oracle (interpret mode) and is
  bit-exact against itself across batch decompositions;
* the gravity body itself is sane: zero density -> zero field, mass
  attracts (g points at the blast), translation-invariant under vmap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AggregationConfig
from repro.configs.gravity import CONFIG_SMALL
from repro.core import GravityScenario, StrategyRunner
from repro.hydro.state import extract_subgrids, sedov_init
from repro.hydro.stepper import courant_dt
from repro.kernels.gravity import (
    gravity_batched_body, gravity_pallas, subgrid_gravity,
)

WM = 10 ** 9
CFG = CONFIG_SMALL
HC = CFG.hydro


@pytest.fixture(scope="module")
def sedov_grav():
    st = sedov_init(HC)
    dt = courant_dt(st.u, HC)
    sc = GravityScenario(CFG)
    ref = StrategyRunner(sc, AggregationConfig(strategy="fused")).rk3_step(
        st.u, dt)
    return st, dt, ref


# ---------------------------------------------------------------------------
# the gravity task body
# ---------------------------------------------------------------------------

def _kw():
    return dict(ghost=HC.ghost, subgrid=HC.subgrid, g_const=CFG.g_const,
                n_iter=CFG.relax_iters)


def test_zero_density_zero_field():
    p = HC.padded
    u = jnp.zeros((HC.n_fields, p, p, p))
    out = subgrid_gravity(u, jnp.float32(0.1), **_kw())
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    assert out.shape == (4, HC.subgrid, HC.subgrid, HC.subgrid)


def test_point_mass_attracts():
    """A central overdensity produces a negative potential well and an
    acceleration field pointing toward it on every axis."""
    p = HC.padded
    u = jnp.zeros((HC.n_fields, p, p, p)).at[0].set(1.0)
    c = p // 2
    u = u.at[0, c, c, c].add(100.0)
    phi, gx, gy, gz = np.asarray(
        subgrid_gravity(u, jnp.float32(0.1), **_kw()))
    s = HC.subgrid
    cc = (c - HC.ghost)                    # well centre in interior coords
    assert phi[cc, cc, cc] == phi.min() < 0.0
    assert gx[0, cc, cc] > 0.0 and gx[s - 1, cc, cc] < 0.0
    assert gy[cc, 0, cc] > 0.0 and gy[cc, s - 1, cc] < 0.0
    assert gz[cc, cc, 0] > 0.0 and gz[cc, cc, s - 1] < 0.0


def test_gravity_pallas_matches_oracle():
    """The Pallas twin (slot_grid, per-slot traced h): allclose to the jnp
    aggregation-region body (same tolerance discipline as the hydro Pallas
    tests — interpret mode compiles a separate program), and bit-identical
    to ITSELF run slot-by-slot (mixed-width batching is exact)."""
    st = sedov_init(HC)
    subs = extract_subgrids(st.u, HC.subgrid, HC.ghost, "outflow")
    h = jnp.full((subs.shape[0],), 0.125, jnp.float32)
    h = h.at[1].set(0.0625)                # mixed per-slot widths
    want = gravity_batched_body(HC.ghost, HC.subgrid, CFG.g_const,
                                CFG.relax_iters)(subs, h)
    got = gravity_pallas(subs, h, **_kw())
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6 * max(scale, 1.0), rtol=2e-5)
    for i in range(2):
        one = gravity_pallas(subs[i:i + 1], h[i:i + 1], **_kw())
        np.testing.assert_array_equal(np.asarray(got[i:i + 1]),
                                      np.asarray(one))


# ---------------------------------------------------------------------------
# cross-solver aggregation: hydro + gravity through one executor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,n_exec,max_agg", [
    ("s3", 1, 16),
    ("s2+s3", 4, 16),
])
def test_two_solver_families_one_executor_bit_identical(sedov_grav, strategy,
                                                        n_exec, max_agg):
    """THE acceptance criterion: hydro + gravity tasks interleave through
    one executor as two concurrent TaskSignature families, and the step is
    bit-identical to the per-family fused reference."""
    st, dt, ref = sedov_grav
    agg = AggregationConfig(strategy=strategy, n_executors=n_exec,
                            max_aggregated=max_agg, launch_watermark=WM)
    r = StrategyRunner(GravityScenario(CFG), agg)
    out = r.rk3_step(st.u, dt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    regions = r.stats["regions"]
    assert len(regions) == 2
    hists = {k: v["aggregated_hist"] for k, v in regions.items()}
    # 8 tasks per family per iteration x 3 RK3 iterations, all in bucket 8
    assert hists["hydro_rhs[5x14x14x14,scalar]"] == {8: 3}
    assert hists["gravity[5x14x14x14,scalar]"] == {8: 3}
    assert r.launches_by_family == {"hydro_rhs": 3, "gravity": 3}
    assert r.stats["kernel_launches"] == 6


def test_gravity_s2_matches_reference(sedov_grav):
    """s2 launches every task of both families separately (one scatter-ring
    per family).  The gravity body's gradient scaling fuses differently
    inside the donated scatter program on XLA:CPU (1-2 ulp reassociation,
    same caveat as the uniform runner's cross-bucket comparison), so this
    path asserts allclose; the aggregated paths above are bit-exact."""
    st, dt, ref = sedov_grav
    r = StrategyRunner(GravityScenario(CFG),
                       AggregationConfig(strategy="s2", n_executors=2))
    out = r.rk3_step(st.u, dt)
    scale = float(np.max(np.abs(np.asarray(ref))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6 * scale, rtol=1e-6)
    n = HC.n_subgrids
    assert r.stats["kernel_launches"] == 3 * 2 * n
    assert r.launches_by_family == {"hydro_rhs": 3 * n, "gravity": 3 * n}


# ---------------------------------------------------------------------------
# two-family epilogue-fused RK stages (DESIGN.md §10)
# ---------------------------------------------------------------------------

def test_gravity_two_family_epilogue_stage_bit_identical(sedov_grav):
    """fuse_epilogue drives each RK stage as ONE wave carrying BOTH
    families — the hydro axpy-fused twin and the unchanged gravity
    relaxation — with the cross-family coupling entering at
    ``assemble_stage``; bit-identical to the fused stage reference."""
    st, dt, ref = sedov_grav
    fused = StrategyRunner(GravityScenario(CFG), AggregationConfig(
        strategy="fused", fuse_epilogue=True))
    ref_stage = fused.rk3_step(st.u, dt)
    for strategy, n_exec in [("s3", 1), ("s2+s3", 2)]:
        r = StrategyRunner(GravityScenario(CFG), AggregationConfig(
            strategy=strategy, n_executors=n_exec, max_aggregated=16,
            launch_watermark=WM, fuse_epilogue=True))
        out = r.rk3_step(st.u, dt)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(ref_stage))
        # both families launch once per stage, interleaved in one wave
        assert r.launches_by_family == {"hydro_rhs+epi": 3, "gravity": 3}
        assert r.stats["kernel_launches"] == 6
    # reassociates ~1e-5 vs the eager global combine — allclose only
    scale = float(np.max(np.abs(np.asarray(ref))))
    np.testing.assert_allclose(np.asarray(ref_stage), np.asarray(ref),
                               atol=1e-5 * scale, rtol=1e-5)


def test_gravity_warmup_precompiles_both_families(sedov_grav):
    st, dt, ref = sedov_grav
    agg = AggregationConfig(strategy="s3", max_aggregated=16,
                            launch_watermark=WM)
    r = StrategyRunner(GravityScenario(CFG), agg)
    r.warmup()
    compiled = [v for region in r.executor.regions.values()
                for v in region.compiled.values()]
    assert compiled and all(isinstance(f, jax.stages.Compiled)
                            for f in compiled)
    assert len(r.executor.regions) == 2    # both families opened by warmup
    out = r.rk3_step(st.u, dt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gravity_step_stays_physical(sedov_grav):
    """Self-gravity must brake the blast, not blow it up: the step stays
    finite with positive density, and the gravity source actually pulled
    momentum inward relative to the no-gravity step."""
    st, dt, ref = sedov_grav
    a = np.asarray(ref)
    assert np.all(np.isfinite(a))
    assert np.all(a[0] > 0.0)
    from repro.core import UniformSedovScenario
    plain = StrategyRunner(
        UniformSedovScenario(HC),
        AggregationConfig(strategy="fused")).rk3_step(st.u, dt)
    assert not np.array_equal(a, np.asarray(plain))   # coupling is live
