"""Slot-ring staging: device-residency, equivalence, and launch accounting.

The PR's invariants (DESIGN.md §3):
* the slot-ring / indexed-gather S3 path is BIT-identical to ``fused`` and
  to the seed's host-staging path (not just allclose);
* launches follow the greedy bucket decomposition exactly;
* ``gather_futures`` is zero-copy when futures cover whole launches;
* ring compaction under watermark remainders preserves results;
* the ``lax.scan`` trajectory driver matches the per-step loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import greedy_launches

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core import (
    AggregationExecutor, SlotRing, SlotView, StrategyRunner,
    UniformSedovScenario, gather_futures,
)
from repro.hydro.state import extract_subgrids, sedov_init
from repro.hydro.stepper import courant_dt, rk3_step, rk3_trajectory

CFG = HydroConfig(subgrid=8, ghost=3, levels=1)


def _batched_affine(x):
    return 2.0 * x + 1.0


def _vm():
    return jax.vmap(_batched_affine)


# ---------------------------------------------------------------------------
# SlotRing unit semantics
# ---------------------------------------------------------------------------

def test_slot_ring_write_and_buffers():
    ring = SlotRing(4, (jnp.zeros((3,)),))
    for i in range(3):
        assert ring.write((jnp.full((3,), float(i)),)) == i
    buf = ring.buffers()[0]
    assert buf.shape == (4, 3)
    np.testing.assert_array_equal(np.asarray(buf[:3]),
                                  np.stack([np.full(3, float(i))
                                            for i in range(3)]))
    assert ring.fill == 3 and ring.writes == 3


def test_slot_ring_swap_is_double_buffered():
    ring = SlotRing(2, (jnp.zeros((2,)),))
    ring.write((jnp.ones((2,)),))
    a = ring.buffers()[0]
    ring.swap()
    assert ring.fill == 0
    assert ring.buffers()[0] is not a     # other buffer now active
    ring.swap()
    assert ring.buffers()[0] is a         # back to the first


def test_slot_ring_compact_renumbers():
    ring = SlotRing(4, (jnp.zeros((2,)),))
    for i in range(4):
        ring.write((jnp.full((2,), float(i)),))
    ring.compact(2)                       # slots 2,3 -> 0,1
    assert ring.fill == 2 and ring.compactions == 1
    np.testing.assert_array_equal(np.asarray(ring.buffers()[0][:2]),
                                  [[2.0, 2.0], [3.0, 3.0]])


def test_executor_ring_compaction_under_watermark_remainders():
    """Partial watermark launches leave a mid-ring remainder; when the ring
    fills, the live tail must slide to the front without corrupting queued
    tasks (exercises SlotRing.compact through the executor)."""
    cfg = AggregationConfig(strategy="s3", n_executors=1, max_aggregated=4,
                            buckets=(1, 2), launch_watermark=3)
    exe = AggregationExecutor(_vm(), cfg)
    xs = [jnp.full((2,), float(i)) for i in range(9)]
    futs = [exe.submit(x) for x in xs]
    exe.flush()
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.full(2, 2.0 * i + 1.0))
    assert exe.ring.compactions >= 1


# ---------------------------------------------------------------------------
# launch accounting: greedy bucket decomposition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_tasks", [1, 3, 7, 12, 29, 64])
def test_launches_match_greedy_bucket_prediction(n_tasks):
    cfg = AggregationConfig(strategy="s3", n_executors=1, max_aggregated=16,
                            launch_watermark=10**9)
    exe = AggregationExecutor(_vm(), cfg)
    for i in range(n_tasks):
        exe.submit(jnp.full((2,), float(i)))
    exe.flush()
    assert exe.stats["launches"] == greedy_launches(
        n_tasks, cfg.bucket_sizes())
    assert sum(k * v for k, v in exe.stats["aggregated_hist"].items()) \
        == n_tasks


def test_warmup_precompiles_aot():
    """warmup AOT-lowers one executable per bucket (.lower().compile()),
    instead of the seed's per-bucket identical jit wrappers."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(_vm(), cfg)
    exe.warmup((jnp.zeros((3,)),))
    for b in cfg.bucket_sizes():
        fn = exe._compiled[("ring", b)]
        assert isinstance(fn, jax.stages.Compiled)
    outs = exe.map([(jnp.full((3,), float(i)),) for i in range(8)])
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o), np.full(3, 2.0 * i + 1.0))


def test_warmup_precompiles_aot_host_mode():
    cfg = AggregationConfig(strategy="s3", max_aggregated=4,
                            launch_watermark=10**9, staging="host")
    exe = AggregationExecutor(_vm(), cfg)
    exe.warmup((jnp.zeros((3,)),))
    for b in cfg.bucket_sizes():
        assert isinstance(exe._compiled[("host", b)], jax.stages.Compiled)
    outs = exe.map([(jnp.full((3,), float(i)),) for i in range(5)])
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o), np.full(3, 2.0 * i + 1.0))


# ---------------------------------------------------------------------------
# gather_futures
# ---------------------------------------------------------------------------

def test_gather_futures_whole_launch_is_zero_copy():
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(_vm(), cfg)
    futs = [exe.submit(jnp.full((2,), float(i))) for i in range(8)]
    exe.flush()
    assert exe.stats["launches"] == 1
    out = gather_futures(futs)
    assert out is futs[0]._batch          # the batch itself, no copy
    np.testing.assert_array_equal(
        np.asarray(out), np.stack([np.full(2, 2.0 * i + 1.0)
                                   for i in range(8)]))


def test_gather_futures_across_launches():
    cfg = AggregationConfig(strategy="s3", max_aggregated=4,
                            launch_watermark=10**9)
    exe = AggregationExecutor(_vm(), cfg)
    futs = [exe.submit(jnp.full((2,), float(i))) for i in range(7)]
    exe.flush()
    assert exe.stats["launches"] > 1
    out = gather_futures(futs)
    np.testing.assert_array_equal(
        np.asarray(out), np.stack([np.full(2, 2.0 * i + 1.0)
                                   for i in range(7)]))


def test_gather_futures_unlaunched_raises():
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(_vm(), cfg)
    futs = [exe.submit(jnp.ones((2,)))]
    with pytest.raises(RuntimeError):
        gather_futures(futs)
    exe.flush()


# ---------------------------------------------------------------------------
# indexed-gather (SlotView) staging
# ---------------------------------------------------------------------------

def test_submit_indexed_matches_concrete_submit():
    parent = jnp.arange(24.0).reshape(6, 4)
    cfg = AggregationConfig(strategy="s3", max_aggregated=6,
                            launch_watermark=10**9)
    ref_exe = AggregationExecutor(_vm(), cfg)
    ref = ref_exe.map([(parent[i],) for i in range(6)])
    exe = AggregationExecutor(_vm(), cfg)
    futs = [exe.submit_indexed((parent,), i) for i in range(6)]
    exe.flush()
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.asarray(ref[i]))


def test_distinct_parents_never_share_a_bucket():
    """Tasks referencing different parent arrays must not be gathered from
    one parent set — the executor launches the queued run first."""
    p1 = jnp.arange(8.0).reshape(2, 4)
    p2 = 100.0 + jnp.arange(8.0).reshape(2, 4)
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(_vm(), cfg)
    f1 = exe.submit_indexed((p1,), 0)
    f2 = exe.submit_indexed((p2,), 1)
    exe.flush()
    assert exe.stats["launches"] == 2     # not merged into one gather
    np.testing.assert_array_equal(np.asarray(f1.result()),
                                  np.asarray(2.0 * p1[0] + 1.0))
    np.testing.assert_array_equal(np.asarray(f2.result()),
                                  np.asarray(2.0 * p2[1] + 1.0))


def test_slotview_args_must_share_index():
    p = jnp.arange(8.0).reshape(2, 4)
    q = jnp.arange(8.0).reshape(2, 4)
    exe = AggregationExecutor(jax.vmap(lambda a, b: a + b),
                              AggregationConfig(strategy="s3"))
    with pytest.raises(ValueError):
        exe.submit(SlotView(p, 0), SlotView(q, 1))


def test_mode_switch_flushes_pending():
    """Ring-mode and ref-mode entries never share a bucket; a mode switch
    launches what is queued first."""
    parent = jnp.arange(12.0).reshape(3, 4)
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(_vm(), cfg)
    f_ring = exe.submit(jnp.full((4,), 7.0))
    f_ref = exe.submit(SlotView(parent, 1))
    assert f_ring.ready()                 # flushed by the mode switch
    exe.flush()
    np.testing.assert_array_equal(np.asarray(f_ring.result()),
                                  np.full(4, 15.0))
    np.testing.assert_array_equal(np.asarray(f_ref.result()),
                                  np.asarray(2.0 * parent[1] + 1.0))


# ---------------------------------------------------------------------------
# hydro: the PR's acceptance invariant — BIT-identical across staging paths
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sedov():
    st = sedov_init(CFG)
    dt = courant_dt(st.u, CFG)
    ref = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="fused")).rk3_step(st.u, dt)
    return st, dt, ref


def test_s3_ring_bit_identical_to_fused_and_host(sedov):
    """One bucket covering all tasks: the gather-staged program computes the
    exact same XLA reduction order as fused and as the seed's host staging —
    results must be bit-identical, not merely allclose."""
    st, dt, ref = sedov
    n = CFG.n_subgrids
    dev = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="s3", max_aggregated=n, launch_watermark=10**9))
    host = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="s3", max_aggregated=n, launch_watermark=10**9,
        staging="host"))
    out_dev = dev.rk3_step(st.u, dt)
    out_host = host.rk3_step(st.u, dt)
    np.testing.assert_array_equal(np.asarray(out_dev), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(out_dev), np.asarray(out_host))


def test_s2_scatter_ring_bit_identical_to_fused(sedov):
    st, dt, ref = sedov
    s2 = StrategyRunner(UniformSedovScenario(CFG),
                        AggregationConfig(strategy="s2", n_executors=2))
    out = s2.rk3_step(st.u, dt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert s2.stats["kernel_launches"] == 3 * CFG.n_subgrids


def test_s3_launch_counts_greedy_on_hydro(sedov):
    st, dt, _ = sedov
    n = CFG.n_subgrids
    for max_agg in (3, n, 2 * n):
        agg = AggregationConfig(strategy="s3", max_aggregated=max_agg,
                                launch_watermark=10**9)
        r = StrategyRunner(UniformSedovScenario(CFG), agg)
        r.rhs(st.u)
        assert r.executor.stats["launches"] == greedy_launches(
            n, agg.bucket_sizes())


def test_trajectory_scan_matches_step_loop(sedov):
    st, dt, _ = sedov
    r = StrategyRunner(UniformSedovScenario(CFG),
                       AggregationConfig(strategy="fused"))
    loop = st.u
    for _ in range(2):
        loop = r.rk3_step(loop, dt)
    before = r.stats["kernel_launches"]
    scan = r.rk3_trajectory(st.u, dt, 2)
    assert r.stats["kernel_launches"] == before + 1   # ONE dispatch
    scale = float(np.max(np.abs(np.asarray(loop))))
    np.testing.assert_allclose(np.asarray(scan), np.asarray(loop),
                               atol=1e-5 * scale, rtol=1e-5)
    # the caller's state array must survive (the driver donates a copy)
    assert st.u.shape == (CFG.n_fields,) + (CFG.grids_per_edge * CFG.subgrid,) * 3


def test_global_trajectory_matches_step_loop(sedov):
    st, dt, _ = sedov
    loop = st.u
    for _ in range(2):
        loop = rk3_step(loop, dt, CFG)
    scan = rk3_trajectory(jnp.array(st.u, copy=True), dt, CFG, 2)
    scale = float(np.max(np.abs(np.asarray(loop))))
    np.testing.assert_allclose(np.asarray(scan), np.asarray(loop),
                               atol=1e-5 * scale, rtol=1e-5)


def test_staging_stats_accounted(sedov):
    st, dt, _ = sedov
    r = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="s3", max_aggregated=CFG.n_subgrids,
        launch_watermark=10**9))
    r.rhs(st.u)
    assert r.stats["staging_s"] >= 0.0
    assert r.pool.total_dispatch_s > 0.0


# ---------------------------------------------------------------------------
# pallas kernel through the ring (interpret mode)
# ---------------------------------------------------------------------------

def test_pallas_prefix_matches_direct_kernel():
    from repro.kernels.hydro_rhs import (
        hydro_rhs_pallas, hydro_rhs_pallas_prefix,
    )
    st = sedov_init(CFG)
    subs = extract_subgrids(st.u, CFG.subgrid, CFG.ghost, "outflow")
    h = CFG.domain / (CFG.grids_per_edge * CFG.subgrid)
    kw = dict(h=h, gamma=CFG.gamma, ghost=CFG.ghost, subgrid=CFG.subgrid)
    want = hydro_rhs_pallas(subs[2:6], **kw)
    got = jax.jit(lambda r, s: hydro_rhs_pallas_prefix(r, s, 4, **kw))(
        subs, jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
