"""Serving engine: continuous batching == sequential decode, aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import AggregationConfig
from repro.models import model
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _ref_decode(cfg, params, prompt, n_new, max_len=64):
    cache = model.init_cache(cfg, params,
                             {"tokens": jnp.zeros((1, 1), jnp.int32)}, 1,
                             max_len)
    for t in prompt[:-1]:
        _, cache = model.decode_step(cfg, params, cache, jnp.array([[t]]))
    tok, out = prompt[-1], []
    for _ in range(n_new):
        lg, cache = model.decode_step(cfg, params, cache, jnp.array([[tok]]))
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
    return out


@pytest.mark.parametrize("arch", ["granite-8b", "xlstm-125m", "zamba2-2.7b"])
def test_engine_matches_sequential(arch):
    cfg = reduced(get_config(arch))
    params = model.init_params(cfg, KEY)
    eng = ServingEngine(cfg, params, max_batch=4, max_len=64)
    prompts = [[5, 7, 9], [11, 3], [2, 2, 2, 2], [8], [13, 21], [1, 2, 3]]
    reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        assert r.output == _ref_decode(cfg, params, r.prompt, 4), r.rid


def test_engine_aggregates_requests():
    """More requests than slots: the engine must batch (aggregate), admit
    continuously, and never launch more than bucket-ladder kernels."""
    cfg = reduced(get_config("granite-8b"))
    params = model.init_params(cfg, KEY)
    eng = ServingEngine(cfg, params, max_batch=8, max_len=32)
    reqs = [Request(i, [i % 7 + 1], max_new_tokens=6) for i in range(20)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.stats["tokens"] == 20 * 6
    # aggregation happened: far fewer launches than tokens
    assert eng.stats["launches"] < eng.stats["tokens"]
    hist = eng.stats["aggregated_hist"]
    assert max(hist) == 8           # the full bucket was used
    # only power-of-two buckets were compiled
    assert set(hist) <= {1, 2, 4, 8}


def test_engine_slot_reuse_no_crosstalk():
    """A slot freed by a finished request and reused by a new one must not
    leak the old request's KV state (the paper's buffer-recycling hazard)."""
    cfg = reduced(get_config("granite-8b"))
    params = model.init_params(cfg, KEY)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=32)
    first = [Request(0, [3, 1, 4], max_new_tokens=3),
             Request(1, [1, 5], max_new_tokens=5)]
    second = [Request(2, [9, 2, 6], max_new_tokens=4)]
    for r in first + second:
        eng.submit(r)
    eng.run()
    assert second[0].output == _ref_decode(cfg, params, [9, 2, 6], 4)


def test_engine_bucket_ladder_from_config():
    cfg = reduced(get_config("granite-8b"))
    params = model.init_params(cfg, KEY)
    agg = AggregationConfig(max_aggregated=4, buckets=(1, 4))
    eng = ServingEngine(cfg, params, max_batch=4, max_len=16, agg=agg)
    assert eng.buckets == (1, 4)
