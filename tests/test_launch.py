"""Launch layer: roofline parsing, analytic cost model, sharding specs."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES_BY_NAME
from repro.distributed.api import logical_rules
from repro.launch.roofline import (
    analytic_bytes, analytic_flops, parse_collectives_with_trips,
    roofline_terms, _trip_count, _split_computations,
)
from repro.launch.sharding import param_pspec, rules_overrides


# ---------------------------------------------------------------------------
# HLO collective parsing with trip counts
# ---------------------------------------------------------------------------

FAKE_HLO = """\
HloModule test

%wide.body (p: (s32[], bf16[128,256])) -> (s32[], bf16[128,256]) {
  %gte = bf16[128,256]{1,0} get-tuple-element(%p), index=1
  %ag = bf16[128,512]{1,0} all-gather(bf16[128,256]{1,0} %gte), dimensions={1}
  %ar = bf16[128,256]{1,0} all-reduce(bf16[128,256]{1,0} %gte), to_apply=%add
}

%wide.cond (p: (s32[], bf16[128,256])) -> pred[] {
  %it = s32[] get-tuple-element(%p), index=0
  %bound = s32[] constant(36)
  %cmp = pred[] compare(%it, %bound), direction=LT
}

ENTRY %main (a: bf16[128,256]) -> bf16[128,256] {
  %w = (s32[], bf16[128,256]) while(%init), condition=%wide.cond, body=%wide.body
  %rs = bf16[64,256]{1,0} reduce-scatter(bf16[128,256]{1,0} %a), dimensions={0}
}
"""


def test_parse_collectives_with_trip_counts():
    out = parse_collectives_with_trips(FAKE_HLO)
    ag_bytes = 128 * 512 * 2          # result bytes, once per trip
    ar_bytes = 128 * 256 * 2          # operand bytes
    rs_bytes = 128 * 256 * 2          # operand bytes, outside the loop
    assert out["all-gather"] == 36 * ag_bytes
    assert out["all-reduce"] == 36 * ar_bytes
    assert out["reduce-scatter"] == rs_bytes
    assert out["total"] == 36 * (ag_bytes + ar_bytes) + rs_bytes


def test_trip_count_extraction():
    comps = _split_computations(FAKE_HLO)
    assert "wide.cond" in comps
    assert _trip_count(comps["wide.cond"]) == 36


def test_parser_on_real_compiled_module():
    """End-to-end: a sharded matmul must show its all-gather."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("model",))
    with mesh:
        f = jax.jit(lambda a, b: a @ b,
                    in_shardings=(jax.sharding.NamedSharding(mesh, P()),
                                  jax.sharding.NamedSharding(mesh, P())))
        c = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    out = parse_collectives_with_trips(c.as_text())
    assert out["total"] >= 0.0        # parses without error on real HLO


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------

def test_analytic_flops_train_scaling():
    cfg = get_config("granite-8b")
    fl4k = analytic_flops(cfg, SHAPES_BY_NAME["train_4k"])
    # total >= 6ND (attention + remat on top)
    assert fl4k["total"] > fl4k["model_flops"]
    assert fl4k["total"] < 3.0 * fl4k["model_flops"]
    # prefill ~ 1/(3*remat) of train for the same tokens
    pf = analytic_flops(cfg, SHAPES_BY_NAME["prefill_32k"])
    assert pf["total"] < fl4k["total"]


def test_analytic_flops_moe_counts_active_only():
    dbrx = get_config("dbrx-132b")
    fl = analytic_flops(dbrx, SHAPES_BY_NAME["train_4k"])
    n_active = dbrx.param_count(active_only=True)
    n_total = dbrx.param_count(active_only=False)
    assert fl["model_flops"] == pytest.approx(
        6.0 * n_active * 256 * 4096, rel=1e-6)
    assert n_total > 2 * n_active


def test_analytic_bytes_decode_dominated_by_cache():
    cfg = get_config("qwen1.5-32b")
    by = analytic_bytes(cfg, SHAPES_BY_NAME["decode_32k"], chips=256)
    # the KV cache read is the dominant term for 32k MHA decode
    assert by["act_traffic_global"] > by["param_traffic_global"]


def test_roofline_terms_structure():
    cfg = get_config("granite-8b")
    coll = {"all-gather": 1e9, "all-reduce": 0.0, "reduce-scatter": 0.0,
            "all-to-all": 0.0, "collective-permute": 0.0, "total": 1e9}
    r = roofline_terms(cfg, SHAPES_BY_NAME["train_4k"], 256, coll)
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["roofline_bound_s"] == max(r["compute_s"], r["memory_s"],
                                        r["collective_s"])
    assert 0.0 < r["roofline_fraction"] <= 1.0


# ---------------------------------------------------------------------------
# sharding specs on real parameter trees (fake mesh, no devices)
# ---------------------------------------------------------------------------

def _fake_mesh(**axes):
    return SimpleNamespace(shape=dict(axes))


def test_param_pspec_dense_model():
    from repro.configs import reduced
    from repro.models import model
    cfg = get_config("granite-8b")
    params_sh = jax.eval_shape(
        lambda k: model.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    with logical_rules(_fake_mesh(pod=2, data=16, model=16)):
        spec = param_pspec(params_sh)
    # stacked layer weights: (L, d, nq*hd) -> (None, fsdp, tp)
    assert spec["layers"]["attn"]["wq"] == P(None, ("pod", "data"), "model")
    assert spec["layers"]["attn"]["wo"] == P(None, "model", ("pod", "data"))
    # embedding: vocab 49152 divides 16 -> model; d 4096 -> fsdp
    assert spec["embed"]["emb"] == P("model", ("pod", "data"))
    # norms replicated
    assert spec["layers"]["ln1"] == P()


def test_param_pspec_moe_expert_fallback():
    from repro.models import model
    with logical_rules(_fake_mesh(pod=2, data=16, model=16)):
        dbrx = get_config("dbrx-132b")
        sh = jax.eval_shape(lambda k: model.init_params(dbrx, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
        spec = param_pspec(sh)
        # stacked (L, E, d, ff): 16 experts divide model -> expert-parallel
        assert spec["layers"]["moe"]["w_gate"] == P(
            None, "model", ("pod", "data"), None)
        qwen = get_config("qwen2-moe-a2.7b")
        sh = jax.eval_shape(lambda k: model.init_params(qwen, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
        spec = param_pspec(sh)
        # 60 experts do NOT divide -> expert dim replicated, ff takes model
        assert spec["layers"]["moe"]["w_gate"] == P(
            None, None, ("pod", "data"), "model")


def test_serving_mode_overrides():
    decode = SHAPES_BY_NAME["decode_32k"]
    small = get_config("seamless-m4t-large-v2")
    big = get_config("llama-3.2-vision-90b")
    assert rules_overrides(decode, small)["fsdp"] is None       # replicate
    assert rules_overrides(decode, big)["fsdp"] == ("data",)    # intra-pod
    train = SHAPES_BY_NAME["train_4k"]
    assert "fsdp" not in rules_overrides(train, small)          # FSDP stays
