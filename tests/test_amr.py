"""Two-level AMR Sedov: every strategy bit-identical to the per-level fused
reference, with coarse+fine task families aggregating through ONE executor.

The acceptance invariants (ISSUE 2):
* s2 / s3 / s2+s3 / fused all reproduce ``amr_reference_step`` EXACTLY
  (assert_array_equal on both levels — the equivalence invariant extended
  to the genuinely adaptive workload);
* shape-agreeing levels share one ``TaskSignature`` family (one compiled
  bucket ladder serves both levels, h being a traced task argument);
* the mixed sub-grid config drives TWO families concurrently through one
  executor, asserted via the per-region bucket-histogram stats;
* prolongation/restriction at the coarse-fine boundary is exact where
  exactness is defined (constant states, restrict-of-prolong).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.amr_sedov import CONFIG, CONFIG_MIXED
from repro.configs.base import AMRHydroConfig, AggregationConfig
from repro.core import AMRSedovScenario, StrategyRunner
from repro.hydro.state import (
    amr_sedov_init, extract_subgrids_multilevel, prolong_coarse,
    restrict_fine, sync_coarse,
)
from repro.hydro.stepper import (
    amr_courant_dt, amr_reference_rhs, amr_reference_step, amr_run,
)

WM = 10 ** 9


@pytest.fixture(scope="module")
def sedov_amr():
    st = amr_sedov_init(CONFIG)
    dt = amr_courant_dt(st.uc, st.uf, CONFIG)
    ref = amr_reference_step(st.uc, st.uf, dt, CONFIG)
    return st, dt, ref


# ---------------------------------------------------------------------------
# coarse-fine exchange primitives
# ---------------------------------------------------------------------------

def test_restrict_of_prolong_is_identity():
    x = jnp.arange(5 * 4 * 4 * 4, dtype=jnp.float32).reshape(5, 4, 4, 4)
    np.testing.assert_array_equal(
        np.asarray(restrict_fine(prolong_coarse(x, 2), 2)), np.asarray(x))


def test_multilevel_extract_shapes():
    cfg = CONFIG
    st = amr_sedov_init(cfg)
    subs_c, subs_f = extract_subgrids_multilevel(st.uc, st.uf, cfg)
    pc = cfg.coarse_subgrid + 2 * cfg.ghost
    pf = cfg.fine_subgrid + 2 * cfg.ghost
    assert subs_c.shape == (cfg.n_subgrids_coarse, cfg.n_fields, pc, pc, pc)
    assert subs_f.shape == (cfg.n_subgrids_fine, cfg.n_fields, pf, pf, pf)


def test_constant_state_has_zero_rhs_on_both_levels():
    """A spatially constant state must be an exact fixed point: the fine
    ghost band (prolongated coarse) and the coarse overlap (restricted
    fine) both reproduce the constant, so every flux difference is 0.0."""
    cfg = CONFIG
    const = jnp.array([1.0, 0.0, 0.0, 0.0, 2.5], jnp.float32)
    uc = jnp.broadcast_to(const[:, None, None, None],
                          (5, cfg.n_coarse, cfg.n_coarse, cfg.n_coarse))
    uf = jnp.broadcast_to(const[:, None, None, None],
                          (5, cfg.n_fine, cfg.n_fine, cfg.n_fine))
    duc, duf = amr_reference_rhs(uc, uf, cfg)
    np.testing.assert_array_equal(np.asarray(duc), 0.0)
    np.testing.assert_array_equal(np.asarray(duf), 0.0)


def test_sync_coarse_overwrites_covered_cells():
    cfg = CONFIG
    st = amr_sedov_init(cfg)
    uc = sync_coarse(jnp.zeros_like(st.uc), st.uf, cfg)
    o, c = cfg.offset, cfg.cover
    np.testing.assert_array_equal(
        np.asarray(uc[:, o:o + c, o:o + c, o:o + c]),
        np.asarray(restrict_fine(st.uf, cfg.refine_ratio)))
    outside = np.asarray(uc).copy()
    outside[:, o:o + c, o:o + c, o:o + c] = 0.0
    np.testing.assert_array_equal(outside, 0.0)


def test_amr_config_validation():
    with pytest.raises(ValueError):
        AMRHydroConfig(cover=7)                     # cannot centre
    with pytest.raises(ValueError):
        AMRHydroConfig(coarse_grids_per_edge=1, coarse_subgrid=8,
                       cover=8)                     # patch hits the boundary


# ---------------------------------------------------------------------------
# the acceptance invariant: every strategy == per-level fused reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,n_exec,max_agg", [
    ("fused", 1, 1),
    ("s2", 2, 1),
    ("s3", 1, 16),
    ("s2+s3", 4, 16),
])
def test_amr_strategy_bit_identical_to_reference(sedov_amr, strategy,
                                                 n_exec, max_agg):
    st, dt, (ref_c, ref_f) = sedov_amr
    agg = AggregationConfig(strategy=strategy, n_executors=n_exec,
                            max_aggregated=max_agg, launch_watermark=WM)
    r = StrategyRunner(AMRSedovScenario(CONFIG), agg)
    out_c, out_f = r.rk3_step((st.uc, st.uf), dt)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(ref_f))


def test_amr_shared_shape_levels_share_one_family(sedov_amr):
    """CONFIG: both levels use 8^3 sub-grids -> ONE TaskSignature region;
    the same bucket-8 program launches coarse AND fine (h is traced)."""
    st, dt, _ = sedov_amr
    agg = AggregationConfig(strategy="s3", n_executors=1, max_aggregated=16,
                            launch_watermark=WM)
    r = StrategyRunner(AMRSedovScenario(CONFIG), agg)
    r.rk3_step((st.uc, st.uf), dt)
    regions = r.stats["regions"]
    assert len(regions) == 1
    (hist,) = [v["aggregated_hist"] for v in regions.values()]
    # 3 RK3 iterations x (1 coarse + 1 fine) launch, all through bucket 8
    assert hist == {8: 6}
    assert r.stats["kernel_launches"] == 6


def test_amr_mixed_subgrids_two_families_one_executor():
    """CONFIG_MIXED: 16^3 coarse + 8^3 fine sub-grids -> two families
    aggregate concurrently through one executor, each with its own bucket
    histogram, and results stay bit-identical to the reference."""
    cfg = CONFIG_MIXED
    st = amr_sedov_init(cfg)
    dt = amr_courant_dt(st.uc, st.uf, cfg)
    ref_c, ref_f = amr_reference_step(st.uc, st.uf, dt, cfg)
    agg = AggregationConfig(strategy="s3", n_executors=1, max_aggregated=16,
                            launch_watermark=WM)
    r = StrategyRunner(AMRSedovScenario(cfg), agg)
    out_c, out_f = r.rk3_step((st.uc, st.uf), dt)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(ref_f))
    regions = r.stats["regions"]
    assert len(regions) == 2
    hists = {k: v["aggregated_hist"] for k, v in regions.items()}
    assert hists["hydro_rhs_s16[5x22x22x22,scalar]"] == {1: 3}
    assert hists["hydro_rhs_s8[5x14x14x14,scalar]"] == {8: 3}
    by_family = r.launches_by_family
    assert by_family == {"hydro_rhs_s16": 3, "hydro_rhs_s8": 3}


def test_amr_warmup_precompiles_both_families(sedov_amr):
    st, dt, (ref_c, ref_f) = sedov_amr
    agg = AggregationConfig(strategy="s3", n_executors=1, max_aggregated=16,
                            launch_watermark=WM)
    r = StrategyRunner(AMRSedovScenario(CONFIG), agg)
    r.warmup()
    compiled = [v for region in r.executor.regions.values()
                for v in region.compiled.values()]
    assert compiled and all(isinstance(f, jax.stages.Compiled)
                            for f in compiled)
    out_c, out_f = r.rk3_step((st.uc, st.uf), dt)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(ref_f))


# ---------------------------------------------------------------------------
# epilogue-fused RK stages (DESIGN.md §10): per-level stage twins
# ---------------------------------------------------------------------------

def test_amr_epilogue_stage_path_bit_identical(sedov_amr):
    """s3 / s2+s3 with fuse_epilogue drive each RK stage as one wave of
    the per-level stage twins (traced h through the fused body + axpy) —
    bit-identical to the fused stage reference, 2 launches per stage."""
    st, dt, (ref_c, ref_f) = sedov_amr
    state = (st.uc, st.uf)
    fused = StrategyRunner(AMRSedovScenario(CONFIG), AggregationConfig(
        strategy="fused", fuse_epilogue=True))
    out_fc, out_ff = fused.rk3_step(state, dt)
    for strategy, n_exec in [("s3", 1), ("s2+s3", 2)]:
        r = StrategyRunner(AMRSedovScenario(CONFIG), AggregationConfig(
            strategy=strategy, n_executors=n_exec, max_aggregated=16,
            launch_watermark=WM, fuse_epilogue=True))
        out_c, out_f = r.rk3_step(state, dt)
        np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_fc))
        np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_ff))
        # one launch per level population per stage, through the twin
        assert r.stats["kernel_launches"] == 6
        assert set(r.launches_by_family) == {"hydro_rhs_s8+epi"}
    # the fused-stage step reassociates ~1e-5 vs the generic combine —
    # allclose, never bit-equal across the two forms
    for got, ref in ((out_fc, ref_c), (out_ff, ref_f)):
        scale = float(np.max(np.abs(np.asarray(ref))))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5 * scale, rtol=1e-5)


def test_amr_mixed_epilogue_two_stage_families():
    """CONFIG_MIXED under fuse_epilogue: TWO stage-twin families aggregate
    concurrently, still bit-identical to the fused stage reference."""
    cfg = CONFIG_MIXED
    st = amr_sedov_init(cfg)
    dt = amr_courant_dt(st.uc, st.uf, cfg)
    state = (st.uc, st.uf)
    fused = StrategyRunner(AMRSedovScenario(cfg), AggregationConfig(
        strategy="fused", fuse_epilogue=True))
    ref_c, ref_f = fused.rk3_step(state, dt)
    r = StrategyRunner(AMRSedovScenario(cfg), AggregationConfig(
        strategy="s3", max_aggregated=16, launch_watermark=WM,
        fuse_epilogue=True))
    out_c, out_f = r.rk3_step(state, dt)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(ref_f))
    assert set(r.launches_by_family) == {"hydro_rhs_s16+epi",
                                         "hydro_rhs_s8+epi"}


def test_amr_run_stays_physical():
    """Two Courant steps of the blast stay finite with positive density and
    pressure proxy (E - KE) on both levels."""
    cfg = CONFIG
    st = amr_run(amr_sedov_init(cfg), cfg, n_steps=2)
    for u in (st.uc, st.uf):
        a = np.asarray(u)
        assert np.all(np.isfinite(a))
        assert np.all(a[0] > 0.0)                   # density
        ke = 0.5 * (a[1] ** 2 + a[2] ** 2 + a[3] ** 2) / a[0]
        # the unlimited high-order scheme may undershoot internal energy at
        # the blast front (the flux solver floors pressure internally);
        # require the undershoot to stay bounded relative to the peak
        assert np.all(a[4] - ke > -1e-2 * np.max(a[4]))
    assert st.t > 0.0 and st.step == 2
