"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.grouped_gemm import grouped_gemm
from repro.kernels.hydro_rhs import (
    hydro_flux_pallas, hydro_reconstruct_pallas, hydro_rhs_pallas,
)

KW = dict(h=0.01, gamma=1.4, ghost=3, subgrid=8)


def _random_state(key, n, s=8, g=3, dtype=jnp.float32):
    p = s + 2 * g
    k1, k2, k3 = jax.random.split(key, 3)
    rho = 1.0 + 0.3 * jax.random.uniform(k1, (n, 1, p, p, p), dtype)
    v = 0.2 * jax.random.normal(k2, (n, 3, p, p, p), dtype)
    pr = 1.0 + 0.5 * jax.random.uniform(k3, (n, 1, p, p, p), dtype)
    e = pr / 0.4 + 0.5 * rho * jnp.sum(v * v, axis=1, keepdims=True)
    return jnp.concatenate([rho, rho * v, e], axis=1)


# ---------------------------------------------------------------------------
# hydro kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["slot_grid", "slot_lane"])
@pytest.mark.parametrize("n_slots", [1, 4, 8])
def test_hydro_rhs_kernel_matches_oracle(layout, n_slots):
    u = _random_state(jax.random.PRNGKey(n_slots), n_slots)
    out = hydro_rhs_pallas(u, layout=layout, **KW)
    want = ref.hydro_rhs_ref(u, **KW)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-6 * max(scale, 1.0), rtol=2e-5)


@pytest.mark.parametrize("subgrid,ghost", [(4, 3), (8, 3), (16, 3)])
def test_hydro_rhs_kernel_shape_sweep(subgrid, ghost):
    """S1 knob sweep: the kernel handles any sub-grid size."""
    kw = dict(h=0.01, gamma=1.4, ghost=ghost, subgrid=subgrid)
    u = _random_state(jax.random.PRNGKey(0), 2, s=subgrid, g=ghost)
    out = hydro_rhs_pallas(u, **kw)
    want = ref.hydro_rhs_ref(u, **kw)
    assert out.shape == (2, 5, subgrid, subgrid, subgrid)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-6 * max(scale, 1.0), rtol=2e-5)


@pytest.mark.parametrize("layout", ["slot_grid", "slot_lane"])
def test_hydro_rhs_kernel_traced_h(layout):
    """Per-slot traced h: a mixed-width batch is bit-identical to the same
    kernel run per width group, and allclose to the static-h program."""
    u = _random_state(jax.random.PRNGKey(7), 8)
    kw = dict(gamma=1.4, ghost=3, subgrid=8)
    # widths ALTERNATE so every lane tile is width-heterogeneous (a kernel
    # that collapsed h to one scalar per block would fail, not pass)
    hs = jnp.where(jnp.arange(8) % 2 == 0, 0.02, 0.01).astype(u.dtype)
    mixed = hydro_rhs_pallas(u, h_slots=hs, layout=layout, lane_tile=4, **kw)
    for i in range(8):
        one = hydro_rhs_pallas(u[i:i + 1], h_slots=hs[i:i + 1],
                               layout=layout, lane_tile=1, **kw)
        np.testing.assert_array_equal(np.asarray(mixed[i:i + 1]),
                                      np.asarray(one))
    static = hydro_rhs_pallas(u, h=0.01, layout=layout, lane_tile=4, **kw)
    scale = float(jnp.max(jnp.abs(static)))
    np.testing.assert_allclose(np.asarray(mixed[1::2]),
                               np.asarray(static[1::2]),
                               atol=2e-5 * max(scale, 1.0), rtol=2e-5)


def test_hydro_split_kernels_match_fused():
    """Paper-faithful two-kernel structure == fused kernel == oracle."""
    u = _random_state(jax.random.PRNGKey(7), 4)
    recon = hydro_reconstruct_pallas(u)
    np.testing.assert_allclose(np.asarray(recon),
                               np.asarray(ref.hydro_reconstruct_ref(u)),
                               rtol=1e-5, atol=1e-5)
    flux = hydro_flux_pallas(recon, **KW)
    fused = hydro_rhs_pallas(u, **KW)
    scale = float(jnp.max(jnp.abs(flux)))
    np.testing.assert_allclose(np.asarray(flux), np.asarray(fused),
                               atol=3e-6 * max(scale, 1.0), rtol=2e-5)


# ---------------------------------------------------------------------------
# grouped GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,k,n", [(4, 256, 512, 384), (2, 128, 256, 128),
                                     (8, 128, 128, 256)])
def test_grouped_gemm_sweep(dtype, e, c, k, n):
    key = jax.random.PRNGKey(e * 100 + n)
    ks = jax.random.split(key, 3)
    x = (jax.random.normal(ks[0], (e, c, k)) * 0.1).astype(dtype)
    w = (jax.random.normal(ks[1], (e, k, n)) * 0.1).astype(dtype)
    gl = jax.random.randint(ks[2], (e,), 0, c + 1)
    y = grouped_gemm(x, w, gl, bc=128, bn=128, bk=128)
    want = ref.grouped_gemm_ref(x, w, gl)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_grouped_gemm_empty_and_full_groups():
    e, c, k, n = 3, 128, 128, 128
    x = jnp.ones((e, c, k), jnp.float32)
    w = jnp.ones((e, k, n), jnp.float32)
    gl = jnp.array([0, c, 17], jnp.int32)
    y = grouped_gemm(x, w, gl)
    assert float(jnp.max(jnp.abs(y[0]))) == 0.0          # empty group -> 0
    np.testing.assert_allclose(np.asarray(y[1]), float(k))
    assert float(jnp.max(jnp.abs(y[2, 17:]))) == 0.0     # beyond group -> 0
    np.testing.assert_allclose(np.asarray(y[2, :17]), float(k))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(8, 2), (4, 4), (12, 4)])
@pytest.mark.parametrize("s,bs", [(512, 128), (1024, 512)])
def test_decode_attention_sweep(hq, hkv, s, bs):
    b, d = 3, 64
    key = jax.random.PRNGKey(hq * s)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    cl = jax.random.randint(ks[3], (b,), 1, s + 1)
    o = decode_attention(q, kc, vc, cl, bs=bs)
    want = ref.decode_attention_ref(q, kc, vc, cl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_ragged_lengths():
    """Aggregated requests of very different lengths stay independent."""
    b, hq, hkv, d, s = 4, 4, 2, 32, 512
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    cl = jnp.array([1, 100, 333, 512], jnp.int32)
    batched = decode_attention(q, kc, vc, cl, bs=128)
    for i in range(b):
        solo = decode_attention(q[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                                cl[i:i + 1], bs=128)
        np.testing.assert_allclose(np.asarray(batched[i]),
                                   np.asarray(solo[0]), atol=2e-5, rtol=2e-5)


def test_decode_attention_bf16():
    b, hq, hkv, d, s = 2, 4, 2, 64, 256
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, d)).astype(jnp.bfloat16)
    kc = jax.random.normal(ks[1], (b, s, hkv, d)).astype(jnp.bfloat16)
    vc = jax.random.normal(ks[2], (b, s, hkv, d)).astype(jnp.bfloat16)
    cl = jnp.array([256, 33], jnp.int32)
    o = decode_attention(q, kc, vc, cl, bs=128)
    want = ref.decode_attention_ref(q, kc, vc, cl)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)
