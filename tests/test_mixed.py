"""Per-family strategy routing (``mixed``, DESIGN.md §12).

The acceptance invariants:

* every per-family assignment in the s2/s3/fused product reproduces the
  fused per-family reference on all three scenarios — bit-identical,
  except where the repo already documents the s2 caveat
  (``test_gravity_s2_matches_reference``: the gravity body reassociates
  1-2 ulp inside the donated scatter program on XLA:CPU, so s2-routed
  gravity asserts tight allclose instead);
* random ``family_strategies`` dicts (exact keys, the ``"*"`` wildcard,
  ``"auto"`` entries) preserve the identity under varying executor-pool
  interleavings (hypothesis property);
* the resolved route and its cost-model justification are observable in
  ``stats["regions"]``;
* guard="finite" composes with routing: an injected NaN in an s3-routed
  family is contained by the executor's bisection (``TaskFailedError``
  naming the culprit), while s2/fused-routed families trip the strategy's
  own per-family tripwire (``NonFiniteStateError`` naming family+route);
* bad assignments (unknown family, unknown route) fail fast at runner
  construction.

Plus unit coverage for the §12 substrate: the multi-path
``BucketCostModel`` (s2 width tables, ``predict_s2_wave``) and the
per-family ``flush_policy`` / ``resolve_family_option`` resolution.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.amr_sedov import CONFIG_MIXED
from repro.configs.base import (
    AggregationConfig, HydroConfig, resolve_family_option,
)
from repro.configs.gravity import CONFIG_SMALL
from repro.core import (
    AMRSedovScenario, FaultInjector, FaultSpec, GravityScenario,
    StrategyRunner, TaskFailedError, UniformSedovScenario,
)
from repro.core.aggregation import (
    AggregationExecutor, BucketCostModel, s2_width_candidates,
)
from repro.core.faults import NonFiniteStateError

WM = 10 ** 9
ROUTES = ("s2", "s3", "fused")
UCFG = HydroConfig(subgrid=8, ghost=3, levels=1)
GCFG = CONFIG_SMALL


def _mixed_runner(scenario, family_strategies, *, n_exec=2, **kw):
    agg = AggregationConfig(strategy="mixed", n_executors=n_exec,
                            max_aggregated=16, launch_watermark=WM,
                            family_strategies=family_strategies, **kw)
    return StrategyRunner(scenario, agg)


def _assert_matches(out, ref, *, exact):
    outs = out if isinstance(out, tuple) else (out,)
    refs = ref if isinstance(ref, tuple) else (ref,)
    for o, r in zip(outs, refs):
        if exact:
            np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
        else:
            scale = float(np.max(np.abs(np.asarray(r))))
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       atol=1e-6 * scale, rtol=1e-6)


@pytest.fixture(scope="module")
def uniform():
    from repro.hydro.state import sedov_init
    from repro.hydro.stepper import courant_dt
    st_ = sedov_init(UCFG)
    dt = courant_dt(st_.u, UCFG)
    ref = StrategyRunner(UniformSedovScenario(UCFG),
                         AggregationConfig(strategy="fused")).rk3_step(
        st_.u, dt)
    return st_.u, dt, ref


@pytest.fixture(scope="module")
def amr_mixed():
    from repro.hydro.state import amr_sedov_init
    from repro.hydro.stepper import amr_courant_dt
    st_ = amr_sedov_init(CONFIG_MIXED)
    dt = amr_courant_dt(st_.uc, st_.uf, CONFIG_MIXED)
    ref = StrategyRunner(AMRSedovScenario(CONFIG_MIXED),
                         AggregationConfig(strategy="fused")).rk3_step(
        (st_.uc, st_.uf), dt)
    return (st_.uc, st_.uf), dt, ref


@pytest.fixture(scope="module")
def grav():
    from repro.hydro.state import sedov_init
    from repro.hydro.stepper import courant_dt
    st_ = sedov_init(GCFG.hydro)
    dt = courant_dt(st_.u, GCFG.hydro)
    ref = StrategyRunner(GravityScenario(GCFG),
                         AggregationConfig(strategy="fused")).rk3_step(
        st_.u, dt)
    return st_.u, dt, ref


# ---------------------------------------------------------------------------
# the product sweep: every per-family assignment == the fused reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route", ROUTES)
def test_mixed_uniform_single_family_product(uniform, route):
    u, dt, ref = uniform
    r = _mixed_runner(UniformSedovScenario(UCFG), {"hydro_rhs": route})
    out = r.rk3_step(u, dt)
    _assert_matches(out, ref, exact=True)


@pytest.mark.parametrize("rc,rf", list(itertools.product(ROUTES, ROUTES)))
def test_mixed_amr_two_family_product(amr_mixed, rc, rf):
    """CONFIG_MIXED: 16^3 coarse + 8^3 fine are distinct families; every
    (coarse route, fine route) pair is bit-identical to the per-level
    fused reference."""
    state, dt, ref = amr_mixed
    r = _mixed_runner(AMRSedovScenario(CONFIG_MIXED),
                      {"hydro_rhs_s16": rc, "hydro_rhs_s8": rf})
    out = r.rk3_step(state, dt)
    _assert_matches(out, ref, exact=True)


@pytest.mark.parametrize("rh,rg", list(itertools.product(ROUTES, ROUTES)))
def test_mixed_gravity_two_family_product(grav, rh, rg):
    """Hydro and gravity route independently through one runner.  Exact
    everywhere except s2-routed gravity (the documented scatter-program
    ulp caveat, same tolerance as test_gravity_s2_matches_reference)."""
    u, dt, ref = grav
    r = _mixed_runner(GravityScenario(GCFG),
                      {"hydro_rhs": rh, "gravity": rg})
    out = r.rk3_step(u, dt)
    _assert_matches(out, ref, exact=rg != "s2")


# ---------------------------------------------------------------------------
# hypothesis: random assignments / wildcards / interleavings
# ---------------------------------------------------------------------------

_GRAV_CACHE: list = []


def _grav_data():
    """Module-level lazy twin of the ``grav`` fixture: the hypothesis
    fallback shim (tests/conftest.py) rewrites @given tests to zero-arg
    callables, so the property test cannot take pytest fixtures."""
    if not _GRAV_CACHE:
        from repro.hydro.state import sedov_init
        from repro.hydro.stepper import courant_dt
        st_ = sedov_init(GCFG.hydro)
        dt = courant_dt(st_.u, GCFG.hydro)
        ref = StrategyRunner(GravityScenario(GCFG),
                             AggregationConfig(strategy="fused")).rk3_step(
            st_.u, dt)
        _GRAV_CACHE.append((st_.u, dt, ref))
    return _GRAV_CACHE[0]


@settings(max_examples=10, deadline=None)
@given(a=st.integers(0, 3), b=st.integers(0, 3),
       wild=st.integers(0, 1), n_exec=st.integers(1, 3))
def test_mixed_random_assignments_preserve_identity(a, b, wild, n_exec):
    """Random family_strategies dicts — exact keys or the "*" wildcard,
    including "auto" entries — preserve the reference identity under
    random executor-pool sizes (which vary the two families' dispatch
    interleaving)."""
    u, dt, ref = _grav_data()
    routes = ROUTES + ("auto",)
    rh, rg = routes[a], routes[b]
    fam = ({"hydro_rhs": rh, "*": rg} if wild
           else {"hydro_rhs": rh, "gravity": rg})
    r = _mixed_runner(GravityScenario(GCFG), fam, n_exec=n_exec)
    out = r.rk3_step(u, dt)
    # unmeasured "auto" falls back to s3 (exact); only explicit s2-routed
    # gravity carries the scatter-program ulp caveat
    _assert_matches(out, ref, exact=rg != "s2")


# ---------------------------------------------------------------------------
# observability: resolved routes + cost-model justification
# ---------------------------------------------------------------------------

def test_mixed_explicit_routes_recorded(grav):
    u, dt, _ = grav
    r = _mixed_runner(GravityScenario(GCFG),
                      {"hydro_rhs": "s2", "gravity": "fused"})
    r.rk3_step(u, dt)
    sel = {k: v.get("selected_strategy")
           for k, v in r.stats["regions"].items()}
    assert sel["hydro_rhs[5x14x14x14,scalar]"] == "s2"
    assert sel["gravity[5x14x14x14,scalar]"] == "fused"
    # s2-routed family publishes launch counts + width histogram (stats
    # parity: the same surface the executor gives aggregated families)
    s2_stats = r.stats["regions"]["hydro_rhs[5x14x14x14,scalar]"]
    n = GCFG.hydro.n_subgrids
    assert s2_stats["submitted"] == 3 * n
    assert s2_stats["launches"] == 3 * n          # width 1 without model
    assert s2_stats["aggregated_hist"] == {1: 3 * n}


def test_mixed_auto_selection_measured(uniform):
    """auto + cost_model: warmup measures the family's s2 / s3 / fused
    wall time, ``select_strategy`` routes to the measured minimum, and
    the decision (with its justification) lands in the region stats."""
    u, dt, ref = uniform
    agg = AggregationConfig(strategy="mixed", n_executors=2,
                            max_aggregated=UCFG.n_subgrids,
                            launch_watermark=WM, cost_model=True,
                            cost_samples=1)
    r = StrategyRunner(UniformSedovScenario(UCFG), agg)
    r.warmup(wave_only=True)
    out = r.rk3_step(u, dt)
    _assert_matches(out, ref, exact=True)
    (stats,) = [v for k, v in r.stats["regions"].items()
                if k.startswith("hydro_rhs")]
    costs = stats["strategy_costs"]
    assert stats["selected_strategy"] in ROUTES
    assert set(costs) >= {"s2", "s3", "fused", "s2_width"}
    assert all(v > 0 for v in costs.values())
    assert costs[stats["selected_strategy"]] == min(
        costs[p] for p in ROUTES if p in costs)


def test_mixed_rejects_unknown_family_and_route():
    sc = UniformSedovScenario(UCFG)
    with pytest.raises(ValueError, match="names no kernel"):
        _mixed_runner(sc, {"not_a_family": "s3"})
    with pytest.raises(ValueError, match="family_strategies"):
        _mixed_runner(sc, {"hydro_rhs": "warp"})


# ---------------------------------------------------------------------------
# guard="finite" x routing (DESIGN.md §11 x §12)
# ---------------------------------------------------------------------------

def _inject(kernel):
    return FaultInjector([FaultSpec(site="payload", kernel=kernel, task=0,
                                    mode="nan", times=1)], seed=0)


@pytest.mark.parametrize("kernel,route,other", [
    ("hydro_rhs", "s3", "s2"),
    ("gravity", "s3", "fused"),
])
def test_mixed_guard_s3_routed_fault_bisected(grav, kernel, route, other):
    """A poisoned task in an s3-routed family keeps the executor's full
    containment: bisection isolates the culprit and the failure surfaces
    as TaskFailedError, even while the OTHER family routes elsewhere."""
    u, dt, _ = grav
    fam = {kernel: route,
           ("gravity" if kernel == "hydro_rhs" else "hydro_rhs"): other}
    agg = AggregationConfig(strategy="mixed", n_executors=2,
                            max_aggregated=16, launch_watermark=WM,
                            family_strategies=fam, guard="finite")
    r = StrategyRunner(GravityScenario(GCFG), agg,
                       fault_injector=_inject(kernel))
    with pytest.raises(TaskFailedError):
        r.rk3_step(u, dt)


@pytest.mark.parametrize("kernel,route", [
    ("hydro_rhs", "s2"),
    ("hydro_rhs", "fused"),
    ("gravity", "s2"),
    ("gravity", "fused"),
])
def test_mixed_guard_nonexecutor_route_tripwire(grav, kernel, route):
    """s2/fused-routed families have no bucket structure to bisect: the
    strategy's own audit trips on the injected NaN, naming the family and
    its route."""
    u, dt, _ = grav
    fam = {"hydro_rhs": "s3", "gravity": "s3"}
    fam[kernel] = route
    agg = AggregationConfig(strategy="mixed", n_executors=2,
                            max_aggregated=16, launch_watermark=WM,
                            family_strategies=fam, guard="finite")
    r = StrategyRunner(GravityScenario(GCFG), agg,
                       fault_injector=_inject(kernel))
    with pytest.raises(NonFiniteStateError) as ei:
        r.rk3_step(u, dt)
    assert kernel in str(ei.value) and route in str(ei.value)


def test_mixed_unguarded_faults_still_poison(grav):
    """Without the guard, the injected NaN flows into the result (faults
    are payload corruption, not exceptions) — the tripwire is what turns
    it into containment."""
    u, dt, _ = grav
    agg = AggregationConfig(strategy="mixed", n_executors=2,
                            max_aggregated=16, launch_watermark=WM,
                            family_strategies={"hydro_rhs": "s2",
                                               "gravity": "s3"})
    r = StrategyRunner(GravityScenario(GCFG), agg,
                       fault_injector=_inject("hydro_rhs"))
    out = r.rk3_step(u, dt)
    assert not bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# the §12 substrate: multi-path cost model + per-family flush policy
# ---------------------------------------------------------------------------

def test_cost_model_paths_are_independent():
    m = BucketCostModel()
    m.record(8, 1e-3)                       # default path: s3
    m.record(1, 2e-4, path="s2")
    m.record(4, 5e-4, path="s2")
    m.record(8, 3e-3, path="fused")
    assert m.measured() and m.measured("s2") and m.measured("fused")
    assert set(m.paths()) == {"s3", "s2", "fused"}
    assert m.buckets("s2") == (1, 4)
    tables = m.as_stats_paths()
    assert tables["s3"] == {8: 1.0} and tables["s2"] == {1: 0.2, 4: 0.5}
    m.clear()
    assert not m.measured() and not m.measured("s2")


def test_predict_s2_wave_picks_cheapest_width():
    m = BucketCostModel()
    assert m.predict_s2_wave(8) is None     # unmeasured
    m.record(1, 1e-3, path="s2")
    m.record(4, 1.5e-3, path="s2")
    # wave 10 @ width 4: 2*1.5ms + 2*1ms = 5ms; @ width 1: 10ms
    w, t = m.predict_s2_wave(10)
    assert w == 4
    np.testing.assert_allclose(t, 5e-3)
    # make width 1 cheaper than coalescing: width 1 must win
    m2 = BucketCostModel()
    m2.record(1, 1e-4, path="s2")
    m2.record(4, 9e-4, path="s2")
    assert m2.predict_s2_wave(8)[0] == 1


def test_s2_width_candidates():
    assert s2_width_candidates(1) == (1,)
    assert s2_width_candidates(2) == (1, 2)
    assert s2_width_candidates(8) == (1, 2, 8)
    assert s2_width_candidates(11) == (1, 2, 8)
    assert s2_width_candidates(64) == (1, 2, 64)


def test_resolve_family_option():
    table = {"hydro_rhs": "s2", "*": "fused"}
    assert resolve_family_option(table, "hydro_rhs", "s3") == "s2"
    assert resolve_family_option(table, "hydro_rhs+epi", "s3") == "s2"
    assert resolve_family_option(table, "gravity", "s3") == "fused"
    assert resolve_family_option({"gravity": "s3"}, "other", "s3") == "s3"
    assert resolve_family_option("cost", "anything", "eager") == "cost"
    assert resolve_family_option(None, "anything", "eager") == "eager"


def test_per_family_flush_policy_resolved_and_traced():
    """A dict-valued flush_policy routes each family to its own drain
    policy; non-eager families record their decision trace."""
    cfg = AggregationConfig(max_aggregated=8, launch_watermark=1,
                            flush_policy={"k": "watermark", "*": "eager"})
    exe = AggregationExecutor(None, cfg)
    exe.register("k", lambda x: x * 2.0)
    exe.register("j", lambda x: x + 1.0)
    parents = (jnp.arange(4, dtype=jnp.float32).reshape(4, 1),)
    for kernel in ("k", "j"):
        exe.submit_range(parents, 0, 4, kernel=kernel)
    exe.flush()
    assert exe.stats["flush_policy"] == {"k": "watermark", "*": "eager"}
    regions = exe.stats["regions"]
    traced = {k: v.get("flush_decisions") for k, v in regions.items()}
    k_key = [k for k in regions if k.startswith("k[")][0]
    j_key = [k for k in regions if k.startswith("j[")][0]
    assert traced[k_key] is not None and traced[k_key]["policy"] == \
        "watermark"
    assert traced[j_key] is None            # eager families don't consult

    with pytest.raises(ValueError):
        AggregationExecutor(None, AggregationConfig(
            flush_policy={"k": "bogus"}))
