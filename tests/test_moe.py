"""MoE dispatch invariants (property-based) + grouped-GEMM path equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.models.moe import (
    _dispatch_indices, capacity_chunks, expert_capacity, moe_ffn, moe_init,
)

KEY = jax.random.PRNGKey(0)


@given(t=st.integers(4, 96), e=st.integers(2, 8), k=st.integers(1, 3),
       cap=st.integers(1, 32), seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_dispatch_positions_are_unique_slots(t, e, k, cap, seed):
    """No two kept (token, slot) pairs may claim the same (expert, pos) —
    the aggregated slab chunks are exclusively owned (the paper's SGMT
    buffer-chunk ownership)."""
    k = min(k, e)
    idx = jax.random.randint(jax.random.PRNGKey(seed), (t, k), 0, e)
    pos, keep = _dispatch_indices(idx, e, cap)
    pos, keep, idx = np.asarray(pos), np.asarray(keep), np.asarray(idx)
    claimed = set()
    for ti in range(t):
        for j in range(k):
            if keep[ti, j]:
                slot = (int(idx[ti, j]), int(pos[ti, j]))
                assert slot not in claimed, slot
                assert pos[ti, j] < cap
                claimed.add(slot)
    # positions are dense per expert: counts match min(arrivals, cap)
    for ex in range(e):
        kept = sorted(p for (x, p) in claimed if x == ex)
        assert kept == list(range(len(kept)))


def test_capacity_alignment_divides_chunks():
    for tokens in (1024, 65_536, 1_048_576):
        cfg = get_config("dbrx-132b")
        c = expert_capacity(tokens, cfg)
        n = capacity_chunks(c)
        assert c % n == 0
        assert c % 128 == 0


def test_moe_pallas_path_matches_xla():
    """The aggregated grouped-GEMM kernel path == the einsum path."""
    cfg = reduced(get_config("dbrx-132b")).replace(d_model=128, d_ff=128)
    p = moe_init(KEY, cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_xla = moe_ffn(p, x, cfg, use_pallas=False)
    y_pl = moe_ffn(p, x, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pl),
                               atol=2e-5, rtol=2e-4)


def test_moe_capacity_drop_is_graceful():
    """With capacity_factor << 1 tokens drop but outputs remain finite and
    the kept tokens' outputs are unchanged vs. full capacity."""
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    p = moe_init(KEY, cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    y_low = moe_ffn(p, x, cfg, capacity_factor=0.05)
    assert bool(jnp.all(jnp.isfinite(y_low)))
    y_full = moe_ffn(p, x, cfg, capacity_factor=8.0)
    assert bool(jnp.all(jnp.isfinite(y_full)))
    # dropping changes some outputs, but never to NaN and never the shared
    # expert contribution (present for every token)
    assert y_low.shape == y_full.shape
