"""The equivalence invariant: any strategy mix == unaggregated reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core import (
    AggregationExecutor, BufferPool, DeviceExecutor, ExecutorPool,
    StrategyRunner, UniformSedovScenario,
)
from repro.hydro.state import sedov_init
from repro.hydro.stepper import courant_dt, rk3_step

CFG = HydroConfig(subgrid=8, ghost=3, levels=1)


# ---------------------------------------------------------------------------
# AggregationExecutor semantics
# ---------------------------------------------------------------------------

def _batched_square(x):
    return x * x + 1.0


@given(n_tasks=st.integers(1, 40), max_agg=st.integers(1, 16),
       n_exec=st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_executor_equivalence_property(n_tasks, max_agg, n_exec):
    """For ANY task count / cap / executor count, per-task results equal the
    unaggregated computation exactly."""
    cfg = AggregationConfig(strategy="s3", n_executors=n_exec,
                            max_aggregated=max_agg)
    exe = AggregationExecutor(jax.vmap(_batched_square), cfg)
    xs = [jnp.full((3, 2), float(i)) for i in range(n_tasks)]
    outs = exe.map([(x,) for x in xs])
    for i, (x, o) in enumerate(zip(xs, outs)):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(x * x + 1.0))
    assert exe.stats["submitted"] == n_tasks
    # every launch respected the cap
    assert all(k <= max_agg for k in exe.stats["aggregated_hist"])


def test_executor_respects_max_aggregated():
    cfg = AggregationConfig(strategy="s3", n_executors=1, max_aggregated=4,
                            launch_watermark=10**9)  # never launch-on-idle
    exe = AggregationExecutor(jax.vmap(_batched_square), cfg)
    futs = [exe.submit(jnp.ones((2,)) * i) for i in range(11)]
    # 11 tasks, cap 4: two full buckets forced at the cap, 3 left queued
    assert exe.stats["launches"] == 2
    assert len(exe._queue) == 3
    exe.flush()
    assert all(f.ready() for f in futs)
    hist = exe.stats["aggregated_hist"]
    assert hist.get(4) == 2 and hist.get(2) == 1 and hist.get(1) == 1


def test_bucket_sizes_ladder():
    agg = AggregationConfig(max_aggregated=32)
    assert agg.bucket_sizes() == (1, 2, 4, 8, 16, 32)
    agg = AggregationConfig(max_aggregated=5)
    assert agg.bucket_sizes() == (1, 2, 4, 5)


def test_future_raises_before_launch():
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(jax.vmap(_batched_square), cfg)
    f = exe.submit(jnp.ones((2,)))
    with pytest.raises(RuntimeError):
        f.result()
    exe.flush()
    assert f.result() is not None


def test_executor_pool_round_robin():
    pool = ExecutorPool(3)
    picked = [pool.get().index for _ in range(6)]
    assert picked == [0, 1, 2, 0, 1, 2]


def test_buffer_pool_recycles():
    pool = BufferPool()
    a = pool.acquire((4, 4), np.float32)
    pool.release(a)
    b = pool.acquire((4, 4), np.float32)
    assert a is b
    assert pool.allocations == 1 and pool.reuses == 1
    c = pool.acquire((4, 4), np.float64)      # different dtype -> new alloc
    assert pool.allocations == 2


def test_buffer_pool_stage():
    pool = BufferPool()
    parts = [np.full((2, 2), i, np.float32) for i in range(3)]
    slab = pool.stage(parts)
    assert slab.shape == (3, 2, 2)
    np.testing.assert_array_equal(slab[2], parts[2])


# ---------------------------------------------------------------------------
# strategy runners on the real hydro tasks (the paper's Table III semantics)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sedov_state():
    st = sedov_init(CFG)
    dt = courant_dt(st.u, CFG)
    ref_runner = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="fused", n_executors=1, max_aggregated=1))
    ref = ref_runner.rk3_step(st.u, dt)
    return st, dt, ref


@pytest.mark.parametrize("strategy,n_exec,max_agg", [
    ("s2", 1, 1),
    ("s2", 4, 1),
    ("s3", 1, 4),
    ("s3", 1, 64),
    ("s2+s3", 4, 8),
])
def test_strategy_equivalence(sedov_state, strategy, n_exec, max_agg):
    """Results are identical up to compiled-bucket float reassociation:
    each bucket size is its own XLA program and XLA:CPU vectorizes the
    per-slot reductions differently per batch size (1-2 ulp).  Within one
    bucket size results are bit-identical (test_executor_equivalence)."""
    st, dt, ref = sedov_state
    agg = AggregationConfig(strategy=strategy, n_executors=n_exec,
                            max_aggregated=max_agg)
    r = StrategyRunner(UniformSedovScenario(CFG), agg)
    out = r.rk3_step(st.u, dt)
    scale = float(np.max(np.abs(np.asarray(ref))))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5 * scale, rtol=1e-5)


def test_strategy_launch_counts(sedov_state):
    st, dt, _ = sedov_state
    n = CFG.n_subgrids
    s2 = StrategyRunner(UniformSedovScenario(CFG),
                        AggregationConfig(strategy="s2"))
    s2.rhs(st.u)
    assert s2.stats["kernel_launches"] == n            # one per task
    fused = StrategyRunner(UniformSedovScenario(CFG),
                           AggregationConfig(strategy="fused"))
    fused.rhs(st.u)
    assert fused.stats["kernel_launches"] == 1
    s3 = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy="s3", max_aggregated=n, launch_watermark=10**9))
    s3.rhs(st.u)
    # cap==n and watermark disabled -> at most a few bucketed launches
    assert s3.stats["kernel_launches"] <= 3


def test_strategy1_is_a_config(sedov_state):
    """S1 = larger sub-grids: same cells, fewer tasks, same physics."""
    cfg16 = HydroConfig(subgrid=16, ghost=3, levels=0)
    assert cfg16.cells_total == CFG.cells_total
    st16 = sedov_init(cfg16)
    st8, dt, _ = sedov_state
    dt16 = courant_dt(st16.u, cfg16)
    # identical initial grids -> identical Courant dt
    assert float(dt16) == pytest.approx(float(dt), rel=1e-6)
    out8 = rk3_step(st8.u, dt, CFG)
    out16 = rk3_step(st16.u, dt, cfg16)
    # same global field evolution regardless of decomposition
    np.testing.assert_allclose(np.asarray(out16), np.asarray(out8),
                               rtol=2e-4, atol=2e-4)
