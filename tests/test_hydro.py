"""Hydro solver: conservation, Courant condition, Sedov physics, decomposition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HydroConfig
from repro.hydro.euler import cons_to_prim, euler_flux, prim_to_cons
from repro.hydro.ppm import DIR_PAIRS, ppm_pair, ppm_reconstruct_all
from repro.hydro.state import (
    assemble_global, extract_subgrids, sedov_init,
)
from repro.hydro.stepper import (
    courant_dt, rk3_step, run, shock_radius, total_conserved,
)

CFG = HydroConfig(subgrid=8, ghost=3, levels=1)   # 16^3 cells, 8 sub-grids


def test_prim_cons_roundtrip():
    key = jax.random.PRNGKey(0)
    rho = 1.0 + jax.random.uniform(key, (4, 4, 4))
    v = 0.3 * jax.random.normal(key, (3, 4, 4, 4))
    p = 0.5 + jax.random.uniform(key, (4, 4, 4))
    u = prim_to_cons(rho, v[0], v[1], v[2], p, 1.4)
    rho2, vx, vy, vz, p2 = cons_to_prim(u, 1.4)
    np.testing.assert_allclose(rho2, rho, rtol=1e-6)
    np.testing.assert_allclose(p2, p, rtol=1e-5)


def test_flux_momentum_includes_pressure():
    u = prim_to_cons(jnp.ones(()), jnp.zeros(()), jnp.zeros(()),
                     jnp.zeros(()), jnp.ones(()), 1.4)
    for ax in range(3):
        f = euler_flux(u, ax, 1.4)
        # at rest: only the momentum component along `ax` carries pressure
        assert float(f[1 + ax]) == pytest.approx(1.0)
        assert float(f[0]) == 0.0


def test_ppm_constant_field_is_exact():
    u = jnp.full((5, 12, 12, 12), 3.25)
    for d in DIR_PAIRS:
        lo, hi = ppm_pair(u, d)
        np.testing.assert_allclose(lo, 3.25, rtol=1e-6)
        np.testing.assert_allclose(hi, 3.25, rtol=1e-6)


def test_ppm_monotone_no_overshoot():
    # a monotone ramp along x must reconstruct within neighbour bounds
    x = jnp.arange(16, dtype=jnp.float32)
    u = jnp.broadcast_to(x[:, None, None], (16, 16, 16))[None]
    lo, hi = ppm_pair(u, (1, 0, 0))
    interior = (slice(None), slice(2, -2), slice(None), slice(None))
    assert bool(jnp.all(lo[interior] <= u[interior] + 1e-5))
    assert bool(jnp.all(hi[interior] >= u[interior] - 1e-5))
    assert bool(jnp.all(hi[interior] - lo[interior] <= 1.0 + 1e-4))


def test_extract_assemble_roundtrip():
    st = sedov_init(CFG)
    subs = extract_subgrids(st.u, CFG.subgrid, CFG.ghost)
    g = CFG.ghost
    interiors = subs[:, :, g:-g, g:-g, g:-g]
    back = assemble_global(interiors, CFG.subgrid)
    np.testing.assert_array_equal(back, st.u)


def test_ghost_cells_match_neighbours_periodic():
    st = sedov_init(CFG)
    subs = extract_subgrids(st.u, CFG.subgrid, CFG.ghost, bc="periodic")
    # sub-grid 0's +x ghost layer must equal sub-grid (1,0,0)'s first x-slice
    g, s = CFG.ghost, CFG.subgrid
    sub0 = subs[0]
    sub_x1 = subs[CFG.grids_per_edge ** 0 * 0 + 4]  # index (1,0,0) of 2x2x2
    np.testing.assert_array_equal(
        sub0[:, g + s:g + s + g, g:-g, g:-g],
        sub_x1[:, g:2 * g, g:-g, g:-g])


def test_conservation_periodic():
    st = sedov_init(CFG)
    h = CFG.domain / st.u.shape[-1]
    c0 = total_conserved(st.u, h)
    out = run(st, CFG, 3, bc="periodic")
    c1 = total_conserved(out.u, h)
    # mass & energy conserved to fp32 machine precision
    assert abs(float(c1[0] - c0[0]) / float(c0[0])) < 1e-5
    assert abs(float(c1[4] - c0[4]) / float(c0[4])) < 1e-5
    # momentum stays ~0 by symmetry
    assert float(jnp.max(jnp.abs(c1[1:4]))) < 1e-5


def test_courant_dt_scales_with_resolution():
    """Paper §IV-B: doubling resolution halves the allowed time-step.
    Measured on a uniform medium so the signal speed is resolution-
    independent (the Sedov IC deposits energy over a resolution-dependent
    radius, confounding the pure 2x)."""
    c1 = HydroConfig(subgrid=8, ghost=3, levels=1)
    c2 = HydroConfig(subgrid=8, ghost=3, levels=2)

    def uniform(cfg):
        n = cfg.grids_per_edge * cfg.subgrid
        one = jnp.ones((n, n, n))
        zero = jnp.zeros((n, n, n))
        return prim_to_cons(one, zero, zero, zero, one, cfg.gamma)

    dt1 = float(courant_dt(uniform(c1), c1))
    dt2 = float(courant_dt(uniform(c2), c2))
    assert dt2 == pytest.approx(dt1 / 2, rel=1e-5)


def test_sedov_shock_expands_and_stays_finite():
    st = sedov_init(CFG)
    out1 = run(st, CFG, 2)
    out2 = run(out1, CFG, 3)
    assert not bool(jnp.any(jnp.isnan(out2.u)))
    r1 = float(shock_radius(out1.u, CFG))
    r2 = float(shock_radius(out2.u, CFG))
    assert r2 > r1 > 0.0
    # density stays positive
    assert float(jnp.min(out2.u[0])) > 0.0


def test_sedov_scaling_law():
    """Shock radius ~ (E t^2 / rho)^(1/5) — check sub-linear t^(~2/5)
    growth once the blast is established (the first steps are dominated by
    the finite energy-deposition radius, so measure between two later
    epochs and bound the exponent loosely)."""
    cfg = HydroConfig(subgrid=8, ghost=3, levels=2)  # 32^3 for resolution
    st = sedov_init(cfg)
    s1 = run(st, cfg, 6)
    s2 = run(s1, cfg, 12)
    r1, t1 = float(shock_radius(s1.u, cfg)), s1.t
    r2, t2 = float(shock_radius(s2.u, cfg)), s2.t
    assert r2 > r1 > 0.0
    measured = np.log(r2 / r1) / np.log(t2 / t1)
    # clearly sub-linear, clearly growing
    assert 0.05 < measured < 0.95, (measured, r1, r2, t1, t2)


def test_table2_cell_counts():
    """Paper Table II: 8^3/3-levels and 16^3/2-levels give identical cells."""
    from repro.configs import sedov, sedov_16
    assert sedov.cells_total == 262144
    assert sedov_16.cells_total == 262144
    assert sedov.n_subgrids == 512
    assert sedov_16.n_subgrids == 64
    # 5 kernels x 3 iterations x sub-grids = kernel calls per time-step
    assert 5 * 3 * sedov.n_subgrids == 7680
    assert 5 * 3 * sedov_16.n_subgrids == 960
