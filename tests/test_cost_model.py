"""DESIGN.md §10: measured per-bucket cost models, wall-time ladder tuning
and the watermark-adaptive flush policies.

Invariants pinned here:

* ``derive_ladder`` edge cases — an empty histogram yields the bare
  remainder ladder ``(1,)``, a single wave larger than the cap seeds its
  cap-split remainder (and an exact cap multiple seeds no remainder at
  all), and exact cost-model ties resolve to the smaller compile
  footprint;
* ``BucketCostModel`` reports medians, interpolates between measured
  bucket sizes, clamps below the smallest measurement and never
  extrapolates under the largest one;
* with ``cost_model=True`` the executor times the drain-reachable buckets
  (``stats["regions"][fam]["cost_model"]``) and the retuned ladder is the
  measured-fastest plan (``tuned_by == "measured"``), with the
  ``inner_chunk`` memo keyed by backend so a timed choice never leaks
  across devices;
* ``executor.retune()`` is a NO-OP for regions without new waves since
  the last retune (no degenerate ``(1,)`` ladder from an empty
  histogram, no re-derivation from stale evidence);
* property (hypothesis shim): the watermark/cost flush policies change
  only WHEN launches fire — random two-family interleavings of ranges
  and per-task submissions gather bit-identically to the eager policy
  and to the direct computation, in order.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import greedy_launches
from hypothesis import given, settings, strategies as st

from repro.configs.base import AggregationConfig
from repro.core import (
    AggregationExecutor, BucketCostModel, derive_ladder, gather_futures,
    ladder_candidates,
)

WM = 10 ** 9


def _affine(x):
    return 2.0 * x + 1.0


def _affine_b(x):
    return 3.0 * x - 2.0


def _linear_model(buckets, per_slot=1.0):
    """t(b) = per_slot * b: zero launch overhead, every plan ties."""
    m = BucketCostModel()
    for b in buckets:
        m.record(b, per_slot * b)
    return m


# ---------------------------------------------------------------------------
# derive_ladder edge cases
# ---------------------------------------------------------------------------

def test_derive_ladder_empty_hist_is_remainder_only():
    """No evidence -> the mandatory remainder bucket alone, never a made-up
    ladder (retune() guards this path, but the function must be safe)."""
    assert derive_ladder({}, cap=64, budget=4) == (1,)
    assert derive_ladder({0: 3, -2: 1}, cap=64, budget=4) == (1,)


def test_derive_ladder_single_over_cap_wave_seeds_remainder():
    """ONE observed 70-wave under cap 64 must keep {64, 6} as a pair: the
    cap bucket without its remainder would drain 64 + six 1s."""
    ladder = derive_ladder({70: 1}, cap=64, budget=4)
    assert 64 in ladder and 6 in ladder
    assert greedy_launches(70, ladder) == 2


def test_derive_ladder_exact_cap_multiple_has_no_remainder():
    """A 128-wave under cap 64 splits 64+64 — there is no remainder to
    seed, and the drain is two cap launches."""
    ladder = derive_ladder({128: 3}, cap=64, budget=4)
    assert 64 in ladder
    assert greedy_launches(128, ladder) == 2


def test_derive_ladder_cost_tie_resolves_to_smaller_footprint():
    """Under a zero-overhead linear model every decomposition of a wave
    predicts the same wall time — the tuner must then keep the SMALLEST
    compile footprint (1,), dropping even the seeded mega bucket."""
    model = _linear_model((1, 2, 24, 64))
    assert derive_ladder({24: 3}, cap=64, budget=4, cost_model=model) == (1,)


def test_derive_ladder_overhead_model_prefers_mega_bucket():
    """Launch-overhead-dominated measurements reproduce the §9 behavior:
    one bucket covering the steady wave."""
    m = BucketCostModel()
    for b in ladder_candidates({24: 3}, 64):
        m.record(b, 1.0 + 0.01 * b)
    assert derive_ladder({24: 3}, cap=64, budget=4, cost_model=m) == (1, 24)


def test_derive_ladder_superlinear_model_rejects_mega_bucket():
    """Measured time CAN say the cap bucket is pessimal (e.g. a flat vmap
    blowing the cache): the tuner must drop the seeded cap and cover the
    wave with the cheaper halves instead — launch-count tuning can never
    learn this."""
    m = BucketCostModel()
    m.record(1, 1.0)
    m.record(32, 2.0)
    m.record(64, 100.0)
    ladder = derive_ladder({64: 3}, cap=64, budget=4, cost_model=m)
    assert 64 not in ladder and 32 in ladder


# ---------------------------------------------------------------------------
# BucketCostModel
# ---------------------------------------------------------------------------

def test_cost_model_median_and_interpolation():
    m = BucketCostModel()
    for t in (1.0, 3.0, 100.0):      # median 3.0, robust to the outlier
        m.record(4, t)
    m.record(8, 5.0)
    assert m.time(4) == 3.0
    assert m.predict(6) == pytest.approx(4.0)        # midpoint of 3 and 5
    assert m.predict(2) == 3.0                       # clamped below min
    assert m.predict(16) == pytest.approx(9.0)       # last-segment slope
    assert m.predict_seq((4, 8)) == pytest.approx(8.0)


def test_cost_model_floor_and_empty():
    m = BucketCostModel()
    with pytest.raises(ValueError):
        m.predict(4)
    m.record(8, 5.0)
    m.record(16, 1.0)                 # noisy downward slope...
    assert m.predict(64) == 1.0       # ...never extrapolates below max's t
    m.clear()
    assert not m.measured()


# ---------------------------------------------------------------------------
# executor end-to-end: measured tuning + persistence
# ---------------------------------------------------------------------------

def test_cost_model_retune_measures_and_tunes():
    cfg = AggregationConfig(strategy="s3", max_aggregated=16,
                            launch_watermark=WM, autotune=True,
                            autotune_warmup=1, cost_model=True,
                            cost_samples=1)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(32.0).reshape(16, 2)
    for _ in range(3):
        fut = exe.submit_range((parent,), 0, 16)
        exe.flush()
    region = next(iter(exe.regions.values()))
    assert region.stats["tuned_by"] == "measured"
    table = region.stats["cost_model"]
    assert table and all(ms >= 0 for ms in table.values())
    # every drain-reachable candidate of the observed waves was timed
    assert set(table) == {b for b in ladder_candidates({16: 1}, 16)}
    assert 16 in region.buckets       # the steady wave stays one launch
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))


def test_chunk_memo_keyed_by_backend():
    """The inner_chunk memo must never serve a choice timed on another
    backend: every entry's key leads with (backend, device kind)."""
    from repro.core.aggregation import _CHUNK_TUNE_MEMO, _backend_key
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=WM, inner_chunk="auto")
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.zeros((8, 4))
    exe.warmup(parent_shapes=(parent,))
    assert _CHUNK_TUNE_MEMO, "auto warmup should have tuned a chunk"
    assert all(k[0] == _backend_key() for k in _CHUNK_TUNE_MEMO)


# ---------------------------------------------------------------------------
# retune() no-op semantics
# ---------------------------------------------------------------------------

def test_retune_empty_hist_region_is_noop():
    """A region opened by warmup alone (no waves) must keep its configured
    ladder — not collapse to a degenerate (1,)."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=WM, autotune=True)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    exe.warmup(parent_shapes=(jnp.zeros((8, 4)),))
    region = next(iter(exe.regions.values()))
    before = region.buckets
    assert len(before) > 1
    ladders = exe.retune()
    assert region.buckets == before
    assert list(ladders.values()) == [before]


def test_retune_without_new_waves_is_noop():
    """retune() re-derives only from NEW evidence: with no waves since the
    last retune it must not touch the region (asserted by poisoning the
    histogram — stale retunes would pick the poison up)."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=16,
                            launch_watermark=WM)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(24.0).reshape(12, 2)
    exe.submit_range((parent,), 0, 12)
    exe.flush()
    first = exe.retune()
    region = next(iter(exe.regions.values()))
    assert 12 in region.buckets
    region.stats["queue_hist"][5] = 100          # poison: stale evidence
    assert exe.retune() == first                 # no-op: poison ignored
    assert 5 not in region.buckets
    exe.submit_range((parent,), 0, 5)            # a REAL new wave
    exe.flush()
    exe.retune()
    assert 5 in region.buckets                   # new evidence picked up


def test_no_retune_churn_when_tuned_ladder_splits_the_wave():
    """A measured tuner may pick a ladder whose max bucket is BELOW the
    steady wave (splitting predicted faster).  Same-size waves must then
    not re-arm the tuner — re-arming keys on new evidence (a peak beyond
    the tuned histogram), never on the ladder shape, or every wave would
    pay a full retune (chunk re-sweep, measurement, AOT) mid-flight."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=16,
                            launch_watermark=WM, autotune=True,
                            autotune_warmup=1)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(16.0).reshape(8, 2)
    exe.submit_range((parent,), 0, 8)
    exe.flush()                                   # retune on hist {8: 1}
    region = next(iter(exe.regions.values()))
    assert region.tuned
    # simulate the measured verdict: splitting the 8-wave beats bucket 8
    region.buckets = (1, 2)
    region.stats["ladder"] = [1, 2]
    retuned_at = region._retuned_waves
    fut = exe.submit_range((parent,), 0, 8)       # same-size wave
    exe.flush()
    assert region.tuned                           # NOT re-armed
    assert region._retuned_waves == retuned_at    # no retune ran
    assert exe.stats["aggregated_hist"].get(2, 0) >= 4   # drained split
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(2.0 * parent + 1.0))
    big = jnp.arange(32.0).reshape(16, 2)         # genuinely new evidence
    exe.submit_range((big,), 0, 16)
    exe.flush()
    assert 16 in region.buckets                   # re-armed and retuned


# ---------------------------------------------------------------------------
# flush policies
# ---------------------------------------------------------------------------

def test_unknown_flush_policy_fails_fast():
    with pytest.raises(ValueError) as ei:
        AggregationExecutor(jax.vmap(_affine),
                            AggregationConfig(flush_policy="bogus"))
    assert "eager, watermark, cost" in str(ei.value)


def test_watermark_policy_waits_for_learned_peak():
    """After one bulk wave teaches the peak, per-task submissions under
    the watermark policy stop leaking partial buckets into idle
    executors: the whole second wave drains as ONE bucket at flush."""
    parent = jnp.arange(16.0).reshape(8, 2)
    cfg = AggregationConfig(strategy="s3", max_aggregated=32,
                            launch_watermark=1, flush_policy="watermark")
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    exe.submit_range((parent,), 0, 8)
    exe.flush()                                  # peak 8 learned
    before = exe.stats["launches"]
    futs = [exe.submit_indexed((parent,), i) for i in range(8)]
    exe.flush()
    assert exe.stats["launches"] == before + 1   # one bucket-8 launch
    np.testing.assert_array_equal(np.asarray(gather_futures(futs)),
                                  np.asarray(2.0 * parent + 1.0))


def test_cost_policy_drain_decision_follows_model():
    """The "cost" policy drains a partial queue early exactly when the
    measured model says the split beats the one-shot wave."""
    parent = jnp.arange(16.0).reshape(8, 2)
    cfg = AggregationConfig(strategy="s3", max_aggregated=32,
                            launch_watermark=1, flush_policy="cost")
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    exe.submit_range((parent,), 0, 8)
    exe.flush()                                  # peak 8 learned
    region = next(iter(exe.regions.values()))
    assert exe._idle_drain_pays(region, 4)       # no model yet: eager
    for b in (1, 2, 4, 8):                       # overhead-dominated:
        region.cost.record(b, 1.0 + 0.01 * b)    # splitting costs a launch
    assert not exe._idle_drain_pays(region, 4)
    assert exe._idle_drain_pays(region, 8)       # a full wave always goes
    region.cost.clear()
    region.cost.record(1, 1.0)
    region.cost.record(4, 4.0)
    region.cost.record(8, 100.0)                 # superlinear mega bucket:
    assert exe._idle_drain_pays(region, 4)       # splitting is free
    futs = [exe.submit_indexed((parent,), i) for i in range(8)]
    exe.flush()                                  # correctness regardless
    np.testing.assert_array_equal(np.asarray(gather_futures(futs)),
                                  np.asarray(2.0 * parent + 1.0))


@given(n_a=st.integers(1, 20), n_b=st.integers(0, 20),
       max_agg=st.integers(2, 12), seed=st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_flush_policies_bit_identical_on_two_family_interleavings(
        n_a, n_b, max_agg, seed):
    """Property: flush policies affect WHEN launches fire, never what they
    compute — random two-family interleavings of ranges and per-task
    submissions gather bit-identically under eager/watermark/cost, and
    match the direct computation in order."""
    pa = jnp.arange(float(n_a * 2)).reshape(n_a, 2)
    pb = jnp.arange(float(n_b * 3)).reshape(n_b, 3) if n_b else None

    def plan(rng, n):
        out, i = [], 0
        while i < n:
            span = rng.randint(1, n - i)
            if span > 1 and rng.random() < 0.6:
                out.append((i, span))
            else:
                out.append((i, 1))
                span = 1
            i += span
        return out

    outs = {}
    for policy in ("eager", "watermark", "cost"):
        rng = random.Random(seed)                # SAME submissions per run
        cfg = AggregationConfig(strategy="s3", max_aggregated=max_agg,
                                launch_watermark=1, flush_policy=policy)
        exe = AggregationExecutor(jax.vmap(_affine), cfg)
        exe.register("b", jax.vmap(_affine_b))
        futs_a, futs_b = [], []
        for wave in range(2):                    # wave 1 teaches the peaks
            lanes = [iter(plan(rng, n_a)), iter(plan(rng, n_b))]
            if wave == 1 and policy == "cost":   # arm the model mid-run
                for region in exe.regions.values():
                    for b in range(1, max_agg + 1):
                        region.cost.record(b, 1.0 + 0.01 * b)
            live = True
            while live:
                live = False
                for lane, (fam, par, sink) in zip(lanes, [
                        ("region", pa, futs_a), ("b", pb, futs_b)]):
                    nxt = next(lane, None)
                    if nxt is None:
                        continue
                    live = True
                    start, span = nxt
                    if span > 1:
                        sink.append(exe.submit_range((par,), start, span,
                                                     kernel=fam))
                    else:
                        sink.append(exe.submit(
                            *(par[start],), kernel=fam))
            exe.flush()
        got_a = np.asarray(gather_futures(futs_a))
        got_b = np.asarray(gather_futures(futs_b)) if futs_b else None
        outs[policy] = (got_a, got_b)
    direct_a = np.tile(np.asarray(2.0 * pa + 1.0), (2, 1))
    for policy, (got_a, got_b) in outs.items():
        np.testing.assert_array_equal(got_a, direct_a, err_msg=policy)
        if got_b is not None:
            np.testing.assert_array_equal(
                got_b, np.tile(np.asarray(3.0 * pb - 2.0), (2, 1)),
                err_msg=policy)
    np.testing.assert_array_equal(outs["watermark"][0], outs["eager"][0])
    np.testing.assert_array_equal(outs["cost"][0], outs["eager"][0])
