"""Multi-region aggregation: TaskSignature routing, per-family bucketing,
gather-mode AOT warmup, coalesced ring writes, and stats consistency.

The PR's invariants (DESIGN.md §7):
* submissions route to their signature's region — families with different
  kernels or shapes keep separate rings/queues/compiled buckets and NEVER
  flush each other;
* interleaved submissions of two families launch with each family's exact
  greedy bucket decomposition;
* one registered body is shape-polymorphic (new shapes open new regions);
* ``warmup(parent_shapes=...)`` AOT-compiles the indexed-gather and
  contiguous-prefix programs (closing the DESIGN.md §6 gap);
* SlotRing coalesces k pending slot writes into one donated scatter;
* every StrategyRunner strategy reports per-call stat deltas.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import greedy_launches

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core import (
    AggregationExecutor, SlotRing, StrategyRunner, TaskSignature,
    UniformSedovScenario, gather_futures,
)
from repro.hydro.state import sedov_init
from repro.hydro.stepper import courant_dt

CFG = HydroConfig(subgrid=8, ghost=3, levels=1)


def _affine(x):
    return 2.0 * x + 1.0


def _square(x):
    return x * x + 3.0


# ---------------------------------------------------------------------------
# TaskSignature
# ---------------------------------------------------------------------------

def test_task_signature_keys_kernel_and_shapes():
    a = TaskSignature.from_args("k", (jnp.zeros((2, 3)), 1.0))
    b = TaskSignature.from_args("k", (jnp.zeros((2, 3)), 2.0))
    c = TaskSignature.from_args("k", (jnp.zeros((3, 2)), 1.0))
    d = TaskSignature.from_args("other", (jnp.zeros((2, 3)), 1.0))
    assert a == b                  # values don't matter, shapes/dtypes do
    assert a != c and a != d
    assert "k[2x3,scalar]" == a.describe()


def test_same_shape_different_dtype_regions_keep_separate_stats():
    """Same shape, different dtype -> distinct regions AND distinct
    stats["regions"] keys (the describe() key renders non-f32 dtypes)."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=4,
                            launch_watermark=10**9)
    exe = AggregationExecutor(jax.vmap(_affine), cfg, name="a")
    exe.submit(jnp.zeros((2,), jnp.float32))
    exe.submit(jnp.zeros((2,), jnp.int32))
    exe.flush()
    assert len(exe.regions) == 2
    assert len(exe.stats["regions"]) == 2
    assert sum(v["submitted"] for v in exe.stats["regions"].values()) == 2


def test_task_signature_slotview_uses_per_slot_shape():
    from repro.core import SlotView
    parent = jnp.zeros((10, 4, 4))
    sig = TaskSignature.from_args("k", (SlotView(parent, 3),))
    assert sig.arg_specs[0][0] == (4, 4)


# ---------------------------------------------------------------------------
# mixed-signature bucketing
# ---------------------------------------------------------------------------

def test_interleaved_families_launch_counts_pinned():
    """Two kernels with different shapes interleave submissions; each family
    drains with ITS OWN greedy bucket decomposition — no cross-family
    flushing, no shared buckets."""
    cfg = AggregationConfig(strategy="s3", n_executors=1, max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(jax.vmap(_affine), cfg, name="affine")
    exe.register("square", jax.vmap(_square))
    futs_a, futs_b = [], []
    for i in range(7):
        futs_a.append(exe.submit(jnp.full((2,), float(i))))
        if i < 5:
            futs_b.append(exe.submit(jnp.full((3, 4), float(i)),
                                     kernel="square"))
    exe.flush()
    buckets = cfg.bucket_sizes()
    want_a = greedy_launches(7, buckets)           # 4+2+1 -> 3
    want_b = greedy_launches(5, buckets)           # 4+1   -> 2
    assert exe.stats["launches"] == want_a + want_b
    regions = exe.stats["regions"]
    assert set(regions) == {"affine[2]", "square[3x4]"}
    assert regions["affine[2]"]["launches"] == want_a
    assert regions["square[3x4]"]["launches"] == want_b
    assert sum(k * v for k, v in
               regions["affine[2]"]["aggregated_hist"].items()) == 7
    assert sum(k * v for k, v in
               regions["square[3x4]"]["aggregated_hist"].items()) == 5
    assert exe.pool.launches_by_family == {"affine": want_a,
                                           "square": want_b}
    for i, f in enumerate(futs_a):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.full(2, 2.0 * i + 1.0))
    for i, f in enumerate(futs_b):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.full((3, 4), i * i + 3.0))


def test_one_body_is_shape_polymorphic():
    """A single registered body serves several task shapes — each opens its
    own region (ring + buckets) lazily."""
    cfg = AggregationConfig(strategy="s3", max_aggregated=4,
                            launch_watermark=10**9)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    f2 = [exe.submit(jnp.full((2,), float(i))) for i in range(3)]
    f5 = [exe.submit(jnp.full((5,), float(i))) for i in range(4)]
    exe.flush()
    assert len(exe.regions) == 2
    for i, f in enumerate(f2):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.full(2, 2.0 * i + 1.0))
    for i, f in enumerate(f5):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.full(5, 2.0 * i + 1.0))


def test_register_conflicting_body_raises():
    exe = AggregationExecutor(jax.vmap(_affine), AggregationConfig(),
                              name="a")
    with pytest.raises(ValueError):
        exe.register("a", jax.vmap(_square))


def test_unknown_kernel_raises():
    exe = AggregationExecutor(jax.vmap(_affine), AggregationConfig())
    with pytest.raises(KeyError):
        exe.submit(jnp.zeros((2,)), kernel="nope")


def test_gather_futures_mixed_output_shapes_raises():
    cfg = AggregationConfig(strategy="s3", max_aggregated=4,
                            launch_watermark=10**9)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    fa = exe.submit(jnp.zeros((2,)))
    fb = exe.submit(jnp.zeros((5,)))
    exe.flush()
    with pytest.raises(ValueError):
        gather_futures([fa, fb])


# ---------------------------------------------------------------------------
# gather-mode AOT warmup (DESIGN.md §6 -> §7)
# ---------------------------------------------------------------------------

def test_warmup_parent_shapes_precompiles_gather_and_prefix():
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    parent = jnp.arange(24.0).reshape(8, 3)
    exe.warmup(parent_shapes=(parent,))
    pk = ((8, 3),)
    for b in cfg.bucket_sizes():
        assert isinstance(exe._compiled[("gather", b, pk)],
                          jax.stages.Compiled)
        assert isinstance(exe._compiled[("prefix_aot", b, pk)],
                          jax.stages.Compiled)
    # contiguous run -> prefix_aot; shuffled run -> gather: both must hit
    # the AOT programs and produce exact results
    futs = [exe.submit_indexed((parent,), i) for i in range(8)]
    exe.flush()
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.asarray(2.0 * parent[i] + 1.0))
    order = [3, 0, 6, 1]
    futs = [exe.submit_indexed((parent,), i) for i in order]
    exe.flush()
    for i, f in zip(order, futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.asarray(2.0 * parent[i] + 1.0))


def test_warmup_requires_some_shape_source():
    exe = AggregationExecutor(jax.vmap(_affine), AggregationConfig())
    with pytest.raises(ValueError):
        exe.warmup()


# ---------------------------------------------------------------------------
# coalesced slot-ring writes
# ---------------------------------------------------------------------------

def test_slot_ring_coalesces_pending_writes():
    ring = SlotRing(8, (jnp.zeros((3,)),))
    for i in range(5):
        assert ring.write((jnp.full((3,), float(i)),)) == i
    assert ring.writes == 5 and ring.commits == 0     # nothing dispatched yet
    buf = ring.buffers()[0]                           # ONE donated scatter
    assert ring.commits == 1
    np.testing.assert_array_equal(
        np.asarray(buf[:5]),
        np.stack([np.full(3, float(i)) for i in range(5)]))
    ring.write((jnp.full((3,), 9.0),))
    np.testing.assert_array_equal(np.asarray(ring.buffers()[0][5]),
                                  np.full(3, 9.0))
    assert ring.commits == 2


def test_executor_ring_writes_one_scatter_per_launch():
    cfg = AggregationConfig(strategy="s3", max_aggregated=8,
                            launch_watermark=10**9)
    exe = AggregationExecutor(jax.vmap(_affine), cfg)
    futs = [exe.submit(jnp.full((3,), float(i))) for i in range(6)]
    ring = exe.ring
    assert ring.writes == 6 and ring.commits == 0
    exe.flush()
    # 6 tasks drain as buckets 4+2 -> 2 launches but the FIRST commit
    # materialized all 6 pending slots in one scatter
    assert ring.commits == 1
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(f.result()),
                                      np.full(3, 2.0 * i + 1.0))


# ---------------------------------------------------------------------------
# per-call stats deltas (all strategies consistent)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sedov():
    st = sedov_init(CFG)
    dt = courant_dt(st.u, CFG)
    return st, dt


@pytest.mark.parametrize("strategy,n_exec,max_agg,per_call", [
    ("fused", 1, 1, 1),
    ("s2", 2, 1, CFG.n_subgrids),
    ("s3", 1, CFG.n_subgrids, 1),
    ("s2+s3", 2, CFG.n_subgrids, 1),
])
def test_stats_deltas_accumulate_per_call(sedov, strategy, n_exec, max_agg,
                                          per_call):
    """Every strategy reports kernel_launches as accumulated per-call deltas
    (s3 used to OVERWRITE with the executor's cumulative counter)."""
    st, dt = sedov
    r = StrategyRunner(UniformSedovScenario(CFG), AggregationConfig(
        strategy=strategy, n_executors=n_exec, max_aggregated=max_agg,
        launch_watermark=10**9))
    r.rhs(st.u)
    assert r.stats["kernel_launches"] == per_call
    r.rhs(st.u)
    assert r.stats["kernel_launches"] == 2 * per_call
    assert r.stats["iterations"] == 2
