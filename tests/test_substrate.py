"""Optimizer, data pipeline, checkpoint/restart, fault tolerance, sharding API."""
import os
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import (
    latest_step, restore_checkpoint, save_checkpoint,
)
from repro.data.pipeline import DataConfig, SyntheticLMStream, length_bucket
from repro.distributed.api import logical_rules, spec_for
from repro.distributed.fault_tolerance import (
    SimulatedFailure, resilient_loop,
)
from repro.optim.adamw import (
    OptConfig, clip_by_global_norm, cosine_lr, global_norm, opt_init,
    opt_update,
)
from repro.optim.compression import int8_compress, int8_decompress


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt_init(params)
    cfg = OptConfig(lr=0.2, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_cosine_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, 0)) == pytest.approx(0.1)
    assert float(cosine_lr(cfg, 9)) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, 55)) == pytest.approx(0.5, abs=0.05)
    assert float(cosine_lr(cfg, 99)) < 0.01


def test_grad_clip():
    tree = {"a": jnp.array([3.0, 4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0)
    assert float(norm) == pytest.approx(5.0)


def test_adamw_bf16_params_fp32_state():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt_init(params)
    assert state["m"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4,), 0.1, jnp.float32)}
    new_p, new_s, _ = opt_update(grads, state, params, OptConfig())
    assert new_p["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, scale = int8_compress(g)
    back = int8_decompress(q, scale)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-6


def test_compressed_allreduce_error_feedback():
    """Across steps, error feedback keeps the accumulated bias near zero."""
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("data",))
    from repro.optim.compression import compressed_allreduce

    def step(g, res):
        return shard_map(
            lambda g, r: compressed_allreduce(g, "data", r),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False)(g, res)

    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    res = jnp.zeros_like(g)
    total_true, total_sent = jnp.zeros_like(g), jnp.zeros_like(g)
    for i in range(20):
        mean, res = step(g, res)
        total_true += g
        total_sent += mean
    # error feedback: cumulative quantization error stays O(one step's scale)
    assert float(jnp.max(jnp.abs(total_sent - total_true))) < \
        float(jnp.max(jnp.abs(g))) * 0.02


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_by_step():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=64, seed=7)
    a = SyntheticLMStream(cfg).batch(13)
    b = SyntheticLMStream(cfg).batch(13)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = SyntheticLMStream(cfg).batch(14)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=32)
    b = SyntheticLMStream(cfg).batch(0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))


def test_length_bucket():
    assert length_bucket(1, (1, 2, 4, 8)) == 1
    assert length_bucket(3, (1, 2, 4, 8)) == 4
    assert length_bucket(9, (1, 2, 4, 8)) == 8   # clamps at max


# ---------------------------------------------------------------------------
# checkpoint / restart
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    params = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                        "b": jnp.ones((3,), jnp.bfloat16)}}
    opt = opt_init(params)
    save_checkpoint(str(tmp_path), 42, params, opt, meta={"arch": "x"})
    assert latest_step(str(tmp_path)) == 42
    p2, o2, meta = restore_checkpoint(str(tmp_path), 42, params, opt)
    np.testing.assert_array_equal(np.asarray(p2["layer"]["w"]),
                                  np.asarray(params["layer"]["w"]))
    assert p2["layer"]["b"].dtype == jnp.bfloat16
    assert int(o2["step"]) == 0 and meta["arch"] == "x"


def test_checkpoint_latest_of_many(tmp_path):
    params = {"w": jnp.zeros((2,))}
    opt = opt_init(params)
    for s in (10, 20, 30):
        save_checkpoint(str(tmp_path), s, params, opt)
    assert latest_step(str(tmp_path)) == 30


def test_resilient_loop_replays_from_checkpoint(tmp_path):
    """Training survives injected node failures; trajectory is exact."""
    saves = {}

    def step_fn(state, step):
        return state + 1

    def save_fn(state, step):
        saves[step] = state

    def restore_fn(step):
        return saves[step]

    fail_at = {7, 13}

    def failure_hook(step):
        if step in fail_at:
            fail_at.remove(step)
            raise SimulatedFailure(f"node lost at step {step}")

    state, stats = resilient_loop(
        step_fn, 0, 20, save_every=5, save_fn=save_fn,
        restore_fn=restore_fn, failure_hook=failure_hook)
    assert state == 20                  # exact trajectory despite 2 failures
    assert stats["failures"] == 2
    assert stats["restores"] == 2


def test_resilient_loop_gives_up_after_retries():
    def failure_hook(step):
        raise SimulatedFailure("dead node")
    with pytest.raises(RuntimeError, match="unrecoverable"):
        resilient_loop(lambda s, i: s, 0, 5, save_every=1,
                       failure_hook=failure_hook, max_retries=2)


# ---------------------------------------------------------------------------
# sharding rules (no devices needed: fake mesh with .shape dict)
# ---------------------------------------------------------------------------

def _fake_mesh(**axes):
    return SimpleNamespace(shape=dict(axes))


def test_spec_divisibility_fallback():
    with logical_rules(_fake_mesh(pod=2, data=16, model=16)):
        # batch 256 shards over pod+data
        assert spec_for((256, 128), ["batch", None]) == P(("pod", "data"), None)
        # batch 1 (long-context decode) cannot shard -> replicated
        assert spec_for((1, 128), ["batch", None]) == P(None, None)
        # 8 kv heads on 16-way model axis -> replicated
        assert spec_for((4096, 8), [None, "kv_heads"]) == P(None, None)
        # 32 heads shard fine
        assert spec_for((4096, 32), [None, "heads"]) == P(None, "model")


def test_spec_used_axes_fall_through():
    with logical_rules(_fake_mesh(pod=2, data=16, model=16),
                       {"kv_seq": ("pod", "data", "model")}):
        # batch takes pod+data; kv_seq falls through to model
        s = spec_for((128, 32768, 8, 128),
                     ["batch", "kv_seq", "kv_heads", None])
        assert s == P(("pod", "data"), "model", None, None)
        # batch-1: kv_seq absorbs everything
        s = spec_for((1, 524288, 8, 128),
                     ["batch", "kv_seq", "kv_heads", None])
        assert s == P(None, ("pod", "data", "model"), None, None)


def test_constrain_noop_without_context():
    from repro.distributed.api import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "embed") is x
