"""Blast-radius containment (DESIGN.md §11): fault injection, the
``guard="finite"`` post-drain audit, ladder bisection, quarantine, and the
degraded execution modes — plus the executor/engine robustness satellites.

The load-bearing property throughout: because the greedy batch
decomposition is EXACT (bucket 1 pads nothing), every surviving task's
re-executed result is bit-identical to its fault-free aggregated result,
so containment never trades correctness for availability.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import AggregationConfig
from repro.core import (
    AggregationExecutor, DeviceExecutor, ExecutorPool, FaultInjector,
    FaultSpec, QuarantineList, TaskFailedError, all_finite, gather_futures,
)
from repro.core.faults import BucketCompileError, LaunchFaultError


def _body(x):
    return x * 2.0 + 1.0


def _make(n, *, guard="finite", specs=(), seed=0, **cfg_kw):
    cfg = AggregationConfig(max_aggregated=n, guard=guard, **cfg_kw)
    inj = FaultInjector(list(specs), seed=seed) if specs else None
    exe = AggregationExecutor(None, cfg, fault_injector=inj)
    exe.register("k", _body)
    return exe


def _wave(exe, n):
    parents = (jnp.arange(n, dtype=jnp.float32).reshape(n, 1) * 0.5,)
    fut = exe.submit_range(parents, 0, n, kernel="k")
    exe.flush()
    return parents, fut


# ---------------------------------------------------------------------------
# FaultSpec / FaultInjector
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="meteor")
    with pytest.raises(ValueError):
        FaultSpec(site="payload")                    # needs task or rate
    with pytest.raises(ValueError):
        FaultSpec(site="launch", mode="explode")
    with pytest.raises(ValueError):
        FaultSpec(site="payload", task=0, rate=1.5)
    with pytest.raises(ValueError):
        FaultSpec(site="payload", task=0, times=0)
    FaultSpec(site="payload", task=3, mode="inf")    # valid


def test_injector_deterministic_replay():
    """Same specs + seed -> the same exact fault schedule, replayable from
    the log; a different seed reshuffles rate-based draws."""
    specs = [FaultSpec(site="payload", rate=0.5, mode="nan")]

    def schedule(seed):
        inj = FaultInjector(specs, seed=seed)
        for wave in range(4):
            inj.poison_positions("k", wave, list(range(8)))
        return list(inj.log)

    a, b = schedule(7), schedule(7)
    assert a == b and a                 # deterministic, and rate=0.5 fired
    assert schedule(8) != a             # seed changes the coin flips


def test_injector_times_cap():
    inj = FaultInjector([FaultSpec(site="payload", task=2, mode="nan",
                                   times=1)])
    assert inj.poison_positions("k", 0, [0, 1, 2, 3]) == {2: "nan"}
    assert inj.poison_positions("k", 1, [0, 1, 2, 3]) == {}   # spent


# ---------------------------------------------------------------------------
# the acceptance scenario: one NaN task in a 64-wide wave
# ---------------------------------------------------------------------------

def test_single_nan_isolated_in_64_wave():
    exe = _make(64, specs=[FaultSpec(site="payload", kernel="k", task=17,
                                     mode="nan", times=1)])
    parents, fut = _wave(exe, 64)
    ref = _body(parents[0])
    assert fut.failed() and fut.failed_indices() == [17]
    for i in range(64):
        if i == 17:
            with pytest.raises(TaskFailedError) as exc:
                fut.task_result(i)
            assert exc.value.task_ids == (17,)
        else:
            np.testing.assert_array_equal(np.asarray(fut.task_result(i)),
                                          np.asarray(ref[i]))
    faults = exe.stats["regions"]["k[1]"]["faults"]
    assert faults["trips"] == 1
    assert faults["failed_tasks"] == 1
    # O(log bucket): the tripped root is split without re-running (its
    # output already tripped), each level re-executes both halves
    assert faults["bisection_launches"] == 2 * 6
    # bisection re-executions never pollute the aggregation histogram
    assert exe.stats["launches"] == 1
    assert exe.stats["aggregated_hist"] == {64: 1}


def test_range_result_raises_with_culprit_ids():
    exe = _make(16, specs=[FaultSpec(site="payload", kernel="k", task=5,
                                     mode="nan", times=1)])
    _, fut = _wave(exe, 16)
    with pytest.raises(TaskFailedError) as exc:
        fut.result()
    assert 5 in exc.value.task_ids
    with pytest.raises(TaskFailedError):
        gather_futures([fut])


def test_two_culprits_both_isolated():
    exe = _make(32, specs=[
        FaultSpec(site="payload", kernel="k", task=3, mode="nan", times=1),
        FaultSpec(site="payload", kernel="k", task=28, mode="inf", times=1),
    ])
    parents, fut = _wave(exe, 32)
    ref = _body(parents[0])
    assert sorted(fut.failed_indices()) == [3, 28]
    for i in range(32):
        if i in (3, 28):
            continue
        np.testing.assert_array_equal(np.asarray(fut.task_result(i)),
                                      np.asarray(ref[i]))
    assert exe.stats["regions"]["k[1]"]["faults"]["failed_tasks"] == 2


def test_per_task_ring_corruption_contained():
    """Ring-slot corruption (staged input, not output) still resolves to
    the owning task; survivors submitted per-task stay bit-identical."""
    exe = _make(8, specs=[FaultSpec(site="ring", kernel="k", task=3,
                                    mode="nan")], launch_watermark=8)
    futs = [exe.submit(jnp.full((4,), float(i), jnp.float32), kernel="k")
            for i in range(8)]
    exe.flush()
    for i, f in enumerate(futs):
        if i == 3:
            assert f.failed()
            with pytest.raises(TaskFailedError):
                f.result()
        else:
            np.testing.assert_array_equal(
                np.asarray(f.result()),
                np.asarray(_body(jnp.full((4,), float(i)))))
    assert exe.stats["regions"]["k[4]"]["faults"]["trips"] == 1


def test_guard_untripped_is_bit_identical():
    """guard="finite" with no faults: same results, zero containment
    activity — the audit is observation-only until it trips."""
    outs = {}
    for guard in ("off", "finite"):
        exe = _make(32, guard=guard)
        parents, fut = _wave(exe, 32)
        outs[guard] = np.asarray(fut.result())
    np.testing.assert_array_equal(outs["off"], outs["finite"])


def test_invalid_guard_rejected():
    with pytest.raises(ValueError):
        _make(8, guard="paranoid")


# ---------------------------------------------------------------------------
# degraded modes: compile / launch faults
# ---------------------------------------------------------------------------

def test_compile_fault_degrades_to_smaller_buckets():
    exe = _make(16, guard="off",
                specs=[FaultSpec(site="compile", kernel="k", bucket=16)])
    parents, fut = _wave(exe, 16)
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(_body(parents[0])))
    faults = exe.stats["regions"]["k[1]"]["faults"]
    assert faults["compile_failures"] == 1
    assert faults["degraded_launches"] >= 2       # e.g. 8 + 8
    # the rung stays banned: the next wave never re-attempts bucket 16
    parents2, fut2 = _wave(exe, 16)
    np.testing.assert_array_equal(np.asarray(fut2.result()),
                                  np.asarray(_body(parents2[0])))
    assert faults["compile_failures"] == 1


def test_transient_launch_fault_retried():
    exe = _make(8, guard="off",
                specs=[FaultSpec(site="launch", kernel="k", bucket=8,
                                 mode="fail", times=1)])
    parents, fut = _wave(exe, 8)
    np.testing.assert_array_equal(np.asarray(fut.result()),
                                  np.asarray(_body(parents[0])))
    faults = exe.stats["regions"]["k[1]"]["faults"]
    assert faults["retries"] == 1 and faults["launch_failures"] == 1
    assert faults["degraded_launches"] == 0       # retry succeeded in place


def test_persistent_launch_fault_fails_futures():
    """Every rung (bucket 1 included) failing leaves nowhere to degrade:
    the futures fail with the dispatch error attached, instead of hanging
    or poisoning the caller with garbage."""
    exe = _make(4, guard="off", max_bucket_retries=1,
                specs=[FaultSpec(site="launch", kernel="k", mode="fail")])
    _, fut = _wave(exe, 4)
    assert fut.failed() and sorted(fut.failed_indices()) == [0, 1, 2, 3]
    with pytest.raises(TaskFailedError):
        fut.result()
    # the per-task error chains back to the injected dispatch fault
    assert isinstance(fut.error(0).__cause__, LaunchFaultError)
    faults = exe.stats["regions"]["k[1]"]["faults"]
    assert faults["failed_tasks"] == 4


def test_quarantine_repeat_offender():
    """The same wave-relative task tripping repeatedly lands on the
    quarantine list; later waves short-circuit it to a singleton probe
    instead of re-bisecting the whole bucket."""
    exe = _make(16, quarantine_threshold=2,
                specs=[FaultSpec(site="payload", kernel="k", task=9,
                                 mode="nan")])
    _wave(exe, 16)
    _wave(exe, 16)
    faults = exe.stats["regions"]["k[1]"]["faults"]
    assert 9 in faults["quarantined"]
    before = faults["bisection_launches"]
    _, fut = _wave(exe, 16)
    assert fut.failed_indices() == [9]
    # quarantined singleton + one clean re-exec of the other 15: far below
    # a fresh 2*log2(16) bisection
    assert faults["bisection_launches"] - before <= 2


# ---------------------------------------------------------------------------
# the recovery property: random schedules, two interleaved families
# ---------------------------------------------------------------------------

def _two_family_executor(specs, seed, n1, n2, cap):
    cfg = AggregationConfig(max_aggregated=cap, guard="finite")
    inj = FaultInjector(list(specs), seed=seed)
    exe = AggregationExecutor(None, cfg, fault_injector=inj)
    exe.register("a", lambda x: x * 3.0 - 2.0)
    exe.register("b", lambda x: jnp.sqrt(jnp.abs(x)) + x)
    pa = (jnp.arange(n1 * 2, dtype=jnp.float32).reshape(n1, 2) * 0.25,)
    pb = (jnp.arange(n2 * 3, dtype=jnp.float32).reshape(n2, 3) * 0.125,)
    fa = exe.submit_range(pa, 0, n1, kernel="a")
    fb = exe.submit_range(pb, 0, n2, kernel="b")
    exe.flush()
    return (pa, fa), (pb, fb)


@given(n1=st.integers(4, 24), n2=st.integers(4, 24),
       c1=st.integers(0, 23), c2=st.integers(0, 23),
       seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_recovery_property(n1, n2, c1, c2, seed):
    """For ANY injected schedule across two interleaved families: exactly
    the injected tasks fail, and every survivor is bit-identical to the
    fault-free fused reference of its family."""
    c1, c2 = c1 % n1, c2 % n2
    specs = [
        FaultSpec(site="payload", kernel="a", task=c1, mode="nan", times=1),
        FaultSpec(site="payload", kernel="b", task=c2, mode="inf", times=1),
    ]
    (pa, fa), (pb, fb) = _two_family_executor(specs, seed, n1, n2, cap=16)
    ref_a = np.asarray(pa[0] * 3.0 - 2.0)
    ref_b = np.asarray(jnp.sqrt(jnp.abs(pb[0])) + pb[0])
    assert fa.failed_indices() == [c1]
    assert fb.failed_indices() == [c2]
    for i in range(n1):
        if i != c1:
            np.testing.assert_array_equal(np.asarray(fa.task_result(i)),
                                          ref_a[i])
    for i in range(n2):
        if i != c2:
            np.testing.assert_array_equal(np.asarray(fb.task_result(i)),
                                          ref_b[i])


# ---------------------------------------------------------------------------
# DeviceExecutor robustness satellites
# ---------------------------------------------------------------------------

def test_launch_raise_keeps_executor_consistent():
    exe = DeviceExecutor(0)

    def boom(x):
        raise RuntimeError("lowering exploded")

    with pytest.raises(RuntimeError):
        exe.launch(boom, jnp.ones(3), family="f")
    # the failed dispatch paid host time but never enqueued anything
    assert exe.dispatch_s > 0.0
    assert exe.launches == 0
    assert exe.launches_by_family == {}
    assert not exe.busy()
    exe.drain()                                     # nothing to wait on
    out = exe.launch(jnp.sin, jnp.ones(3), family="f")
    assert exe.launches == 1 and exe.launches_by_family == {"f": 1}
    jax.block_until_ready(out)


def test_drain_surfaces_first_error_and_clears():
    class _Deferred:
        def __init__(self, msg=None):
            self.msg = msg

        def block_until_ready(self):
            if self.msg:
                raise RuntimeError(self.msg)
            return self

        def __jax_array__(self):            # keep jax.block_until_ready away
            raise TypeError

    exe = DeviceExecutor(0)
    exe._inflight = [_Deferred("first"), _Deferred("second"), _Deferred()]
    with pytest.raises(RuntimeError, match="first"):
        exe.drain()
    assert exe._inflight == []              # tracking cleared despite errors

    pool = ExecutorPool(2)
    pool.executors[0]._inflight = [_Deferred("left")]
    pool.executors[1]._inflight = [_Deferred("right")]
    with pytest.raises(RuntimeError, match="left"):
        pool.drain()
    assert all(e._inflight == [] for e in pool.executors)


# ---------------------------------------------------------------------------
# runner-level guard (executor-less strategies)
# ---------------------------------------------------------------------------

def test_runner_guard_fused_trips_on_nonfinite():
    from repro.configs.base import HydroConfig
    from repro.core import NonFiniteStateError, StrategyRunner, \
        UniformSedovScenario
    from repro.hydro.state import sedov_init

    cfg = HydroConfig(subgrid=8, ghost=3, levels=1)
    u = sedov_init(cfg).u
    runner = StrategyRunner(UniformSedovScenario(cfg), AggregationConfig(
        strategy="fused", guard="finite", max_aggregated=1))
    jax.block_until_ready(runner.rhs(u))            # clean state passes
    bad = u.at[(0,) * u.ndim].set(float("nan"))
    with pytest.raises(NonFiniteStateError):
        runner.rhs(bad)
    # unguarded runner propagates silently (the pre-§11 behaviour)
    off = StrategyRunner(UniformSedovScenario(cfg), AggregationConfig(
        strategy="fused", guard="off", max_aggregated=1))
    jax.block_until_ready(off.rhs(bad))


# ---------------------------------------------------------------------------
# serving engine: submit validation + poisoned-tenant eviction
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def _engine_model():
    from repro.configs import get_config, reduced
    from repro.models import model as model_mod
    cfg = reduced(get_config("granite-8b"))
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_submit_validation(_engine_model):
    from repro.serving import Request, ServingEngine
    cfg, params = _engine_model
    eng = ServingEngine(cfg, params, max_batch=4, max_len=32)
    for bad in [
        Request(0, []),                              # empty prompt
        Request(1, "abc"),                           # not a token list
        Request(2, [1, 2.5]),                        # non-int token
        Request(3, [-1]),                            # negative id
        Request(4, [10 ** 9]),                       # out of vocab
        Request(5, [1], max_new_tokens=0),           # nothing to decode
        Request(6, [1] * 30, max_new_tokens=8),      # exceeds max_len
    ]:
        with pytest.raises(ValueError):
            eng.submit(bad)
    assert eng.pending == []
    eng.submit(Request(7, [3, 5], max_new_tokens=4))
    assert len(eng.pending) == 1


def test_engine_evicts_poisoned_request(_engine_model):
    """A poisoned tenant is evicted and its slot recycled, while the
    co-batched tenant's tokens are IDENTICAL to a fault-free run — the
    blast radius of one bad request is exactly that request."""
    from repro.serving import Request, ServingEngine
    cfg, params = _engine_model

    def run(injector, guard):
        agg = AggregationConfig(max_aggregated=4, guard=guard)
        eng = ServingEngine(cfg, params, max_batch=4, max_len=32, agg=agg,
                            fault_injector=injector)
        reqs = [Request(0, [3, 5, 7], max_new_tokens=4),
                Request(1, [2, 4, 6], max_new_tokens=4)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, reqs

    _, clean = run(None, "off")
    inj = FaultInjector([FaultSpec(site="payload", kernel="decode", task=1,
                                   mode="nan", times=1)], seed=5)
    eng, reqs = run(inj, "finite")
    assert reqs[1].failed and reqs[1].done and "non-finite" in reqs[1].error
    assert not reqs[0].failed
    assert reqs[0].output == clean[0].output        # co-tenant undisturbed
    assert eng.stats["faults"] == {"trips": 1, "evicted": 1}
    assert sorted(eng.slots_free) == list(range(4))  # slot recycled
    # the recycled slot serves a fresh request correctly
    again = Request(2, [3, 5, 7], max_new_tokens=4)
    eng.submit(again)
    eng.run()
    assert again.output == clean[0].output


# ---------------------------------------------------------------------------
# misc API
# ---------------------------------------------------------------------------

def test_all_finite_and_quarantine_list():
    assert all_finite({"a": jnp.ones(3), "i": jnp.arange(3)})
    assert not all_finite(jnp.array([1.0, float("nan")]))
    assert not all_finite((jnp.ones(2), jnp.array([float("inf")])))
    q = QuarantineList(threshold=2)
    assert not q.record_offense(7)          # first strike
    assert q.record_offense(7)              # quarantined now
    assert 7 in q and 8 not in q
    assert q.as_stats() == [7]
