"""BENCH artifact schema sanity check (the CI gate against artifact drift).

Every ``BENCH_*.json`` at the repo root must carry the expected top-level
keys (benchmark id, backend, config, sweep parameters, per-strategy rows)
and every row must carry a config tag plus the launch/timing counters the
analysis notebooks key on.  A benchmark that silently changes its payload
shape fails the build here instead of producing unreadable artifacts.

  PYTHONPATH=src python benchmarks/check_bench_schema.py [paths...]

With no arguments, checks all BENCH_*.json at the repo root (and fails if
there are none).  Exits non-zero listing every violation.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import List

ROOT = os.path.join(os.path.dirname(__file__), os.pardir)

TOP_KEYS = ("benchmark", "backend", "config", "steps", "repeats", "rows")
ROW_KEYS = ("config", "ms_per_step", "launches_per_step")

# optional per-row observability fields (launch_overhead ladder sweep /
# DESIGN.md §10 measured-tuning rows): validated for shape whenever
# present; *_ladder* rows require ladder+hists, *cost* rows additionally
# require the measured cost table and the configured flush policy
OPTIONAL_ROW_KEYS = ("ms_per_step_samples", "ladder", "region_hists",
                     "cost_model", "flush_policy", "guard", "faults",
                     "guard_overhead_pct", "guard_overhead_ratios")

FLUSH_POLICIES = ("eager", "watermark", "cost")
GUARD_POLICIES = ("off", "finite")


def _check_optional_row(path: str, i: int, row: dict) -> List[str]:
    problems = []
    samples = row.get("ms_per_step_samples")
    if samples is not None and not (
            isinstance(samples, list)
            and all(isinstance(s, (int, float)) for s in samples)):
        problems.append(f"{path}: rows[{i}] 'ms_per_step_samples' must be "
                        f"a list of numbers")
    ladder = row.get("ladder")
    if ladder is not None and not (
            isinstance(ladder, dict)
            and all(isinstance(v, list)
                    and all(isinstance(b, int) and b > 0 for b in v)
                    for v in ladder.values())):
        problems.append(f"{path}: rows[{i}] 'ladder' must map family -> "
                        f"list of positive bucket sizes")
    hists = row.get("region_hists")
    if hists is not None and not (
            isinstance(hists, dict)
            and all(isinstance(v, dict) for v in hists.values())):
        problems.append(f"{path}: rows[{i}] 'region_hists' must map "
                        f"family -> bucket histogram")
    cost = row.get("cost_model")
    if cost is not None and not (
            isinstance(cost, dict)
            and all(isinstance(v, dict) and v
                    and all(isinstance(ms, (int, float)) and ms >= 0
                            for ms in v.values())
                    for v in cost.values())):
        problems.append(f"{path}: rows[{i}] 'cost_model' must map family "
                        f"-> non-empty {{bucket: median ms}} table")
    policy = row.get("flush_policy")
    if policy is not None and policy not in FLUSH_POLICIES:
        problems.append(f"{path}: rows[{i}] 'flush_policy' must be one of "
                        f"{FLUSH_POLICIES}, got {policy!r}")
    guard = row.get("guard")
    if guard is not None and guard not in GUARD_POLICIES:
        problems.append(f"{path}: rows[{i}] 'guard' must be one of "
                        f"{GUARD_POLICIES}, got {guard!r}")
    faults = row.get("faults")
    if faults is not None and not (
            isinstance(faults, dict)
            and all(isinstance(v, dict)
                    and all(isinstance(c, (int, list)) for c in v.values())
                    for v in faults.values())):
        problems.append(f"{path}: rows[{i}] 'faults' must map family -> "
                        f"fault-counter dict (DESIGN.md §11 stats schema)")
    pct = row.get("guard_overhead_pct")
    if pct is not None and not isinstance(pct, (int, float)):
        problems.append(f"{path}: rows[{i}] 'guard_overhead_pct' must be "
                        f"a number")
    ratios = row.get("guard_overhead_ratios")
    if ratios is not None and not (
            isinstance(ratios, list) and ratios
            and all(isinstance(x, (int, float)) and x > 0 for x in ratios)):
        problems.append(f"{path}: rows[{i}] 'guard_overhead_ratios' must "
                        f"be a non-empty list of positive ratios")
    tag = str(row.get("config", ""))
    if "guard" in tag and (guard is None or faults is None):
        problems.append(f"{path}: rows[{i}] is a guard row but lacks "
                        f"'guard'/'faults'")
    if "ladder" in tag and (ladder is None or hists is None):
        problems.append(f"{path}: rows[{i}] is a ladder-sweep row but "
                        f"lacks 'ladder'/'region_hists'")
    if "cost" in tag and (ladder is None or hists is None or cost is None
                          or policy is None):
        problems.append(f"{path}: rows[{i}] is a cost-model-tuned row but "
                        f"lacks one of 'ladder'/'region_hists'/"
                        f"'cost_model'/'flush_policy'")
    return problems


def check_file(path: str) -> List[str]:
    problems = []
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(payload, dict):
        return [f"{path}: top level must be an object"]
    for key in TOP_KEYS:
        if key not in payload:
            problems.append(f"{path}: missing top-level key {key!r}")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        problems.append(f"{path}: 'rows' must be a non-empty list")
        return problems
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            problems.append(f"{path}: rows[{i}] must be an object")
            continue
        for key in ROW_KEYS:
            if key not in row:
                problems.append(f"{path}: rows[{i}] missing {key!r}")
        problems.extend(_check_optional_row(path, i, row))
    return problems


def main(argv: List[str]) -> int:
    paths = argv or sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not paths:
        print("check_bench_schema: no BENCH_*.json artifacts found",
              file=sys.stderr)
        return 1
    problems: List[str] = []
    for path in paths:
        problems.extend(check_file(path))
    for p in problems:
        print(f"check_bench_schema: {p}", file=sys.stderr)
    if not problems:
        print(f"check_bench_schema: {len(paths)} artifact(s) OK "
              f"({', '.join(os.path.basename(p) for p in paths)})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
