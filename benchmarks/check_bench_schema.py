"""BENCH artifact schema sanity check (the CI gate against artifact drift).

Every ``BENCH_*.json`` at the repo root must carry the expected top-level
keys (benchmark id, backend, config, sweep parameters, per-strategy rows)
and every row must carry a config tag plus the launch/timing counters the
analysis notebooks key on.  A benchmark that silently changes its payload
shape fails the build here instead of producing unreadable artifacts.

This is also the PERF GATE for the aggregation claim (DESIGN.md §12):
in every artifact, the row with the minimum ``ms_per_step`` must be an
aggregated or mixed strategy (``s3`` / ``s2+s3`` / ``mixed``) — if a
per-task launch strategy (s2) or the fused upper bound ever becomes the
fastest row, the build fails, because then the aggregation runtime is no
longer earning its complexity on that scenario.  ``mixed`` rows must
additionally carry the per-family assignment (``family_strategies``) and
the measured selection that justified it (``selection``).

  PYTHONPATH=src python benchmarks/check_bench_schema.py [paths...]

With no arguments, checks all BENCH_*.json at the repo root (and fails if
there are none).  Exits non-zero listing every violation.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import List

ROOT = os.path.join(os.path.dirname(__file__), os.pardir)

TOP_KEYS = ("benchmark", "backend", "config", "steps", "repeats", "rows")
ROW_KEYS = ("config", "ms_per_step", "launches_per_step")

# optional per-row observability fields (launch_overhead ladder sweep /
# DESIGN.md §10 measured-tuning rows): validated for shape whenever
# present; *_ladder* rows require ladder+hists, *cost* rows additionally
# require the measured cost table and the configured flush policy, *store*
# rows the §13 warm-start observables (warm_start / tuned_by /
# measurement_launches)
OPTIONAL_ROW_KEYS = ("ms_per_step_samples", "ladder", "region_hists",
                     "cost_model", "cost_model_paths", "flush_policy",
                     "guard", "faults", "guard_overhead_pct",
                     "guard_overhead_ratios", "strategy",
                     "family_strategies", "selection", "flush_decisions",
                     "warm_start", "tuned_by", "measurement_launches")

FLUSH_POLICIES = ("eager", "watermark", "cost")
GUARD_POLICIES = ("off", "finite")
STRATEGIES = ("s1", "s2", "s3", "s2+s3", "fused", "mixed")
# the strategies allowed to own the fastest row of an artifact: the
# explicitly aggregated modes and the per-family router (which may route
# SOME families to s2/fused, but only by measured cost)
AGGREGATED_MIN_STRATEGIES = ("s3", "s2+s3", "mixed")
FAMILY_ROUTES = ("s2", "s3", "fused")
COST_PATHS = ("s2", "s3", "fused")
# provenance of a family's tuning (DESIGN.md §13): restored from the
# persistent store, seeded by the analytical roofline prior, measured
# live, or launch-count-retuned without a cost model
TUNED_BY = ("store", "prior", "measured", "launches")


def _check_optional_row(path: str, i: int, row: dict) -> List[str]:
    problems = []
    samples = row.get("ms_per_step_samples")
    if samples is not None and not (
            isinstance(samples, list)
            and all(isinstance(s, (int, float)) for s in samples)):
        problems.append(f"{path}: rows[{i}] 'ms_per_step_samples' must be "
                        f"a list of numbers")
    ladder = row.get("ladder")
    if ladder is not None and not (
            isinstance(ladder, dict)
            and all(isinstance(v, list)
                    and all(isinstance(b, int) and b > 0 for b in v)
                    for v in ladder.values())):
        problems.append(f"{path}: rows[{i}] 'ladder' must map family -> "
                        f"list of positive bucket sizes")
    hists = row.get("region_hists")
    if hists is not None and not (
            isinstance(hists, dict)
            and all(isinstance(v, dict) for v in hists.values())):
        problems.append(f"{path}: rows[{i}] 'region_hists' must map "
                        f"family -> bucket histogram")
    cost = row.get("cost_model")
    if cost is not None and not (
            isinstance(cost, dict)
            and all(isinstance(v, dict) and v
                    and all(isinstance(ms, (int, float)) and ms >= 0
                            for ms in v.values())
                    for v in cost.values())):
        problems.append(f"{path}: rows[{i}] 'cost_model' must map family "
                        f"-> non-empty {{bucket: median ms}} table")
    paths_tbl = row.get("cost_model_paths")
    if paths_tbl is not None and not (
            isinstance(paths_tbl, dict)
            and all(isinstance(per_path, dict) and per_path
                    and all(p in COST_PATHS for p in per_path)
                    and all(isinstance(tbl, dict) and tbl
                            and all(isinstance(ms, (int, float)) and ms >= 0
                                    for ms in tbl.values())
                            for tbl in per_path.values())
                    for per_path in paths_tbl.values())):
        problems.append(f"{path}: rows[{i}] 'cost_model_paths' must map "
                        f"family -> path ({COST_PATHS}) -> non-empty "
                        f"{{batch/width: median ms}} table")
    policy = row.get("flush_policy")
    if policy is not None:
        # per-family flush policies (DESIGN.md §12) are a family->policy
        # mapping; scalar rows keep the plain string
        ok = (policy in FLUSH_POLICIES if isinstance(policy, str)
              else isinstance(policy, dict) and policy
              and all(v in FLUSH_POLICIES for v in policy.values()))
        if not ok:
            problems.append(f"{path}: rows[{i}] 'flush_policy' must be one "
                            f"of {FLUSH_POLICIES} or a family->policy "
                            f"mapping, got {policy!r}")
    decisions = row.get("flush_decisions")
    if decisions is not None and not (
            isinstance(decisions, dict) and decisions
            and all(isinstance(d, dict)
                    and {"policy", "consulted", "full_wave",
                         "drained_early", "held"} <= set(d)
                    for d in decisions.values())):
        problems.append(f"{path}: rows[{i}] 'flush_decisions' must map "
                        f"family -> decision-counter dict (policy/consulted/"
                        f"full_wave/drained_early/held)")
    guard = row.get("guard")
    if guard is not None and guard not in GUARD_POLICIES:
        problems.append(f"{path}: rows[{i}] 'guard' must be one of "
                        f"{GUARD_POLICIES}, got {guard!r}")
    faults = row.get("faults")
    if faults is not None and not (
            isinstance(faults, dict)
            and all(isinstance(v, dict)
                    and all(isinstance(c, (int, list)) for c in v.values())
                    for v in faults.values())):
        problems.append(f"{path}: rows[{i}] 'faults' must map family -> "
                        f"fault-counter dict (DESIGN.md §11 stats schema)")
    pct = row.get("guard_overhead_pct")
    if pct is not None and not isinstance(pct, (int, float)):
        problems.append(f"{path}: rows[{i}] 'guard_overhead_pct' must be "
                        f"a number")
    ratios = row.get("guard_overhead_ratios")
    if ratios is not None and not (
            isinstance(ratios, list) and ratios
            and all(isinstance(x, (int, float)) and x > 0 for x in ratios)):
        problems.append(f"{path}: rows[{i}] 'guard_overhead_ratios' must "
                        f"be a non-empty list of positive ratios")
    strategy = row.get("strategy")
    if strategy is not None and strategy not in STRATEGIES:
        problems.append(f"{path}: rows[{i}] 'strategy' must be one of "
                        f"{STRATEGIES}, got {strategy!r}")
    fam_strats = row.get("family_strategies")
    if fam_strats is not None and not (
            isinstance(fam_strats, dict) and fam_strats
            and all(v in FAMILY_ROUTES + ("auto",)
                    for v in fam_strats.values())):
        problems.append(f"{path}: rows[{i}] 'family_strategies' must map "
                        f"family -> one of {FAMILY_ROUTES + ('auto',)}")
    selection = row.get("selection")
    if selection is not None and not (
            isinstance(selection, dict) and selection
            and all(isinstance(s, dict)
                    and s.get("selected_strategy") in FAMILY_ROUTES
                    for s in selection.values())):
        problems.append(f"{path}: rows[{i}] 'selection' must map family -> "
                        f"{{selected_strategy in {FAMILY_ROUTES}, "
                        f"strategy_costs}}")
    warm = row.get("warm_start")
    if warm is not None and not isinstance(warm, bool):
        problems.append(f"{path}: rows[{i}] 'warm_start' must be a bool, "
                        f"got {warm!r}")
    tuned_by = row.get("tuned_by")
    if tuned_by is not None and not (
            isinstance(tuned_by, dict) and tuned_by
            and all(v in TUNED_BY for v in tuned_by.values())):
        problems.append(f"{path}: rows[{i}] 'tuned_by' must map family -> "
                        f"one of {TUNED_BY}")
    meas = row.get("measurement_launches")
    if meas is not None and not (
            isinstance(meas, dict)
            and all(isinstance(c, int) and c >= 0 for c in meas.values())):
        problems.append(f"{path}: rows[{i}] 'measurement_launches' must "
                        f"map family -> non-negative launch count")
    tag = str(row.get("config", ""))
    hists_any = hists if hists is not None \
        else row.get("bucket_hist_by_family")
    if "guard" in tag and (guard is None or faults is None):
        problems.append(f"{path}: rows[{i}] is a guard row but lacks "
                        f"'guard'/'faults'")
    if "ladder" in tag and (ladder is None or hists is None):
        problems.append(f"{path}: rows[{i}] is a ladder-sweep row but "
                        f"lacks 'ladder'/'region_hists'")
    if "cost" in tag and (ladder is None or hists_any is None
                          or cost is None or policy is None):
        problems.append(f"{path}: rows[{i}] is a cost-model-tuned row but "
                        f"lacks one of 'ladder'/bucket hists/"
                        f"'cost_model'/'flush_policy'")
    if (strategy == "mixed" or "mixed" in tag) and (
            fam_strats is None or selection is None):
        problems.append(f"{path}: rows[{i}] is a mixed row but lacks "
                        f"'family_strategies'/'selection' (the per-family "
                        f"assignment and the measured justification)")
    if "store" in tag and (warm is None or tuned_by is None
                           or meas is None):
        problems.append(f"{path}: rows[{i}] is a warm-start store row but "
                        f"lacks one of 'warm_start'/'tuned_by'/"
                        f"'measurement_launches' (the DESIGN.md §13 "
                        f"cold-vs-warm observables)")
    if "policy" in tag and decisions is None:
        problems.append(f"{path}: rows[{i}] is an adaptive-drain policy "
                        f"row but lacks 'flush_decisions' (the decision "
                        f"trace is the point of the row)")
    return problems


def check_file(path: str) -> List[str]:
    problems = []
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(payload, dict):
        return [f"{path}: top level must be an object"]
    for key in TOP_KEYS:
        if key not in payload:
            problems.append(f"{path}: missing top-level key {key!r}")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        problems.append(f"{path}: 'rows' must be a non-empty list")
        return problems
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            problems.append(f"{path}: rows[{i}] must be an object")
            continue
        for key in ROW_KEYS:
            if key not in row:
                problems.append(f"{path}: rows[{i}] missing {key!r}")
        problems.extend(_check_optional_row(path, i, row))
    problems.extend(_check_aggregated_min(path, rows))
    return problems


def _row_strategy(row: dict) -> str:
    """The row's strategy, falling back to a tag heuristic for artifacts
    produced before rows carried an explicit 'strategy' field."""
    strategy = row.get("strategy")
    if strategy is not None:
        return str(strategy)
    tag = str(row.get("config", ""))
    if tag.startswith("mixed"):
        return "mixed"
    if tag.startswith("s2s3") or tag.startswith("s2+s3"):
        return "s2+s3"
    if tag.startswith("s3"):
        return "s3"
    if tag.startswith("s2"):
        return "s2"
    return "fused" if tag.startswith("fused") else "?"


def _check_aggregated_min(path: str, rows: List[dict]) -> List[str]:
    """The DESIGN.md §12 perf gate: the fastest row of every artifact must
    be an aggregated or mixed strategy.  Diagnostic rows that measure a
    contained failure (fault smoke) rather than a steady-state step are
    excluded — their wall time is one aborted step, not a strategy."""
    timed = [(i, r) for i, r in enumerate(rows)
             if isinstance(r, dict)
             and isinstance(r.get("ms_per_step"), (int, float))
             and "faultsmoke" not in str(r.get("config", ""))]
    if not timed:
        return []
    i, best = min(timed, key=lambda ir: ir[1]["ms_per_step"])
    strategy = _row_strategy(best)
    if strategy in AGGREGATED_MIN_STRATEGIES:
        return []
    ranked = sorted((r["ms_per_step"], str(r.get("config")),
                     _row_strategy(r)) for _, r in timed)
    table = ", ".join(f"{tag}[{s}]={ms}" for ms, tag, s in ranked[:4])
    return [f"{path}: fastest row rows[{i}] "
            f"({best.get('config')!r}, {best['ms_per_step']} ms/step) is "
            f"strategy {strategy!r} — an aggregated or mixed row "
            f"({AGGREGATED_MIN_STRATEGIES}) must be the minimum "
            f"ms_per_step; leaders: {table}"]


def main(argv: List[str]) -> int:
    paths = argv or sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    if not paths:
        print("check_bench_schema: no BENCH_*.json artifacts found",
              file=sys.stderr)
        return 1
    problems: List[str] = []
    for path in paths:
        problems.extend(check_file(path))
    for p in problems:
        print(f"check_bench_schema: {p}", file=sys.stderr)
    if not problems:
        print(f"check_bench_schema: {len(paths)} artifact(s) OK "
              f"({', '.join(os.path.basename(p) for p in paths)})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
