"""Portability axis: Pallas kernels vs pure-XLA lowering (the paper's
Kokkos-vs-native comparison, one abstraction level up).

The paper found Kokkos within ~10% of native CUDA/HIP.  Our analogue: the
same hydro RHS and MoE grouped-GEMM exist as (a) portable XLA (jnp) code and
(b) Pallas kernels with explicit VMEM tiling.  On the CPU container the
Pallas path runs in interpret mode (a correctness harness, not a speed
path), so this benchmark reports CORRECTNESS deltas (must be ~0) and the
structural kernel properties that matter on the TPU target (VMEM working
set, HBM bytes saved by the fused kernel), with interpret-mode wall times
included only for completeness.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.grouped_gemm import grouped_gemm
from repro.kernels.hydro_rhs import hydro_rhs_pallas

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _time(fn, *args, n=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def hydro_row():
    key = jax.random.PRNGKey(0)
    n, s, g = 8, 8, 3
    p = s + 2 * g
    k1, k2, k3 = jax.random.split(key, 3)
    rho = 1.0 + 0.3 * jax.random.uniform(k1, (n, 1, p, p, p))
    v = 0.2 * jax.random.normal(k2, (n, 3, p, p, p))
    pr = 1.0 + 0.5 * jax.random.uniform(k3, (n, 1, p, p, p))
    e = pr / 0.4 + 0.5 * rho * jnp.sum(v * v, axis=1, keepdims=True)
    u = jnp.concatenate([rho, rho * v, e], axis=1)
    kw = dict(h=0.01, gamma=1.4, ghost=g, subgrid=s)

    xla = jax.jit(lambda x: ref.hydro_rhs_ref(x, **kw))
    pallas = jax.jit(lambda x: hydro_rhs_pallas(x, **kw))
    out_x, out_p = xla(u), pallas(u)
    err = float(jnp.max(jnp.abs(out_x - out_p)))
    scale = float(jnp.max(jnp.abs(out_x)))
    # structural numbers for the TPU target
    in_bytes = 5 * p ** 3 * 4
    recon_bytes = 26 * 5 * p ** 3 * 4
    out_bytes = 5 * s ** 3 * 4
    return {
        "kernel": "hydro_rhs",
        "rel_err": err / scale,
        "xla_ms": round(_time(xla, u) * 1e3, 2),
        "pallas_interpret_ms": round(_time(pallas, u) * 1e3, 2),
        "hbm_bytes_unfused_per_task": in_bytes + 2 * recon_bytes + out_bytes,
        "hbm_bytes_fused_per_task": in_bytes + out_bytes,
        "hbm_reduction_x": round((in_bytes + 2 * recon_bytes + out_bytes)
                                 / (in_bytes + out_bytes), 1),
    }


def gemm_row():
    key = jax.random.PRNGKey(1)
    e, c, k, n = 8, 256, 512, 512
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (e, c, k), jnp.float32) * 0.1
    w = jax.random.normal(ks[1], (e, k, n), jnp.float32) * 0.1
    gl = jnp.array([256, 128, 0, 17, 256, 64, 32, 200], jnp.int32)

    xla = jax.jit(lambda *a: ref.grouped_gemm_ref(*a))
    pallas = jax.jit(lambda *a: grouped_gemm(*a, bc=128, bn=128, bk=256))
    out_x, out_p = xla(x, w, gl), pallas(x, w, gl)
    err = float(jnp.max(jnp.abs(out_x - out_p)))
    dead = float(1.0 - jnp.sum(gl) / (e * c))
    return {
        "kernel": "grouped_gemm",
        "rel_err": err / max(float(jnp.max(jnp.abs(out_x))), 1e-9),
        "xla_ms": round(_time(xla, x, w, gl) * 1e3, 2),
        "pallas_interpret_ms": round(_time(pallas, x, w, gl) * 1e3, 2),
        "dead_capacity_fraction": round(dead, 3),
        "mxu_tiles_skipped_fraction": round(dead, 3),
    }


def main() -> None:
    print("portability: Pallas vs XLA (Kokkos-vs-native analogue)")
    rows = [hydro_row(), gemm_row()]
    keys = sorted({k for r in rows for k in r})
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
        assert r["rel_err"] < 1e-4, r
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "portability.json"), "w") as f:
        json.dump(rows, f, indent=2)
    print("OK: Pallas kernels bit-consistent with XLA path (interpret mode)")


if __name__ == "__main__":
    main()
