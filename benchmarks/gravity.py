"""Cross-solver aggregation benchmark: hydro + gravity through one executor.

For each strategy, measures per RK3 time-step on the self-gravitating
Sedov scenario — every iteration submits the hydro Reconstruct+Flux tasks
AND the per-sub-grid gravity solves interleaved into ONE
``AggregationExecutor`` (two concurrent ``TaskSignature`` families):

* wall time per step,
* kernel launches per step (the aggregation win),
* per-family bucket histograms and per-family launch counts (the
  multi-region observability surface).

  PYTHONPATH=src python benchmarks/gravity.py [--smoke] [--steps N]
                                              [--repeats N]

Writes BENCH_gravity.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
from bench_util import WM, hist_deltas, region_cost_models, \
    region_cost_paths, region_hists, region_ladders, region_selection, \
    time_per_step

from repro.configs.base import AggregationConfig
from repro.configs.gravity import CONFIG, CONFIG_SMALL
from repro.core import GravityScenario, StrategyRunner
from repro.hydro.state import sedov_init
from repro.hydro.stepper import courant_dt

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_gravity.json")


def run(cfg, steps: int, repeats: int) -> List[dict]:
    st = sedov_init(cfg.hydro)
    dt = courant_dt(st.u, cfg.hydro)
    scn = GravityScenario(cfg)    # shared: one set of traced family bodies
    rows = []
    # the *_epi rows drive the TWO-FAMILY epilogue-fused stage protocol
    # (DESIGN.md §10): each RK stage submits the hydro axpy-fused twin AND
    # the gravity relaxation interleaved in the same wave, bit-identical
    # to the fused stage reference (pinned in tests/test_gravity.py)
    # s3_cost_auto is the full-kit aggregated row (auto-tuned ladder,
    # chunked epilogue-fused mega-buckets, measured bucket costs);
    # mixed_auto routes hydro and gravity independently to their measured
    # fastest path (DESIGN.md §12) — the two families genuinely differ
    # (the gravity relaxation is much cheaper per task than the hydro
    # Reconstruct+Flux), so per-family routing is where this sweep's win
    # lives.  The resolved assignment and the measured per-path costs
    # ride in the mixed row.
    for tag, strat, n_exec, max_agg, knobs in [
        ("s2", "s2", 4, 1, {}),
        ("s3", "s3", 1, 16, {}),
        ("s2s3", "s2+s3", 4, 16, {}),
        ("s3_epi", "s3", 1, 16, dict(fuse_epilogue=True)),
        ("s3_cost_auto", "s3", 1, 64,
         dict(autotune=True, inner_chunk="auto", cost_model=True)),
        ("mixed_auto", "mixed", 4, 64,
         dict(autotune=True, inner_chunk="auto", cost_model=True)),
        ("fused_per_family", "fused", 1, 1, {}),
    ]:
        agg = AggregationConfig(strategy=strat, n_executors=n_exec,
                                max_aggregated=max_agg, launch_watermark=WM,
                                **knobs)
        r = StrategyRunner(scn, agg)
        r.warmup()                           # AOT gather/prefix buckets
        r.rk3_step(st.u, dt)                 # compile remaining programs
        r.stats["kernel_launches"] = 0
        warm_fams = dict(r.launches_by_family)
        warm_hists = region_hists(r)
        sec, samples = time_per_step(r.rk3_step, st.u, dt, steps, repeats)
        launches = r.stats["kernel_launches"] / (steps * repeats)
        by_family = {k: (v - warm_fams.get(k, 0)) / (steps * repeats)
                     for k, v in r.launches_by_family.items()}
        regions = hist_deltas(region_hists(r), warm_hists)
        rows.append({
            "config": tag,
            "strategy": strat,
            "ms_per_step": round(sec * 1e3, 3),
            "ms_per_step_samples": [round(s * 1e3, 3) for s in samples],
            "launches_per_step": launches,
            "launches_by_family_per_step": by_family or None,
            "fuse_epilogue": bool(knobs.get("fuse_epilogue", False)),
            "flush_policy": agg.flush_policy,
            "n_families": len(regions) or None,
            "bucket_hist_by_family": regions or None,
        })
        if knobs.get("cost_model"):
            rows[-1]["ladder"] = region_ladders(r)
            rows[-1]["cost_model"] = region_cost_models(r) or None
        if strat == "mixed":
            rows[-1]["family_strategies"] = (
                dict(agg.family_strategies) if agg.family_strategies
                else {"*": "auto"})
            rows[-1]["selection"] = region_selection(r) or None
            rows[-1]["cost_model_paths"] = region_cost_paths(r) or None
        print(f"  {tag:18s} {rows[-1]['ms_per_step']:9.2f} ms/step  "
              f"launches/step {launches:.0f}  families {regions or '-'}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier-1 smoke: small grid, 1 step, 1 repeat")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.repeats = 1, 1
    if args.steps < 1 or args.repeats < 1:
        ap.error("--steps and --repeats must be >= 1")
    cfg = CONFIG_SMALL if args.smoke else CONFIG
    hc = cfg.hydro
    print(f"gravity: {cfg.name}, {hc.n_subgrids} sub-grids of "
          f"{hc.subgrid}^3, 2 kernel families/iteration, "
          f"backend={jax.default_backend()}")
    rows = run(cfg, args.steps, args.repeats)
    payload = {
        "benchmark": "gravity",
        "backend": jax.default_backend(),
        "config": cfg.name,
        "steps": args.steps,
        "repeats": args.repeats,
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
