"""Two-level AMR Sedov strategy sweep: the multi-region aggregation runtime
on a genuinely adaptive task population.

For each strategy, measures per RK3 time-step on the two-level refined
Sedov scenario:

* wall time per step,
* kernel launches per step (the aggregation win),
* per-family bucket histograms (``--mixed`` drives TWO TaskSignature
  families — 16^3 coarse + 8^3 fine sub-grids — through one executor).

  PYTHONPATH=src python benchmarks/amr_sedov.py [--mixed] [--smoke]
                                                [--steps N] [--repeats N]

Writes BENCH_amr_sedov.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
from bench_util import WM, hist_deltas, region_cost_models, \
    region_cost_paths, region_hists, region_ladders, region_selection, \
    time_per_step

from repro.configs.amr_sedov import CONFIG, CONFIG_MIXED
from repro.configs.base import AggregationConfig
from repro.core import AMRSedovScenario, StrategyRunner
from repro.hydro.state import amr_sedov_init
from repro.hydro.stepper import amr_courant_dt

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_amr_sedov.json")


def run(cfg, steps: int, repeats: int) -> List[dict]:
    st = amr_sedov_init(cfg)
    dt = amr_courant_dt(st.uc, st.uf, cfg)
    scn = AMRSedovScenario(cfg)   # shared: one set of traced family bodies
    rows = []
    # the *_epi rows drive the per-level epilogue-fused stage twins
    # (DESIGN.md §10): gather -> level body (traced h) -> Shu-Osher axpy
    # as ONE program per bucket, bit-identical to the fused stage
    # reference (pinned in tests/test_amr.py)
    # s3_cost_auto is the full-kit aggregated row (auto-tuned ladder,
    # chunked epilogue-fused mega-buckets, measured bucket costs) — the
    # DESIGN.md §10 configuration the plain s3/s2s3 rows deliberately
    # leave off.  mixed_auto is the DESIGN.md §12 row: the executor
    # measures each family's s2 / s3 / fused wall time during warmup and
    # routes every family to its measured minimum (coarse and fine levels
    # may route differently); the resolved assignment and the measured
    # costs that justified it ride in the row.
    for tag, strat, n_exec, max_agg, knobs in [
        ("s2", "s2", 4, 1, {}),
        ("s3", "s3", 1, 16, {}),
        ("s2s3", "s2+s3", 4, 16, {}),
        ("s3_epi", "s3", 1, 16, dict(fuse_epilogue=True)),
        ("s2s3_epi", "s2+s3", 4, 16, dict(fuse_epilogue=True)),
        ("s3_cost_auto", "s3", 1, 64,
         dict(autotune=True, inner_chunk="auto", cost_model=True)),
        ("mixed_auto", "mixed", 4, 64,
         dict(autotune=True, inner_chunk="auto", cost_model=True)),
        ("fused_per_level", "fused", 1, 1, {}),
    ]:
        agg = AggregationConfig(strategy=strat, n_executors=n_exec,
                                max_aggregated=max_agg, launch_watermark=WM,
                                **knobs)
        r = StrategyRunner(scn, agg)
        r.warmup()                           # AOT gather/prefix buckets
        state = (st.uc, st.uf)
        r.rk3_step(state, dt)                # compile remaining programs
        r.stats["kernel_launches"] = 0
        warm_fams = dict(r.launches_by_family)
        warm_hists = region_hists(r)
        sec, samples = time_per_step(r.rk3_step, state, dt, steps, repeats)
        launches = r.stats["kernel_launches"] / (steps * repeats)
        by_family = {k: (v - warm_fams.get(k, 0)) / (steps * repeats)
                     for k, v in r.launches_by_family.items()}
        regions = hist_deltas(region_hists(r), warm_hists)
        mixed = strat == "mixed"
        rows.append({
            "config": tag,
            "strategy": strat,
            "ms_per_step": round(sec * 1e3, 3),
            "ms_per_step_samples": [round(s * 1e3, 3) for s in samples],
            "launches_per_step": launches,
            "launches_by_family_per_step": by_family or None,
            "fuse_epilogue": bool(knobs.get("fuse_epilogue", False)),
            "flush_policy": agg.flush_policy,
            "n_families": len(regions) or None,
            "bucket_hist_by_family": regions or None,
        })
        if knobs.get("cost_model"):
            rows[-1]["ladder"] = region_ladders(r)
            rows[-1]["cost_model"] = region_cost_models(r) or None
        if mixed:
            rows[-1]["family_strategies"] = (
                dict(agg.family_strategies) if agg.family_strategies
                else {"*": "auto"})
            rows[-1]["selection"] = region_selection(r) or None
            rows[-1]["cost_model_paths"] = region_cost_paths(r) or None
        print(f"  {tag:16s} {rows[-1]['ms_per_step']:9.2f} ms/step  "
              f"launches/step {launches:.0f}  families {regions or '-'}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mixed", action="store_true",
                    help="mixed sub-grid sizes: two TaskSignature families")
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier-1 smoke: 1 step, 1 repeat")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.repeats = 1, 1
    if args.steps < 1 or args.repeats < 1:
        ap.error("--steps and --repeats must be >= 1")
    cfg = CONFIG_MIXED if args.mixed else CONFIG
    print(f"amr_sedov: {cfg.name}, coarse {cfg.n_coarse}^3 "
          f"(+{cfg.n_fine}^3 fine patch), backend={jax.default_backend()}")
    rows = run(cfg, args.steps, args.repeats)
    payload = {
        "benchmark": "amr_sedov",
        "backend": jax.default_backend(),
        "config": cfg.name,
        "steps": args.steps,
        "repeats": args.repeats,
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
