"""Aggregate the dry-run JSONs into the §Roofline table (markdown + CSV)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")

COLS = ("arch", "shape", "mesh", "chips", "dominant", "compute_s",
        "memory_s", "collective_s", "roofline_bound_s", "roofline_fraction",
        "useful_flop_ratio", "temp_gb", "args_gb")


def load_rows(mesh: str = None):
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "dryrun_*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "skipped": r["skipped"]})
            continue
        rl = r["roofline"]
        m = r["memory"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "chips": r["chips"], "dominant": rl["dominant"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "roofline_bound_s": rl["roofline_bound_s"],
            "roofline_fraction": rl["roofline_fraction"],
            "useful_flop_ratio": rl["useful_flop_ratio"],
            "temp_gb": m.get("temp_size_in_bytes", 0) / 1e9,
            "args_gb": m.get("argument_size_in_bytes", 0) / 1e9,
            "collectives": rl.get("collectives", {}),
        })
    return rows


def fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def main() -> None:
    rows = load_rows()
    if not rows:
        print("roofline_report: no dry-run results yet "
              "(run python -m repro.launch.dryrun --all)")
        return
    print(",".join(COLS))
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']},{r['shape']},{r['mesh']},SKIPPED:"
                  f" {r['skipped']}")
        else:
            print(",".join(fmt(r.get(c, "")) for c in COLS))


if __name__ == "__main__":
    main()
