"""Benchmark driver: one section per paper table + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--full]

* table2_setup       — paper Table II (scenario/launch accounting)
* table3_strategies  — paper Table III (S1/S2/S3 strategy sweep, wall time)
* portability        — Kokkos-vs-native analogue (Pallas vs XLA)
* serving_aggregation— request-level strategy-3 (engine throughput sweep)
* roofline_report    — §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import time

import jax


def serving_aggregation(quick: bool = False):
    """Throughput of the serving engine vs aggregation bucket cap."""
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.configs.base import AggregationConfig
    from repro.models import model
    from repro.serving import Request, ServingEngine

    cfg = reduced(get_config("granite-8b"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 8 if quick else 24
    rows = []
    for cap in (1, 4, 8):
        agg = AggregationConfig(max_aggregated=cap,
                                buckets=tuple(b for b in (1, 2, 4, 8)
                                              if b <= cap))
        eng = ServingEngine(cfg, params, max_batch=cap, max_len=64, agg=agg)
        reqs = [Request(i, [i % 7 + 1, 3], max_new_tokens=8)
                for i in range(n_req)]
        for r in reqs:
            eng.submit(r)
        eng.run()          # includes compile; warm pass below
        eng2 = ServingEngine(cfg, params, max_batch=cap, max_len=64, agg=agg)
        eng2._decode = eng._decode          # reuse compiled buckets
        reqs = [Request(i, [i % 7 + 1, 3], max_new_tokens=8)
                for i in range(n_req)]
        for r in reqs:
            eng2.submit(r)
        t0 = time.perf_counter()
        eng2.run()
        dt = time.perf_counter() - t0
        rows.append({"max_batch": cap,
                     "tokens_per_s": round(eng2.stats["tokens"] / dt, 1),
                     "launches": eng2.stats["launches"],
                     "tokens": eng2.stats["tokens"]})
        print(f"  engine cap={cap}: {rows[-1]['tokens_per_s']} tok/s, "
              f"{rows[-1]['launches']} launches")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    print(f"== benchmarks (backend={jax.default_backend()}, "
          f"devices={len(jax.devices())}) ==")

    print("\n-- table2_setup (paper Table II) --")
    from benchmarks import table2_setup
    table2_setup.main()

    print("\n-- table3_strategies (paper Table III) --")
    from benchmarks import table3_strategies
    sys.argv = ["table3"] + (["--quick"] if args.quick else []) \
        + (["--full"] if args.full else [])
    table3_strategies.main()

    print("\n-- portability (Kokkos-vs-native analogue) --")
    from benchmarks import portability
    portability.main()

    print("\n-- serving aggregation (request-level strategy 3) --")
    serving_aggregation(quick=args.quick)

    print("\n-- roofline report (from dry-run artifacts) --")
    from benchmarks import roofline_report
    roofline_report.main()

    print("\nall benchmarks done")


if __name__ == "__main__":
    main()
