"""Paper Table III analogue: runtime/time-step under each aggregation strategy.

Sweeps the same three parameters as the paper — sub-grid size (S1), number
of executors (S2), max aggregated kernels (S3) — over the Sedov blast wave,
measuring wall-clock per time-step on THIS runtime (XLA:CPU here; the same
harness runs unchanged on TPU).  The paper's qualitative finding reproduces
on a third runtime: per-task launches (S2) leave the device starved and
dispatch-bound, explicit aggregation (S3) recovers most of the gap to the
whole-graph bound, and combining strategies is best.

``--full`` runs the paper's exact 512-sub-grid scenario (8^3, 3 levels);
default is the 64-sub-grid version (same physics, CI-sized).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import jax

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core import StrategyRunner, UniformSedovScenario
from repro.hydro.state import sedov_init
from repro.hydro.stepper import courant_dt

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def sweep(levels: int = 2, steps: int = 2, quick: bool = False):
    cfg8 = HydroConfig(subgrid=8, ghost=3, levels=levels)
    cfg16 = HydroConfig(subgrid=16, ghost=3, levels=levels - 1)
    grid: List[tuple] = [
        # (tag, cfg, strategy, n_exec, max_agg)
        ("s1_8_noagg", cfg8, "s2", 1, 1),       # unaggregated baseline
        ("s1_16_noagg", cfg16, "s2", 1, 1),     # strategy 1
        ("s2_exec4", cfg8, "s2", 4, 1),
        ("s2_exec8", cfg8, "s2", 8, 1),
        ("s3_agg4", cfg8, "s3", 1, 4),
        ("s3_agg16", cfg8, "s3", 1, 16),
        ("s3_agg_all", cfg8, "s3", 1, cfg8.n_subgrids),
        ("s2s3_exec4_agg8", cfg8, "s2+s3", 4, 8),
        ("s2s3_exec4_agg16", cfg8, "s2+s3", 4, 16),
        ("fused_bound", cfg8, "fused", 1, 1),   # beyond-paper whole-graph
        ("fused_bound_16", cfg16, "fused", 1, 1),
        # whole multi-step trajectory as ONE lax.scan program (upper bound)
        ("fused_scan_bound", cfg8, "fused", 1, 1),
    ]
    if quick:
        grid = [g for g in grid if g[0] in
                ("s1_8_noagg", "s3_agg16", "s2s3_exec4_agg8", "fused_bound",
                 "fused_scan_bound")]

    rows = []
    for tag, cfg, strat, n_exec, max_agg in grid:
        st = sedov_init(cfg)
        dt = courant_dt(st.u, cfg)
        agg = AggregationConfig(strategy=strat, n_executors=n_exec,
                                max_aggregated=max_agg)
        runner = StrategyRunner(UniformSedovScenario(cfg), agg)
        use_scan = tag == "fused_scan_bound"
        if use_scan:
            runner.rk3_trajectory(st.u, dt, steps)  # warmup/compile
        else:
            runner.rk3_step(st.u, dt)               # warmup/compile
        runner.stats["kernel_launches"] = 0
        sec = runner.time_step(st.u, dt, n_steps=steps, use_scan=use_scan)
        rows.append({
            "config": tag, "strategy": strat, "subgrid": cfg.subgrid,
            "n_subgrids": cfg.n_subgrids, "executors": n_exec,
            "max_aggregated": max_agg,
            "staging": agg.staging,
            "ms_per_step": round(sec * 1e3, 2),
            # fractional for the scan row: ONE dispatch covers all steps.
            # Every strategy (s3 included) now accumulates per-call deltas,
            # so the per-step division is uniform.
            "launches_per_step": round(
                runner.stats["kernel_launches"] / max(steps, 1), 3),
        })
        print(f"  {tag:22s} {rows[-1]['ms_per_step']:9.2f} ms/step")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact 512 sub-grids (slow on CPU)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=2)
    args = ap.parse_args()
    levels = 3 if args.full else 2
    print(f"table3_strategies: Sedov, {8 ** 3 * (2 ** levels) ** 3} cells, "
          f"backend={jax.default_backend()}")
    rows = sweep(levels=levels, steps=args.steps, quick=args.quick)
    hdr = list(rows[0].keys())
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[h]) for h in hdr))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "table3_strategies.json"), "w") as f:
        json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
