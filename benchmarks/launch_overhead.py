"""Launch-overhead benchmark: host staging vs. the device-resident slot ring.

Measures, per RK3 time-step on the Sedov scenario, for every strategy /
staging combination:

* wall time per step (the Table III metric),
* kernel launches per step,
* host *staging* time (slicing, stacking, ring writes — everything spent
  preparing inputs before dispatch),
* host *dispatch* time (enqueueing compiled programs).

The ``*_seed`` rows reproduce the seed implementation exactly — s2 as
``subs[i:i+1]`` slicing + per-iteration ``jnp.concatenate``, s3 as
``staging="host"`` (slice -> host-stack -> launch) — so the perf trajectory
of the slot-ring rework is measurable from this PR onward.  The ``fused_scan``
row is the upper bound: whole RK3 trajectories as ONE ``lax.scan`` program.

The aggregated rows (``s3_slotring`` / ``s2s3_slotring``) run the DESIGN.md
§9 hot path: one bulk ``submit_range`` per wave, auto-tuned bucket ladders,
and epilogue-fused mega-buckets (chunked body evaluation picked by timed
warmup).  The ``s3_ladder{16,32,64,auto}`` sweep varies only the ladder cap,
recording each row's final per-family ladder and timed-window bucket
histograms.  ``s3_cost_auto`` is the DESIGN.md §10 row: the tuner TIMES
every drain-reachable bucket and derives the ladder minimizing predicted
wall time per wave (launch counts are a proxy; the measured table rides in
the row as ``cost_model``, the configured drain policy as
``flush_policy``).  ``s3_cost_policy`` is the TIMED adaptive-drain row: a
NON-pinned watermark where the "cost" policy consults the measured bucket
table per drain opportunity (its decision trace rides in the row as
``flush_decisions``).  ``mixed_auto`` is the DESIGN.md §12 row: the
executor measures every family's s2 / s3 / fused wall time during warmup
and routes each family to its measured minimum — the resolved assignment
(``family_strategies``), the per-family verdicts (``selection``), and the
multi-path cost tables (``cost_model_paths``) ride in the row.
``s3_cost_store`` (emitted only with ``--store DIR``) is the DESIGN.md §13
warm-start row: identical knobs to ``s3_cost_auto`` plus a persistent
TuneStore — a COLD run measures, persists its tuning and reports
``warm_start: false``; a SECOND process against the same directory
restores the ladder / cost tables / chunk choice from disk and must
report ``warm_start: true`` with ``measurement_launches == 0`` (the CI
cold-vs-warm gate).  All wall times are MEDIANS of per-repeat means (raw
samples ride along in the JSON).

  PYTHONPATH=src python benchmarks/launch_overhead.py [--full] [--steps N]
                                                      [--store DIR]

Writes BENCH_launch_overhead.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
from bench_util import WM, flush_decision_trace, hist_deltas, \
    paired_overhead_pct, region_cost_models, region_cost_paths, \
    region_hists, region_ladders, region_measurement_launches, \
    region_selection, region_tuned_by, time_per_step, warm_start

from repro.configs.base import AggregationConfig, HydroConfig
from repro.core import StrategyRunner, UniformSedovScenario
from repro.core.executor import ExecutorPool
from repro.hydro.state import assemble_global, extract_subgrids, sedov_init
from repro.hydro.stepper import courant_dt

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_launch_overhead.json")


class SeedS2Runner:
    """The seed's s2 hot loop, verbatim semantics: slice each task out of
    the sub-grid array on the host queue, launch, then re-assemble with one
    O(n) ``jnp.concatenate`` per iteration.  Kept here (not in repro.core)
    purely as the measurable baseline."""

    def __init__(self, cfg: HydroConfig, n_executors: int = 1):
        self.cfg = cfg
        self._jit_batched = UniformSedovScenario(cfg).jitted_body("hydro_rhs")
        self.pool = ExecutorPool(n_executors)
        self.staging_s = 0.0
        self.launches = 0

    def rhs(self, u):
        subs = extract_subgrids(u, self.cfg.subgrid, self.cfg.ghost,
                                "outflow")
        n = subs.shape[0]
        results = [None] * n
        for i in range(n):
            t0 = time.perf_counter()
            task = subs[i:i + 1]
            self.staging_s += time.perf_counter() - t0
            results[i] = self.pool.get().launch(self._jit_batched, task)
        self.launches += n
        t0 = time.perf_counter()
        out = jnp.concatenate(results)
        self.staging_s += time.perf_counter() - t0
        return assemble_global(out, self.cfg.subgrid)

    def rk3_step(self, u, dt):
        l0 = self.rhs(u)
        u1 = u + dt * l0
        l1 = self.rhs(u1)
        u2 = 0.75 * u + 0.25 * (u1 + dt * l1)
        l2 = self.rhs(u2)
        return (1.0 / 3.0) * u + (2.0 / 3.0) * (u2 + dt * l2)


class SeedS3Runner:
    """The seed's s3 rhs, verbatim semantics: per-task ``subs[i]`` slicing
    into the submit queue (host staging re-stacks each bucket), then
    per-future slice + ``jnp.stack`` output assembly."""

    def __init__(self, cfg: HydroConfig, n_executors: int, max_agg: int,
                 watermark: int = 1):
        from repro.core.aggregation import AggregationExecutor
        self.cfg = cfg
        agg = AggregationConfig(strategy="s3", n_executors=n_executors,
                                max_aggregated=max_agg, staging="host",
                                launch_watermark=watermark)
        self.exe = AggregationExecutor(UniformSedovScenario(cfg).batched_body,
                                       agg, name="seed_s3")
        self.staging_s = 0.0

    def rhs(self, u):
        subs = extract_subgrids(u, self.cfg.subgrid, self.cfg.ghost,
                                "outflow")
        n = subs.shape[0]
        futs = [self.exe.submit(subs[i]) for i in range(n)]
        self.exe.flush()
        t0 = time.perf_counter()
        out = jnp.stack([f.result() for f in futs])   # seed output assembly
        self.staging_s += time.perf_counter() - t0
        return assemble_global(out, self.cfg.subgrid)

    def rk3_step(self, u, dt):
        l0 = self.rhs(u)
        u1 = u + dt * l0
        l1 = self.rhs(u1)
        u2 = 0.75 * u + 0.25 * (u1 + dt * l1)
        l2 = self.rhs(u2)
        return (1.0 / 3.0) * u + (2.0 / 3.0) * (u2 + dt * l2)


def run(levels: int = 2, steps: int = 3, repeats: int = 3,
        store: Optional[str] = None) -> List[dict]:
    cfg = HydroConfig(subgrid=8, ghost=3, levels=levels)
    st = sedov_init(cfg)
    dt = courant_dt(st.u, cfg)
    n = cfg.n_subgrids
    rows = []

    def record(tag, sec, launches, staging_s, dispatch_s: Optional[float],
               strategy=None, samples=None, ladder=None, hists=None,
               cost=None, cost_paths=None, flush_policy=None, guard=None,
               faults=None, family_strategies=None, selection=None,
               flush_decisions=None, warm=None, tuned_by=None,
               measurement_launches=None):
        row = {
            "config": tag, "strategy": strategy, "n_subgrids": n,
            "ms_per_step": round(sec * 1e3, 3),
            "launches_per_step": launches,
            "staging_ms_per_step": None if staging_s is None
            else round(staging_s * 1e3 / steps, 3),
            "dispatch_ms_per_step": None if dispatch_s is None
            else round(dispatch_s * 1e3 / steps, 3),
        }
        if samples is not None:
            row["ms_per_step_samples"] = [round(s * 1e3, 3) for s in samples]
        if ladder is not None:
            row["ladder"] = ladder
        if hists is not None:
            row["region_hists"] = hists
        if cost is not None:
            row["cost_model"] = cost
        if cost_paths is not None:
            row["cost_model_paths"] = cost_paths
        if flush_policy is not None:
            row["flush_policy"] = flush_policy
        if guard is not None:
            row["guard"] = guard
        if faults is not None:
            row["faults"] = faults
        if family_strategies is not None:
            row["family_strategies"] = dict(family_strategies)
        if selection is not None:
            row["selection"] = selection
        if flush_decisions is not None:
            row["flush_decisions"] = flush_decisions
        if warm is not None:
            row["warm_start"] = warm
        if tuned_by is not None:
            row["tuned_by"] = tuned_by
        if measurement_launches is not None:
            row["measurement_launches"] = measurement_launches
        rows.append(row)
        print(f"  {tag:24s} {row['ms_per_step']:9.2f} ms/step  "
              f"staging {row['staging_ms_per_step']} ms")

    # -- seed baselines ---------------------------------------------------
    seed2 = SeedS2Runner(cfg, n_executors=4)
    seed2.rk3_step(st.u, dt)                      # warmup
    seed2.staging_s = 0.0
    for e in seed2.pool.executors:
        e.dispatch_s = 0.0
    sec, samples = time_per_step(seed2.rk3_step, st.u, dt, steps, repeats)
    record("s2_seed_hoststage", sec, 3 * n,
           seed2.staging_s / repeats, seed2.pool.total_dispatch_s / repeats,
           strategy="s2", samples=samples)

    # launch_watermark is pinned high on the s3 A/B rows so both staging
    # modes drain with the IDENTICAL greedy bucket sequence — watermark
    # launches depend on busy-detection timing, which staging cost itself
    # perturbs (the comparison would otherwise measure emergent launch
    # policy, not staging)
    for tag, n_exec in [("s3_seed_hoststage", 1),
                        ("s2s3_seed_hoststage", 4)]:
        seed3 = SeedS3Runner(cfg, n_executors=n_exec, max_agg=16,
                             watermark=WM)
        seed3.rk3_step(st.u, dt)                  # warmup
        seed3.staging_s = 0.0
        seed3.exe.stats["staging_s"] = 0.0
        seed3.exe.stats["launches"] = 0
        for e in seed3.exe.pool.executors:
            e.dispatch_s = 0.0
        sec, samples = time_per_step(seed3.rk3_step, st.u, dt, steps,
                                     repeats)
        record(tag, sec,
               seed3.exe.stats["launches"] // (steps * repeats),
               (seed3.staging_s + seed3.exe.stats["staging_s"]) / repeats,
               seed3.exe.pool.total_dispatch_s / repeats,
               strategy="s3" if n_exec == 1 else "s2+s3", samples=samples)

    # -- the DESIGN.md §9 hot path + ladder sweep -------------------------
    # s3/s2+s3 rows run bulk submission + epilogue-fused mega-buckets with
    # chunked evaluation; the ladder sweep varies only the bucket cap.
    # "auto" rows let the per-region tuner re-derive the ladder from the
    # observed queue-length histogram (warmup waves) — a steady n-task wave
    # converges on one bucket-n launch per stage.
    agg_rows = [
        ("s2_slotring", "s2", 4, dict(max_aggregated=1, launch_watermark=1)),
        ("s3_slotring", "s3", 1,
         dict(max_aggregated=n, launch_watermark=WM, autotune=True,
              inner_chunk="auto", fuse_epilogue=True)),
        ("s2s3_slotring", "s2+s3", 4,
         dict(max_aggregated=n, launch_watermark=WM, autotune=True,
              inner_chunk="auto", fuse_epilogue=True)),
        ("fused_bound", "fused", 1,
         dict(max_aggregated=1, launch_watermark=1)),
    ]
    for cap in (16, 32, 64):
        agg_rows.append((f"s3_ladder{cap}", "s3", 1,
                         dict(max_aggregated=cap, launch_watermark=WM,
                              inner_chunk="auto", fuse_epilogue=True)))
    agg_rows.append(("s3_ladder_auto", "s3", 1,
                     dict(max_aggregated=n, launch_watermark=WM,
                          autotune=True, inner_chunk="auto",
                          fuse_epilogue=True)))
    # the DESIGN.md §10 row: the tuner times every drain-reachable bucket
    # (median-of-samples wall time) and derives the ladder minimizing
    # PREDICTED WALL TIME per wave, not launch count; the chosen ladder and
    # the measured cost table ride in the row.  launch_watermark is pinned
    # like the other rows, so the recorded flush_policy documents the
    # adaptive-drain configuration without perturbing the A/B drain.
    agg_rows.append(("s3_cost_auto", "s3", 1,
                     dict(max_aggregated=n, launch_watermark=WM,
                          autotune=True, inner_chunk="auto",
                          fuse_epilogue=True, cost_model=True,
                          flush_policy="cost")))
    # the DESIGN.md §13 warm-start row (only with --store): s3_cost_auto
    # knobs plus a persistent TuneStore and the roofline prior.  On a cold
    # store this row measures, persists its tuning and reports
    # warm_start=false; re-running the benchmark against the SAME store
    # directory restores everything from disk — the row then must report
    # warm_start=true and measurement_launches == 0 (the CI gate).
    if store is not None:
        agg_rows.append(("s3_cost_store", "s3", 1,
                         dict(max_aggregated=n, launch_watermark=WM,
                              autotune=True, inner_chunk="auto",
                              fuse_epilogue=True, cost_model=True,
                              flush_policy="cost", tune_store=store,
                              prior="roofline")))
    # the DESIGN.md §11 guard row: identical knobs to s3_cost_auto plus
    # guard="finite" — the untripped audit (ONE scalar all-finite check per
    # drained launch).  The acceptance bar is <= 5% overhead vs the
    # unguarded twin; the measured ratio rides in the row.
    agg_rows.append(("s3_cost_auto_guard", "s3", 1,
                     dict(max_aggregated=n, launch_watermark=WM,
                          autotune=True, inner_chunk="auto",
                          fuse_epilogue=True, cost_model=True,
                          flush_policy="cost", guard="finite")))
    # the TIMED adaptive-drain row (DESIGN.md §10): unlike every row above,
    # the watermark is NOT pinned — idle executors may drain early, and the
    # "cost" policy consults the measured bucket table to decide whether an
    # early partial drain beats waiting for the full wave.  The per-family
    # decision trace (consulted / drained_early / held counters) rides in
    # the row, so the policy's behaviour is observable, not just its cost.
    # max_aggregated is 2n: at exactly n the bulk-submitted wave hits the
    # cap branch, which flushes unconditionally — the policy would never
    # be consulted and the trace would be empty.
    agg_rows.append(("s3_cost_policy", "s3", 1,
                     dict(max_aggregated=2 * n, launch_watermark=1,
                          autotune=True, inner_chunk="auto",
                          fuse_epilogue=True, cost_model=True,
                          flush_policy="cost")))
    # the DESIGN.md §12 row: cost-driven per-family routing.  The executor
    # measures every family's s2 / s3 / fused wall time during warmup and
    # ``select_strategy`` routes each family to its measured minimum; the
    # resolved assignment and the costs that justified it ride in the row.
    agg_rows.append(("mixed_auto", "mixed", 4,
                     dict(max_aggregated=n, launch_watermark=WM,
                          autotune=True, inner_chunk="auto",
                          fuse_epilogue=True, cost_model=True)))
    scn = UniformSedovScenario(cfg)   # shared: one body, one chunk tuning
    runners = {}                      # kept alive for the paired guard A/B
    for tag, strat, n_exec, knobs in agg_rows:
        agg = AggregationConfig(strategy=strat, n_executors=n_exec,
                                staging="device", **knobs)
        r = StrategyRunner(scn, agg)
        r.warmup(wave_only=True)      # AOT wave buckets + chunk selection
        r.rk3_step(st.u, dt)          # warmup/compile (autotune retunes
        warm_hists = region_hists(r)  # mid-step: 3 waves > warmup=2)
        r.stats["staging_s"] = 0.0
        r.stats["kernel_launches"] = 0
        if r.executor is not None:
            r.executor.stats["staging_s"] = 0.0
            r.executor.stats["launches"] = 0
        for e in r.pool.executors:
            e.dispatch_s = 0.0
        sec, samples = time_per_step(r.rk3_step, st.u, dt, steps, repeats)
        staging_s = (r.executor.stats["staging_s"]
                     if r.executor is not None else 0.0)
        launches = (3 * n if strat == "s2"
                    else 3 if strat == "fused"
                    else r.stats["kernel_launches"] / (steps * repeats)
                    if strat == "mixed"
                    else r.executor.stats["launches"] // (steps * repeats))
        aggregated = r.executor is not None
        guard_val = getattr(agg, "guard", "off")
        fault_stats = None
        if aggregated and guard_val != "off":
            fault_stats = {fam: dict(s["faults"])
                           for fam, s in r.executor.stats["regions"].items()
                           if "faults" in s}
        mixed = strat == "mixed"
        stored = "tune_store" in knobs
        if stored:
            # Persist whatever this process tuned so the NEXT process warm
            # starts.  On a warm run the regions were restored (not
            # measured), so this is a no-op merge of identical entries.
            r.save_tuning()
        record(tag, sec, launches, staging_s / repeats,
               r.pool.total_dispatch_s / repeats, strategy=strat,
               samples=samples,
               ladder=region_ladders(r) if aggregated else None,
               hists=(hist_deltas(region_hists(r), warm_hists)
                      if aggregated else None),
               cost=region_cost_models(r) or None,
               cost_paths=(region_cost_paths(r) or None) if mixed else None,
               flush_policy=(getattr(agg, "flush_policy", "eager")
                             if aggregated else None),
               guard=guard_val if guard_val != "off" else None,
               faults=fault_stats,
               family_strategies=(dict(agg.family_strategies)
                                  if agg.family_strategies else {"*": "auto"})
               if mixed else None,
               selection=(region_selection(r) or None) if mixed else None,
               flush_decisions=(flush_decision_trace(r) or None),
               warm=warm_start(r) if stored else None,
               tuned_by=(region_tuned_by(r) or None) if stored else None,
               measurement_launches=(region_measurement_launches(r)
                                     if stored else None))
        if tag in ("s3_cost_auto", "s3_cost_auto_guard"):
            runners[tag] = r
    # guarded-vs-unguarded overhead (the <= 5% acceptance metric).  The
    # two rows' own ms_per_step are timed minutes apart and this box
    # drifts more than the guard costs (bench_util.time_per_step), so the
    # acceptance ratio is measured PAIRED: the warm runners re-timed
    # back-to-back within each repeat, ratio per repeat, median of ratios.
    by_tag = {row["config"]: row for row in rows}
    if "s3_cost_auto" in runners and "s3_cost_auto_guard" in runners:
        pct, ratios = paired_overhead_pct(
            runners["s3_cost_auto"].rk3_step,
            runners["s3_cost_auto_guard"].rk3_step, st.u, dt, steps,
            repeats)
        guarded = by_tag["s3_cost_auto_guard"]
        guarded["guard_overhead_pct"] = pct
        guarded["guard_overhead_ratios"] = ratios
        print(f"  guard overhead vs s3_cost_auto (paired): {pct:+.2f}%  "
              f"ratios={ratios}")

    # -- fault-injection smoke: one poisoned task, containment observable --
    # A single injected NaN task in the first wave: the guard trips, the
    # ladder bisection isolates the culprit, and the enriched failure
    # surfaces through the strategy layer.  Counters (not wall time) are
    # the point of this row.
    from repro.core import FaultInjector, FaultSpec, TaskFailedError
    inj = FaultInjector([FaultSpec(site="payload", kernel="hydro_rhs",
                                   task=0, mode="nan", times=1)], seed=0)
    agg = AggregationConfig(strategy="s3", n_executors=1, staging="device",
                            max_aggregated=n, launch_watermark=WM,
                            guard="finite")
    r = StrategyRunner(scn, agg, fault_injector=inj)
    r.warmup(wave_only=True)          # keep compile time out of the row
    t0 = time.perf_counter()
    contained = False
    try:
        r.rk3_step(st.u, dt)
    except TaskFailedError:
        contained = True
    smoke_sec = time.perf_counter() - t0
    assert contained, "fault smoke: injected NaN was not contained"
    fault_stats = {fam: dict(s["faults"])
                   for fam, s in r.executor.stats["regions"].items()}
    record("s3_guard_faultsmoke", smoke_sec,
           r.executor.stats["launches"], 0.0, None, strategy="s3",
           guard="finite", faults=fault_stats)

    # -- scan trajectory: whole multi-step RK3 as one program -------------
    r = StrategyRunner(UniformSedovScenario(cfg),
                       AggregationConfig(strategy="fused"))
    r.rk3_trajectory(st.u, dt, steps)             # warmup/compile
    samples = []
    for _ in range(repeats):
        jax.block_until_ready(st.u)
        t0 = time.perf_counter()
        out = r.rk3_trajectory(st.u, dt, steps)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / steps)
    record("fused_scan_bound", statistics.median(samples), 1.0 / steps,
           0.0, None, strategy="fused", samples=samples)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact 512 sub-grids (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI tier-1 smoke: smallest grid, 1 step, 1 repeat "
                         "(counters are exact; wall times indicative only)")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N timing (filters scheduler noise)")
    ap.add_argument("--store", default=os.environ.get("REPRO_TUNE_STORE")
                    or None, metavar="DIR",
                    help="persistent tune-store directory: adds the "
                         "s3_cost_store warm-start row (cold run measures "
                         "and persists; a second run against the same DIR "
                         "must report measurement_launches == 0)")
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.repeats = 1, 1
    if args.steps < 1 or args.repeats < 1:
        ap.error("--steps and --repeats must be >= 1")
    levels = 1 if args.smoke else 3 if args.full else 2
    print(f"launch_overhead: Sedov, {8 ** 3 * (2 ** levels) ** 3} cells, "
          f"backend={jax.default_backend()}")
    rows = run(levels=levels, steps=args.steps, repeats=args.repeats,
               store=args.store)
    payload = {
        "benchmark": "launch_overhead",
        "backend": jax.default_backend(),
        "config": "sedov",
        "levels": levels,
        "steps": args.steps,
        "repeats": args.repeats,
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
