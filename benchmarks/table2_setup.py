"""Paper Table II analogue: scenario setup (cells, sub-grids, launch counts).

Prints the exact Table II quantities for the two sub-grid configurations,
derived from the implemented solver (not hard-coded): total cells, leaf
sub-grid count, ghost cells per sub-grid, kernel calls per time-step
(5 kernel families x 3 RK iterations x sub-grids), and the host-device
transfer count analogue (under XLA the per-kernel H2D/D2H pairs of the CUDA
implementation fuse into the program — reported as 0 by construction, the
first structural win of the whole-graph approach; see EXPERIMENTS.md).
"""
from __future__ import annotations

from repro.configs import sedov, sedov_16

KERNEL_FAMILIES = 5     # prep, reconstruct, flux, update, dt-reduce
RK_ITERS = 3


def rows():
    out = []
    for cfg in (sedov, sedov_16):
        padded = cfg.padded
        ghost_cells = padded ** 3 - cfg.subgrid ** 3
        out.append({
            "subgrid": f"{cfg.subgrid}^3",
            "cells": cfg.cells_total,
            "leaf_subgrids": cfg.n_subgrids,
            "ghost_cells_per_subgrid": ghost_cells,
            "kernel_calls_per_step": KERNEL_FAMILIES * RK_ITERS * cfg.n_subgrids,
            "cpu_gpu_transfers_per_step": 0,
        })
    return out


def main() -> None:
    print("table2_setup: Sedov blast-wave scenario (paper Table II)")
    hdr = ("subgrid", "cells", "leaf_subgrids", "ghost_cells_per_subgrid",
           "kernel_calls_per_step", "cpu_gpu_transfers_per_step")
    print(",".join(hdr))
    for r in rows():
        print(",".join(str(r[h]) for h in hdr))
    # paper's numbers as assertions (reproduction check)
    r8, r16 = rows()
    assert r8["cells"] == 262144 and r16["cells"] == 262144
    assert r8["leaf_subgrids"] == 512 and r16["leaf_subgrids"] == 64
    assert r8["kernel_calls_per_step"] == 7680
    assert r16["kernel_calls_per_step"] == 960
    print("OK: matches paper Table II (512/64 leaves, 7680/960 calls)")


if __name__ == "__main__":
    main()
