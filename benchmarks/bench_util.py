"""Shared helpers for the strategy-sweep benchmarks.

The aggregation executor's counters are cumulative and include the warm
(compile) step, while the benchmark rows report per-timed-step values —
these helpers snapshot/diff the per-family bucket histograms so every
sweep's JSON stays internally consistent.
"""
from __future__ import annotations

# launch watermark that never fires: sweeps pin the greedy bucket drain so
# launch counts measure aggregation policy, not idle-detection timing
WM = 10 ** 9


def region_hists(runner) -> dict:
    """Per-family bucket histograms of a runner's aggregation executor
    (empty when the strategy runs without one)."""
    if runner.executor is None:
        return {}
    return {k: dict(v["aggregated_hist"])
            for k, v in runner.executor.stats["regions"].items()}


def hist_deltas(now: dict, warm: dict) -> dict:
    """Per-family bucket histograms over the timed region only."""
    out = {}
    for fam, hist in now.items():
        d = {b: c - warm.get(fam, {}).get(b, 0) for b, c in hist.items()}
        out[fam] = {b: c for b, c in d.items() if c}
    return out
