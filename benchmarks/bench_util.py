"""Shared helpers for the strategy-sweep benchmarks.

The aggregation executor's counters are cumulative and include the warm
(compile) step, while the benchmark rows report per-timed-step values —
these helpers snapshot/diff the per-family bucket histograms so every
sweep's JSON stays internally consistent.  ``time_per_step`` is the shared
timing loop: it reports the MEDIAN of per-repeat mean step times (this box
shows ±20% run-to-run variance on identical programs; a single mean or a
best-of hides that, the median with the raw samples alongside does not).
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, List, Tuple

import jax

# launch watermark that never fires: sweeps pin the greedy bucket drain so
# launch counts measure aggregation policy, not idle-detection timing
WM = 10 ** 9


def time_per_step(step_fn: Callable, state, dt, steps: int,
                  repeats: int) -> Tuple[float, List[float]]:
    """Median-of-repeats seconds per step, plus the raw per-repeat samples
    (each sample is one repeat's mean over ``steps`` steps)."""
    samples = []
    for _ in range(repeats):
        out = state
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(steps):
            out = step_fn(out, dt)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / steps)
    return statistics.median(samples), samples


def paired_overhead_pct(base_fn: Callable, test_fn: Callable, state, dt,
                        steps: int, repeats: int
                        ) -> Tuple[float, List[float]]:
    """Median paired overhead of ``test_fn`` vs ``base_fn`` in percent,
    plus the raw per-repeat ratios.  Run-to-run drift on this box exceeds
    the effects an A/B row pair measures (see ``time_per_step``), so the
    two step functions are timed back-to-back WITHIN each repeat — the
    per-repeat ratio cancels slow drift, the median rejects spikes.  At
    least 5 paired repeats run even in smoke mode (a single ratio is no
    better than the unpaired difference it replaces)."""
    ratios = []
    for _ in range(max(5, repeats)):
        base_s, _ = time_per_step(base_fn, state, dt, steps, 1)
        test_s, _ = time_per_step(test_fn, state, dt, steps, 1)
        ratios.append(test_s / base_s)
    return (round(100.0 * (statistics.median(ratios) - 1.0), 2),
            [round(r, 4) for r in ratios])


def region_ladders(runner) -> dict:
    """Per-family bucket ladders of a runner's aggregation executor (the
    auto-tuner's output surface; empty without an executor)."""
    if runner.executor is None:
        return {}
    return {k: list(v.get("ladder", []))
            for k, v in runner.executor.stats["regions"].items()}


def region_hists(runner) -> dict:
    """Per-family bucket histograms of a runner's aggregation executor
    (empty when the strategy runs without one)."""
    if runner.executor is None:
        return {}
    return {k: dict(v["aggregated_hist"])
            for k, v in runner.executor.stats["regions"].items()}


def region_cost_models(runner) -> dict:
    """Per-family measured bucket-cost tables (bucket -> median ms) of a
    runner's aggregation executor — the DESIGN.md §10 observability
    surface.  Empty without an executor or before any measurement ran
    (``cost_model=False`` rows)."""
    if runner.executor is None:
        return {}
    return {k: {str(b): ms for b, ms in v["cost_model"].items()}
            for k, v in runner.executor.stats["regions"].items()
            if v.get("cost_model")}


def hist_deltas(now: dict, warm: dict) -> dict:
    """Per-family bucket histograms over the timed region only."""
    out = {}
    for fam, hist in now.items():
        d = {b: c - warm.get(fam, {}).get(b, 0) for b, c in hist.items()}
        out[fam] = {b: c for b, c in d.items() if c}
    return out
