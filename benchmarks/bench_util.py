"""Shared helpers for the strategy-sweep benchmarks.

The aggregation executor's counters are cumulative and include the warm
(compile) step, while the benchmark rows report per-timed-step values —
these helpers snapshot/diff the per-family bucket histograms so every
sweep's JSON stays internally consistent.  ``time_per_step`` is the shared
timing loop: it reports the MEDIAN of per-repeat mean step times (this box
shows ±20% run-to-run variance on identical programs; a single mean or a
best-of hides that, the median with the raw samples alongside does not).
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, List, Tuple

import jax

# launch watermark that never fires: sweeps pin the greedy bucket drain so
# launch counts measure aggregation policy, not idle-detection timing
WM = 10 ** 9


def time_per_step(step_fn: Callable, state, dt, steps: int,
                  repeats: int) -> Tuple[float, List[float]]:
    """Median-of-repeats seconds per step, plus the raw per-repeat samples
    (each sample is one repeat's mean over ``steps`` steps)."""
    samples = []
    for _ in range(repeats):
        out = state
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(steps):
            out = step_fn(out, dt)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / steps)
    return statistics.median(samples), samples


def paired_overhead_pct(base_fn: Callable, test_fn: Callable, state, dt,
                        steps: int, repeats: int
                        ) -> Tuple[float, List[float]]:
    """Median paired overhead of ``test_fn`` vs ``base_fn`` in percent,
    plus the raw per-repeat ratios.  Run-to-run drift on this box exceeds
    the effects an A/B row pair measures (see ``time_per_step``), so the
    two step functions are timed back-to-back WITHIN each repeat — the
    per-repeat ratio cancels slow drift, the median rejects spikes.  At
    least 5 paired repeats run even in smoke mode (a single ratio is no
    better than the unpaired difference it replaces)."""
    ratios = []
    for _ in range(max(5, repeats)):
        base_s, _ = time_per_step(base_fn, state, dt, steps, 1)
        test_s, _ = time_per_step(test_fn, state, dt, steps, 1)
        ratios.append(test_s / base_s)
    return (round(100.0 * (statistics.median(ratios) - 1.0), 2),
            [round(r, 4) for r in ratios])


def _regions(runner) -> dict:
    """The strategy-independent per-family stats surface.  With an
    aggregation executor this is a live view of its region registry; s2 /
    fused / mixed populate the same key on the runner's own stats, so s2
    rows stop reporting null histograms (DESIGN.md §12 stats parity)."""
    return runner.stats.get("regions", {})


def region_ladders(runner) -> dict:
    """Per-family bucket ladders (the auto-tuner's output surface; a
    family routed away from the executor reports an empty ladder)."""
    return {k: list(v.get("ladder", []))
            for k, v in _regions(runner).items()}


def region_hists(runner) -> dict:
    """Per-family launched-batch histograms.  For aggregated families
    these are bucket sizes; for s2-routed families, coalesce widths."""
    return {k: dict(v.get("aggregated_hist", {}))
            for k, v in _regions(runner).items()}


def region_cost_models(runner) -> dict:
    """Per-family measured s3 bucket-cost tables (bucket -> median ms) —
    the DESIGN.md §10 observability surface.  Empty before any
    measurement ran (``cost_model=False`` rows)."""
    return {k: {str(b): ms for b, ms in v["cost_model"].items()}
            for k, v in _regions(runner).items()
            if v.get("cost_model")}


def region_cost_paths(runner) -> dict:
    """Per-family per-execution-path cost tables
    (family -> path -> batch/width -> median ms): the DESIGN.md §12
    surface that justifies s2-vs-s3-vs-fused selection."""
    return {k: {p: {str(b): ms for b, ms in tbl.items()}
                for p, tbl in v["cost_model_paths"].items()}
            for k, v in _regions(runner).items()
            if v.get("cost_model_paths")}


def region_selection(runner) -> dict:
    """Per-family routing decision: which strategy ran the family and the
    measured per-path costs (ms for the family's wave) that justified it.
    ``strategy_costs`` is null for explicit (non-measured) assignments."""
    out = {}
    for k, v in _regions(runner).items():
        if v.get("selected_strategy") is None:
            continue
        out[k] = {"selected_strategy": v["selected_strategy"],
                  "strategy_costs": v.get("strategy_costs"),
                  "s2_width": v.get("s2_width")}
    return out


def flush_decision_trace(runner) -> dict:
    """Per-family flush-policy decision counters (policy consulted /
    full-wave drains / early drains / holds) — the ``flush_policy="cost"``
    observability surface.  Empty under the eager policy."""
    return {k: dict(v["flush_decisions"])
            for k, v in _regions(runner).items()
            if v.get("flush_decisions")}


def warm_start(runner) -> bool:
    """Did any of the runner's families restore tuned state from the
    persistent tune store (DESIGN.md §13)?"""
    exe = getattr(runner, "executor", None)
    return bool(exe.stats.get("warm_start")) if exe is not None else False


def region_tuned_by(runner) -> dict:
    """Per-family provenance of the current tuning: "store" (loaded from
    the persistent tune store), "prior" (analytical roofline seed),
    "measured" (live cost-model retune) or "launches" (launch-count
    retune).  Absent families have never been tuned."""
    return {k: v["tuned_by"] for k, v in _regions(runner).items()
            if v.get("tuned_by")}


def region_measurement_launches(runner) -> dict:
    """Per-family kernel launches spent on stopwatch measurement (bucket
    timing, s2/fused probes, chunk sweeps).  A warm-started process must
    report 0 everywhere — the §13 acceptance counter."""
    return {k: int(v.get("measurement_launches", 0))
            for k, v in _regions(runner).items()}


def hist_deltas(now: dict, warm: dict) -> dict:
    """Per-family bucket histograms over the timed region only."""
    out = {}
    for fam, hist in now.items():
        d = {b: c - warm.get(fam, {}).get(b, 0) for b, c in hist.items()}
        out[fam] = {b: c for b, c in d.items() if c}
    return out
